"""Distributed in-memory checkpoint loading: LoadPlan executors,
range-limited RAIM5 decode, reshard-on-restore (elastic n->m), ranged
tier-3 file restores, and RestoreResult load stats."""
import os
from types import SimpleNamespace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import CheckpointSession, CheckpointSpec, RestoreTarget
from repro.core import ReftConfig, ReftGroup, raim5
from repro.core.loader import (
    FileSource, LoadStats, ShmSource, build_plan, load_bytes, load_tree,
    member_shard_need, need_for_leaves, need_for_sharding, normalize_ranges,
)
from repro.core.recovery import (
    attach_survivors, checkpoint_families, latest_checkpoint_step,
    restore_bytes, restore_from_checkpoint, restore_state,
)
from repro.core.treebytes import make_flat_spec


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 32)),
                   "b": jnp.ones((17,), jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((64, 32)), "step": jnp.int32(0)},
        "rng": jax.random.PRNGKey(seed + 1),
    }


def advance(state, step):
    return jax.tree.map(
        lambda x: x + step if x.dtype != jnp.uint32 else x, state)


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def fake_mesh(**axes):
    return SimpleNamespace(axis_names=tuple(axes),
                           axis_sizes=tuple(axes.values()))


@pytest.fixture
def group(tmp_path):
    state = small_state()
    cfg = ReftConfig(bucket_bytes=1024, stage_slots=4,
                     ckpt_dir=str(tmp_path),
                     checkpoint_every_snapshots=10 ** 6)
    g = ReftGroup(4, state, cfg)
    yield g, state
    g.close()


def _monolithic_restore(views, n, total_bytes, step, failed=None):
    """The pre-refactor whole-region path, kept here as the oracle: read
    every member's full shard, decode the failed member's WHOLE shard,
    reassemble one contiguous buffer."""
    if n == 1:
        (view,) = views.values()
        return view.read_own(step)[:total_bytes].copy()

    def read_block(node, stripe, index):
        return views[node].read_block(step, stripe, index)

    recovered = None
    if failed is not None:
        recovered = raim5.decode_node(
            failed, n, total_bytes, read_block=read_block,
            read_parity=lambda s: views[s].read_parity(step))
    return raim5.reassemble(n, total_bytes, read_block, recovered)


# ----------------------------------------------------------- plan algebra
def test_normalize_ranges_merges_and_clips():
    assert normalize_ranges([(5, 10), (8, 20), (30, 30), (-5, 3)], 18) \
        == ((0, 3), (5, 18))
    assert normalize_ranges([], 100) == ()


def test_build_plan_full_coverage_and_partial():
    n, total = 4, 100_000
    plan = build_plan(n, total)
    # direct reads cover every real byte exactly once (no failed member)
    assert plan.read_bytes == total and plan.decode_bytes == 0
    for node in plan.reads:
        assert plan.member_covered(node)
    # partial need -> strictly fewer bytes, decode limited to intersection
    # (9000, 12000) sits inside block (stripe 0, idx 1), owned by node 2)
    need = [(9000, 12_000), (60_000, 61_000)]
    p2 = build_plan(n, total, need=need, failed=2)
    covered = sum(b - a for a, b in p2.need)
    assert p2.read_bytes + p2.decode_bytes == covered
    assert 2 not in p2.reads
    bs = raim5.block_size(total, n)
    whole_shard = sum(
        min(hi, total) - min(lo, total)
        for lo, hi in (r.byte_range(bs, n)
                       for r in raim5.data_blocks_of_node(2, n)))
    assert 0 < p2.decode_bytes < whole_shard


def test_resolve_need_member_requires_sg_size():
    from repro.core.loader import resolve_need
    spec = make_flat_spec({"w": np.zeros((8,), np.float32)})
    with pytest.raises(ValueError, match="sg_size"):
        resolve_need(spec, RestoreTarget(member=1))
    with pytest.raises(ValueError, match="out of range"):
        resolve_need(spec, RestoreTarget(member=5, sg_size=2))
    need = resolve_need(spec, RestoreTarget(member=1, sg_size=2))
    assert need and sum(b - a for a, b in need) < spec.total_bytes


def test_member_shard_need_partitions_stream():
    total = 99_999
    for m in (1, 2, 3, 5):
        allr = []
        for member in range(m):
            allr += member_shard_need(m, member, total)
        assert normalize_ranges(allr, total) == ((0, total),)
        covered = sum(b - a for a, b in normalize_ranges(allr, total))
        assert sum(b - a for a, b in allr) == covered   # disjoint shards


# ------------------------------------------------- byte-identity vs oracle
def test_ranged_loader_byte_identical_to_monolithic(group):
    g, state = group
    g.snapshot(state, 1)
    views = attach_survivors(g.run, list(range(4)), 4, g.total_bytes)
    try:
        want = _monolithic_restore(views, 4, g.total_bytes, 1)
        got = restore_bytes(views, 4, g.total_bytes, 1)
        np.testing.assert_array_equal(got, want)
    finally:
        for v in views.values():
            v.close()


def test_ranged_decode_byte_identical_after_node_loss(group):
    g, state = group
    g.snapshot(state, 1)
    g.inject_node_failure(2)
    views = attach_survivors(g.run, [0, 1, 3], 4, g.total_bytes)
    try:
        want = _monolithic_restore(views, 4, g.total_bytes, 1, failed=2)
        st = LoadStats()
        got = restore_bytes(views, 4, g.total_bytes, 1, failed=2, stats=st)
        np.testing.assert_array_equal(got, want)
        assert st.decoded_bytes > 0
    finally:
        for v in views.values():
            v.close()


def test_range_limited_decode_decodes_less_than_whole_shard(group):
    """A partial plan touching a lost member decodes ONLY the
    plan-intersecting stripe sub-ranges, not the whole shard."""
    g, state = group
    g.snapshot(state, 1)
    spec = make_flat_spec(state)
    need = need_for_leaves(spec, ("w",))        # params.w only
    full_plan = build_plan(4, g.total_bytes, failed=1)
    whole_shard = sum(r.nbytes
                     for r in build_plan(4, g.total_bytes).reads[1])
    g.inject_node_failure(1)
    views = attach_survivors(g.run, [0, 2, 3], 4, g.total_bytes)
    try:
        plan = build_plan(4, g.total_bytes, need=need, failed=1)
        assert 0 < plan.decode_bytes < whole_shard
        buf, st = load_bytes(plan, ShmSource(views, 1), verify=False)
        assert st.decoded_bytes == plan.decode_bytes
        # the needed ranges are byte-identical to a full decode restore
        want = _monolithic_restore(views, 4, g.total_bytes, 1, failed=1)
        for a, b in plan.need:
            np.testing.assert_array_equal(buf[a:b], want[a:b])
        assert full_plan.decode_bytes == whole_shard  # contrast: full plan
    finally:
        for v in views.values():
            v.close()


def test_load_tree_streamed_h2d(group):
    """Per-leaf streamed assembly with overlapped device_put restores the
    same tree as the host path."""
    g, state = group
    g.snapshot(state, 1)
    views = attach_survivors(g.run, list(range(4)), 4, g.total_bytes)
    try:
        spec = make_flat_spec(state)
        plan = build_plan(4, g.total_bytes)
        tree, st = load_tree(plan, ShmSource(views, 1), state, spec,
                             device_put=True)
        assert trees_equal(tree, state)
        assert st.h2d_seconds >= 0.0
        assert st.crc_members == tuple(sorted(plan.reads))
    finally:
        for v in views.values():
            v.close()


# ------------------------------------------------------ facade load stats
def test_restore_result_load_stats_sanity(group, tmp_path):
    g, state = group
    g.snapshot(state, 1)
    g.inject_node_failure(3)
    rec, step, extra, tier = g.recover()
    assert tier == "raim5" and trees_equal(rec, state)
    ld = g.last_load_stats
    assert ld is not None
    assert ld.tier == "raim5" and ld.source == "shm"
    assert ld.bytes_read > 0 and ld.read_seconds >= 0.0
    # a FULL restore of a lost member decodes its entire (real) shard
    whole_shard = sum(r.nbytes
                      for r in build_plan(4, g.total_bytes).reads[3])
    assert ld.decoded_bytes == whole_shard
    assert ld.members == (0, 1, 2)
    assert ld.saved_n == 4 and not ld.resharded


def test_partial_restore_via_target_leaves(tmp_path):
    """RestoreTarget(leaves=...) loads only matching leaves; the rest keep
    the template's values (and the plan reads strictly less)."""
    template = small_state(5)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path), sg_size=4,
                          resume=False)
    ck = spec.build(template)
    try:
        state = advance(template, 3)
        assert ck.snapshot(state, 1, wait=True)
        res = ck.restore(target=RestoreTarget(leaves=("params",)))
        assert res.tier == "in-memory"
        assert trees_equal(res.state["params"], state["params"])
        assert trees_equal(res.state["opt"], template["opt"])   # untouched
        total = make_flat_spec(template).total_bytes
        assert 0 < res.load.bytes_needed < total
    finally:
        ck.close()


def test_partial_leaf_straddle_keeps_template_bytes(group):
    """A plan boundary cutting THROUGH a leaf: the uncovered part keeps
    the template's values (not zeros), consistent with untouched leaves."""
    g, state = group
    g.snapshot(state, 1)
    spec = make_flat_spec(state)
    w = next(l for l in spec.leaves if "w" in l.path)
    half = w.offset + w.nbytes // 2
    views = attach_survivors(g.run, list(range(4)), 4, g.total_bytes)
    try:
        plan = build_plan(4, g.total_bytes, need=[(w.offset, half)])
        template = advance(state, 9)          # distinguishable from state
        tree, _ = load_tree(plan, ShmSource(views, 1), template, spec,
                            verify=False)
        got = np.asarray(tree["params"]["w"]).reshape(-1) \
            .view(np.uint8)
        want_lo = np.asarray(state["params"]["w"]).reshape(-1) \
            .view(np.uint8)[:w.nbytes // 2]
        want_hi = np.asarray(template["params"]["w"]).reshape(-1) \
            .view(np.uint8)[w.nbytes // 2:]
        np.testing.assert_array_equal(got[:w.nbytes // 2], want_lo)
        np.testing.assert_array_equal(got[w.nbytes // 2:], want_hi)
    finally:
        for v in views.values():
            v.close()


# --------------------------------------------------- elastic n->m restart
def test_elastic_restart_state_parity(tmp_path):
    """An n=4 run's REFT-Ckpt restores under m=2 (reshard-on-restore) to
    the SAME state a same-topology (4->4) restore produces."""
    template = small_state(7)
    state = advance(advance(template, 1), 2)
    spec4 = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                           sg_size=4, resume=False)
    with CheckpointSession(spec4, template) as sess:
        assert sess.snapshot(state, 2, extra_meta={"at": 2}, wait=True)
        assert sess.persist() == 2

    # same-topology resume (4 -> 4)
    with CheckpointSession(
            CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                           sg_size=4, resume=True), template) as s44:
        same = s44.restored
        assert same is not None and same.step == 2

    # elastic resume (4 -> 2): different sg_size, same checkpoint dir
    with CheckpointSession(
            CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                           sg_size=2, resume=True), template) as s42:
        elastic = s42.restored
        assert elastic is not None
        assert elastic.step == 2 and elastic.tier == "checkpoint"
        assert elastic.extra_meta == {"at": 2}
        assert trees_equal(elastic.state, same.state)
        assert trees_equal(elastic.state, state)
        ld = elastic.load
        assert ld.resharded and ld.saved_n == 4 and ld.target_n == 2


def test_corrupt_meta_of_first_holder_is_demoted_not_fatal(group):
    """A member whose snapshot META is unreadable must be demoted and
    parity-rebuilt like any corrupt member — even when it is the first
    holder the ladder would have read the spec from."""
    g, state = group
    g.snapshot(state, 1)
    views = attach_survivors(g.run, [0], 4, g.total_bytes)
    idx = views[0].clean_steps()[1]
    for v in views.values():
        v.close()
    from repro.core.smp import META_SLOT, _attach, _seg
    shm = _attach(_seg(g.run, 0, "meta"))
    base = idx * META_SLOT
    shm.buf[base + 8:base + 20] = b"x" * 12        # clobber the pickle
    shm.close()
    rec, step, extra, tier = g.recover()
    assert tier == "raim5" and step == 1
    assert trees_equal(rec, state)


def test_verify_crc_probe_utility(group):
    """The standalone streamed probe: clean member -> True, corrupt own
    region -> False (same verdicts the ladder's folded checks apply)."""
    from repro.core.recovery import verify_crc
    from repro.core.smp import _attach, _seg
    g, state = group
    g.snapshot(state, 1)
    views = attach_survivors(g.run, [0, 1], 4, g.total_bytes)
    try:
        assert verify_crc(views[0], 1, 4, g.total_bytes, chunk_bytes=512)
        idx = views[1].clean_steps()[1]
        shm = _attach(_seg(g.run, 1, f"buf{idx}"))
        shm.buf[10] = (shm.buf[10] + 1) % 256
        shm.close()
        assert not verify_crc(views[1], 1, 4, g.total_bytes,
                              chunk_bytes=512)
    finally:
        for v in views.values():
            v.close()


def _corrupt_reft_parity(path):
    import pickle
    with open(path, "rb") as f:
        head = pickle.load(f)
        data_off = f.tell()
    from repro.core.smp import NodeLayout
    lay = NodeLayout(head["n"], head["total_bytes"])
    blob = bytearray(open(path, "rb").read())
    blob[data_off + lay.own_bytes + 5] ^= 0xFF
    open(path, "wb").write(bytes(blob))


def test_tier3_corrupt_parity_feeding_decode_is_caught(tmp_path):
    """A corrupt survivor PARITY block must not XOR silently into decoded
    bytes: the parity digest (recorded at publish) demotes its holder,
    the budget trips, and the older intact family restores."""
    template = small_state(17)
    s2 = advance(template, 2)
    s4 = advance(s2, 4)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                          sg_size=4, resume=False)
    with CheckpointSession(spec, template) as sess:
        assert sess.snapshot(s2, 2, wait=True)
        assert sess.persist() == 2
        assert sess.snapshot(s4, 4, wait=True)
        assert sess.persist() == 4
    # corrupt node 2's OWN region (demoted -> needs decode) AND node 1's
    # PARITY region (feeds that decode) in the step-4 family
    import pickle as _p
    p2 = os.path.join(str(tmp_path), "step-4-node-2.reft")
    with open(p2, "rb") as f:
        _p.load(f)
        off = f.tell()
    blob = bytearray(open(p2, "rb").read())
    blob[off + 100] ^= 0xFF
    open(p2, "wb").write(bytes(blob))
    _corrupt_reft_parity(os.path.join(str(tmp_path), "step-4-node-1.reft"))
    tree, step, _ = restore_from_checkpoint(str(tmp_path), 4, template)
    assert step == 2 and trees_equal(tree, s2)


def _corrupt_reft_meta(path):
    import pickle
    with open(path, "rb") as f:
        head = pickle.load(f)
        payload = f.read()
    head["meta"] = b"garbage-not-pickle"
    with open(path, "wb") as f:
        pickle.dump(head, f)
        f.write(payload)


def test_tier3_corrupt_meta_demoted_then_family_skipped(tmp_path):
    """One corrupt meta blob in a family: that member is demoted and
    decoded.  Two (over RAIM5's budget): the family is SKIPPED and the
    older intact family restores — tier 3 never aborts on bad metadata."""
    template = small_state(13)
    s2 = advance(template, 2)
    s4 = advance(s2, 4)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                          sg_size=4, resume=False)
    with CheckpointSession(spec, template) as sess:
        assert sess.snapshot(s2, 2, wait=True)
        assert sess.persist() == 2
        assert sess.snapshot(s4, 4, wait=True)
        assert sess.persist() == 4
    _corrupt_reft_meta(os.path.join(str(tmp_path), "step-4-node-1.reft"))
    st = LoadStats()
    tree, step, _ = restore_from_checkpoint(str(tmp_path), 4, template,
                                            stats=st)
    assert step == 4 and trees_equal(tree, s4)
    assert st.decoded_bytes > 0                    # node 1 rebuilt
    _corrupt_reft_meta(os.path.join(str(tmp_path), "step-4-node-2.reft"))
    tree, step, _ = restore_from_checkpoint(str(tmp_path), 4, template)
    assert step == 2 and trees_equal(tree, s2)     # fell back one family


def test_tier3_corrupt_shard_demoted_and_decoded(tmp_path):
    """The ranged file loader folds each shard file's CRC into its read
    pass; a flipped byte demotes that member and RAIM5 rebuilds it from
    the family's parity blocks — disk corruption no longer silently
    poisons a tier-3 restore."""
    template = small_state(9)
    state = advance(template, 4)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                          sg_size=4, resume=False)
    with CheckpointSession(spec, template) as sess:
        assert sess.snapshot(state, 4, wait=True)
        assert sess.persist() == 4
    path = os.path.join(str(tmp_path), "step-4-node-2.reft")
    import pickle
    with open(path, "rb") as f:
        pickle.load(f)                       # skip the head
        data_off = f.tell()
    blob = bytearray(open(path, "rb").read())
    blob[data_off + 100] ^= 0xFF             # corrupt node 2's OWN region
    open(path, "wb").write(bytes(blob))
    st = LoadStats()
    tree, step, _ = restore_from_checkpoint(str(tmp_path), 4, template,
                                            stats=st)
    assert step == 4 and trees_equal(tree, state)
    assert st.decoded_bytes > 0              # node 2 rebuilt from parity
    # PARTIAL plans verify via per-stripe digests now: corruption in a
    # stripe the plan does not read is neither paid for nor decoded
    # around (the restored bytes never touch it), while corruption
    # INSIDE a read stripe is caught and decoded around
    spec_f = make_flat_spec(template)
    need = need_for_leaves(spec_f, ("w",))
    st2 = LoadStats()
    tree2, _, _ = restore_from_checkpoint(str(tmp_path), 4, template,
                                          need=need, stats=st2)
    assert trees_equal(tree2["params"]["w"], state["params"]["w"])
    assert st2.probe_segments > 0            # stripe table used
    assert st2.decoded_bytes == 0            # byte 100 is outside the plan
    # now corrupt a byte the plan DOES read (any member of its footprint);
    # heal node 2 first so exactly ONE member is corrupt (RAIM5 budget)
    blob = bytearray(open(path, "rb").read())
    blob[data_off + 100] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    from repro.core.loader import build_plan, plan_local_ranges
    plan = build_plan(4, spec_f.total_bytes, need=need)
    nd, ranges = sorted(plan_local_ranges(plan).items())[0]
    path_nd = os.path.join(str(tmp_path), f"step-4-node-{nd}.reft")
    with open(path_nd, "rb") as f:
        pickle.load(f)
        off_nd = f.tell()
    blob = bytearray(open(path_nd, "rb").read())
    blob[off_nd + ranges[0][0] + 8] ^= 0xFF
    open(path_nd, "wb").write(bytes(blob))
    st3 = LoadStats()
    tree3, _, _ = restore_from_checkpoint(str(tmp_path), 4, template,
                                          need=need, stats=st3)
    assert trees_equal(tree3["params"]["w"], state["params"]["w"])
    assert st3.decoded_bytes > 0             # member rebuilt from parity


# ------------------------------------------------ filename parsing (regex)
def test_latest_checkpoint_step_adversarial_filenames(tmp_path):
    """Anchored-regex parsing: names with extra dashes / junk can neither
    crash discovery (the old int(split("-")[1]) did) nor fabricate
    phantom families."""
    template = small_state(11)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                          sg_size=2, resume=False)
    with CheckpointSession(spec, template) as sess:
        assert sess.snapshot(advance(template, 1), 10, wait=True)
        assert sess.persist() == 10
    for junk in ("step-99-node-0-evil.reft", "step-x-node-0.reft",
                 "step-88-foo-node-1.reft", "step--3-node-0.reft"):
        open(os.path.join(str(tmp_path), junk), "wb").write(b"junk")
    fams = checkpoint_families(str(tmp_path))
    assert set(fams) == {10}
    assert latest_checkpoint_step(str(tmp_path)) == 10
    assert latest_checkpoint_step(str(tmp_path), 2) == 10
    # and a real torn family is still skipped for completeness
    open(os.path.join(str(tmp_path), "step-20-node-0.reft"), "wb") \
        .write(b"junk")
    assert latest_checkpoint_step(str(tmp_path), 2) == 10


# --------------------------------------------------- dist target -> ranges
def test_need_for_sharding_slices_leading_dim():
    state = {"w": np.zeros((8, 4), np.float32),
             "b": np.zeros((6,), np.float32)}
    spec = make_flat_spec(state)
    from jax.sharding import PartitionSpec as P
    shardings = {"w": P("data", None), "b": P()}
    mesh = fake_mesh(data=2, model=2)
    w_nbytes = 8 * 4 * 4
    need0 = need_for_sharding(spec, shardings, mesh, {"data": 0})
    need1 = need_for_sharding(spec, shardings, mesh, {"data": 1})
    w_off = next(l.offset for l in spec.leaves if "w" in l.path)
    b_off = next(l.offset for l in spec.leaves if "b" in l.path)
    assert (w_off, w_off + w_nbytes // 2) in need0
    assert (w_off + w_nbytes // 2, w_off + w_nbytes) in need1
    # unsharded leaf -> whole leaf for every rank
    for need in (need0, need1):
        assert (b_off, b_off + 24) in need
    # non-dividing dim is dropped by adapt_spec -> whole leaf
    shardings = {"w": P(None, "model"), "b": P("model",)}   # 6 % 2 == 0
    need = need_for_sharding(spec, shardings, mesh, {"model": 1})
    assert (b_off + 12, b_off + 24) in need
