"""CheckFreq / TorchSnapshot baseline checkpointers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import (CheckFreqCheckpointer, TorchSnapshotCheckpointer,
                        load_checkpoint)


def state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (128, 64)),
            "mu": jnp.zeros((333,)), "step": jnp.int32(5)}


def eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_checkfreq_roundtrip(tmp_path):
    s = state()
    ck = CheckFreqCheckpointer(str(tmp_path), s)
    t = ck.save_sync(s, 7)
    assert t.total > 0 and t.d2h >= 0
    assert eq(load_checkpoint(str(tmp_path), 7, s), s)


@pytest.mark.parametrize("n_ranks", [2, 3, 8])
def test_torchsnapshot_sharded_roundtrip(tmp_path, n_ranks):
    s = state(1)
    ck = TorchSnapshotCheckpointer(str(tmp_path), s, n_ranks=n_ranks)
    ck.save_sync(s, 3)
    import os
    files = [f for f in os.listdir(tmp_path) if f.startswith("ckpt-3-")]
    assert len(files) == n_ranks          # parallel per-rank shards
    assert eq(load_checkpoint(str(tmp_path), 3, s), s)


def test_async_inflight_refusal(tmp_path):
    s = {"w": jnp.zeros((1 << 14,))}
    ck = CheckFreqCheckpointer(str(tmp_path), s)
    assert ck.save_async(s, 1)
    ck.wait()
    assert ck.last_step == 1


def test_shards_are_smaller_than_full(tmp_path):
    import os
    s = state(2)
    d1, d2 = tmp_path / "full", tmp_path / "shard"
    CheckFreqCheckpointer(str(d1), s).save_sync(s, 1)
    TorchSnapshotCheckpointer(str(d2), s, n_ranks=4).save_sync(s, 1)
    full = max(os.path.getsize(d1 / f) for f in os.listdir(d1))
    shard = max(os.path.getsize(d2 / f) for f in os.listdir(d2))
    assert shard < full / 2               # ~1/4 + header
