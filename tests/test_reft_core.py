"""REFT core: snapshot engine + SMP double-buffering + 3-tier recovery
(single-host process tree; real SMP processes)."""
import dataclasses
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import NodeState, ReftConfig, ReftGroup
from repro.core.recovery import restore_state
from repro.core.smp import ReadOnlyNode
from repro.core.snapshot import SnapshotEngine


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 32)),
                   "b": jnp.ones((17,), jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((64, 32)), "step": jnp.int32(0)},
        "rng": jax.random.PRNGKey(seed + 1),
    }


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture
def group():
    state = small_state()
    cfg = ReftConfig(bucket_bytes=256, stage_slots=4,
                     ckpt_dir=tempfile.mkdtemp(),
                     checkpoint_every_snapshots=10 ** 6)
    g = ReftGroup(4, state, cfg)
    yield g, state
    g.close()


def test_snapshot_and_inmemory_restore(group):
    g, state = group
    g.snapshot(state, 1, extra_meta={"k": 1})
    st2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.uint32 else x,
                       state)
    g.snapshot(st2, 2, extra_meta={"k": 2})
    g.inject_software_failure(0)
    rec, step, extra, tier = g.recover()
    assert tier == "in-memory" and step == 2 and extra == {"k": 2}
    assert trees_equal(rec, st2)


def test_raim5_tier_single_node_loss(group):
    g, state = group
    g.snapshot(state, 1)
    g.inject_node_failure(3)
    rec, step, extra, tier = g.recover()
    assert tier == "raim5" and step == 1
    assert trees_equal(rec, state)


def test_checkpoint_tier_double_loss(group):
    g, state = group
    g.snapshot(state, 1)
    g.checkpoint()
    g.inject_node_failure(0)
    g.inject_node_failure(2)
    rec, step, extra, tier = g.recover()
    assert tier == "checkpoint" and step == 1
    assert trees_equal(rec, state)


def test_dirty_snapshot_never_visible():
    """A snapshot without `end` must leave the previous clean intact
    (the dirty/clean double-buffer of §4.2)."""
    state = small_state()
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(bucket_bytes=128, stage_slots=2))
    try:
        eng.snapshot_sync(state, 1, {"v": 1})
        # partial write: begin + some buckets, no end
        from repro.core.treebytes import leaf_arrays
        eng.smp.begin(2)
        eng.smp.send_bucket(0, 0, np.zeros(64, np.uint8))
        view = ReadOnlyNode(eng.run, 0, 1, eng.spec.total_bytes)
        steps = view.clean_steps()
        assert 1 in steps and 2 not in steps
        assert view.latest_clean() == 1
        view.close()
        rec, step, extra = restore_state(eng.run, 1, eng.spec.total_bytes,
                                         state, [0])
        assert step == 1
        assert trees_equal(rec, state)
    finally:
        eng.close()


def test_multi_version_history():
    """Three buffers -> the two most recent clean steps stay addressable."""
    state = small_state()
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=4096))
    try:
        for s in (1, 2, 3, 4):
            eng.snapshot_sync(jax.tree.map(
                lambda x: x + s if x.dtype != jnp.uint32 else x, state), s)
        view = ReadOnlyNode(eng.run, 0, 1, eng.spec.total_bytes)
        steps = sorted(view.clean_steps())
        view.close()
        assert 4 in steps and 3 in steps and 1 not in steps
    finally:
        eng.close()


def test_snapshot_async_overlaps_and_self_limits():
    state = {"w": jnp.zeros((1 << 16,), jnp.float32)}
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=1 << 12))
    try:
        assert eng.snapshot_async(state, 1)
        # second call while in flight is refused, not queued (Figure 4)
        started = eng.snapshot_async(state, 2)
        eng.wait()
        assert eng.last_clean_step in (1, 2)
        if not started:
            assert eng.last_clean_step == 1
    finally:
        eng.close()


def test_heal_restores_full_protection(group):
    g, state = group
    g.snapshot(state, 1)
    g.inject_node_failure(1)
    rec, step, extra, tier = g.recover()
    assert tier == "raim5"
    g.heal(1)
    assert g.states[1] == NodeState.HEALTHY
    g.snapshot(state, 2)
    g.inject_node_failure(2)           # a *different* node can now fail
    rec, step, extra, tier = g.recover()
    assert tier == "raim5" and step == 2
    assert trees_equal(rec, state)
