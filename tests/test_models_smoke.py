"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED variant runs one forward + one train step on CPU with shape checks
and no NaNs; decoders additionally verify step-by-step decode matches the
full forward bit-for-float."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import InputShape
from repro.data.pipeline import make_batch
from repro.models import model as M
from repro.train.steps import init_train_state, make_train_step

SHAPE = InputShape("smoke", 32, 2, "train")


def _batch(cfg):
    return make_batch(cfg, SHAPE, seed=1)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loss, out = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, _batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    assert not any(bool(jnp.any(jnp.isnan(x)))
                   for x in jax.tree.leaves(params))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    state = init_train_state(cfg, 0).tree()
    step = jax.jit(make_train_step(cfg))
    b = _batch(cfg)
    state, m1 = step(state, b)
    state, m2 = step(state, b)
    assert int(state["step"]) == 2
    assert jnp.isfinite(m1["loss"]) and jnp.isfinite(m2["loss"])
    # same batch twice -> optimizer should reduce loss
    assert float(m2["loss"]) < float(m1["loss"]), arch
    for leaf in jax.tree.leaves(state["params"]):
        assert not bool(jnp.any(jnp.isnan(leaf)))


DECODE_ARCHS = [a for a in ASSIGNED_ARCHS
                if get_config(a).supports_decode
                and get_config(a).family != "vlm"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, T), 0,
                              cfg.vocab_size)
    lg_full, _ = M.logits_fn(cfg, params, {"tokens": toks, "labels": toks})
    cache = M.init_cache(cfg, 2, 16)
    dec = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))
    for t in range(T):
        lg, cache = dec(params, cache, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_full),
                               atol=2e-4, rtol=2e-3)
    assert int(cache["index"]) == T


def test_vlm_prefill_and_decode():
    cfg = get_config("phi-3-vision-4.2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 8
    key = jax.random.PRNGKey(3)
    batch = {
        "tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
        "patches": jax.random.normal(key, (B, cfg.num_patches, cfg.d_model),
                                     jnp.float32),
        "labels": jax.random.randint(key, (B, T + cfg.num_patches), 0,
                                     cfg.vocab_size),
    }
    loss, _ = M.forward(cfg, params, batch)
    assert jnp.isfinite(loss)
    logits, caches = M.logits_fn(cfg, params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    # patch positions must be masked out of the loss
    batch2 = dict(batch)
    batch2["labels"] = batch["labels"].at[:, :cfg.num_patches].set(0)
    loss2, _ = M.forward(cfg, params, batch2)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-6)


def test_encoder_is_bidirectional():
    cfg = get_config("hubert-xlarge").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    # flipping a LATE frame must change EARLY logits (no causal mask)
    x1, _, _ = M.embed_batch(cfg, params, b)
    frames2 = b["frames"].at[:, -1, :].add(10.0)
    l1, _ = M.logits_fn(cfg, params, b)
    positions = jnp.arange(b["frames"].shape[1])
    h1, _, _ = M._scan_blocks(cfg, params,
                              M.embed_batch(cfg, params, b)[0], positions)
    b2 = dict(b)
    b2["frames"] = frames2
    h2, _, _ = M._scan_blocks(cfg, params,
                              M.embed_batch(cfg, params, b2)[0], positions)
    assert float(jnp.max(jnp.abs(h1[:, 0] - h2[:, 0]))) > 1e-6


def test_unroll_matches_scan():
    for arch in ["qwen3-8b", "jamba-v0.1-52b", "gemma3-4b"]:
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        b = _batch(cfg)
        l1, _ = M.forward(cfg, params, b, unroll=False)
        l2, _ = M.forward(cfg, params, b, unroll=True)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_window_kv_cache_ring_buffer():
    """Ring cache (window-sized) must reproduce full-cache decode."""
    base = get_config("starcoder2-3b").reduced()   # sliding_window=64
    cfg = dataclasses.replace(base, sliding_window=8)
    cfg_ring = dataclasses.replace(cfg, window_kv_cache=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    T = 24
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, T), 0,
                              cfg.vocab_size)
    c_full = M.init_cache(cfg, 1, 32)
    c_ring = M.init_cache(cfg_ring, 1, 32)
    assert (c_ring["entries"]["pos0"]["k"].shape[2]
            < c_full["entries"]["pos0"]["k"].shape[2])
    for t in range(T):
        lf, c_full = M.decode_step(cfg, params, c_full, toks[:, t:t + 1])
        lr, c_ring = M.decode_step(cfg_ring, params, c_ring,
                                   toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                                   atol=1e-4, rtol=1e-3)
