"""RAIM5 layout invariants + encode/decode properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import raim5


@pytest.mark.parametrize("n", [2, 3, 4, 6, 8])
def test_layout_partition(n):
    """Every (stripe, index) data block is stored on exactly one node, never
    on its own stripe's parity node, and each node holds n-1 blocks."""
    seen = {}
    for node in range(n):
        refs = raim5.data_blocks_of_node(node, n)
        assert len(refs) == n - 1
        for r in refs:
            assert r.stripe != node          # parity node holds no data
            assert (r.stripe, r.index) not in seen
            seen[(r.stripe, r.index)] = node
    assert len(seen) == n * (n - 1)
    for s in range(n):
        for j in range(n - 1):
            assert raim5.node_of_block(s, j, n) == seen[(s, j)]


@given(n=st.integers(2, 6), total=st.integers(1, 5000),
       seed=st.integers(0, 2 ** 31))
def test_single_node_decode_bitexact(n, total, seed):
    rng = np.random.default_rng(seed)
    state = rng.integers(0, 256, size=total, dtype=np.uint8)
    bs = raim5.block_size(total, n)

    # per-node storage: data blocks + parity (as the SMP would hold them)
    def block_bytes(ref):
        lo, hi = ref.byte_range(bs, n)
        blk = np.zeros(bs, np.uint8)
        a, b = min(lo, total), min(hi, total)
        blk[:b - a] = state[a:b]
        return blk

    store = {node: {(r.stripe, r.index): block_bytes(r)
                    for r in raim5.data_blocks_of_node(node, n)}
             for node in range(n)}
    parity = {node: raim5.encode_parity(node, n, state)
              for node in range(n)}

    failed = int(rng.integers(0, n))
    rec = raim5.decode_node(
        failed, n, total,
        read_block=lambda nd, s, j: store[nd][(s, j)],
        read_parity=lambda s: parity[s])
    # every lost block must decode bit-exactly
    for r in raim5.data_blocks_of_node(failed, n):
        np.testing.assert_array_equal(rec[(r.stripe, r.index)],
                                      block_bytes(r))
    # and full reassembly must reproduce the state
    full = raim5.reassemble(
        n, total,
        read_block=lambda nd, s, j: store[nd][(s, j)],
        recovered=rec)
    np.testing.assert_array_equal(full, state)


@given(blocks=st.integers(2, 8), nbytes=st.integers(1, 1000),
       seed=st.integers(0, 2 ** 31))
def test_xor_blocks_properties(blocks, nbytes, seed):
    rng = np.random.default_rng(seed)
    data = [rng.integers(0, 256, nbytes, dtype=np.uint8)
            for _ in range(blocks)]
    p = raim5.xor_blocks(data)
    # xor of parity with all-but-one recovers the one (associativity)
    for i in range(blocks):
        others = [d for j, d in enumerate(data) if j != i]
        np.testing.assert_array_equal(raim5.xor_blocks(others + [p]), data[i])
    # self-inverse
    np.testing.assert_array_equal(raim5.xor_blocks([p, p]),
                                  np.zeros(nbytes, np.uint8))


def test_snapshot_ranges_double_traffic():
    """Snapshot traffic per node is ~2W/n (own shard + parity stripe)."""
    n, total = 4, 10 ** 6
    for node in range(n):
        ranges = raim5.snapshot_ranges(node, n, total)
        vol = sum(hi - lo for lo, hi in ranges)
        assert abs(vol - 2 * total / n) < 2 * total / n * 0.05
