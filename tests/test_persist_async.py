"""Async overlapped REFT-Ckpt persistence: SMP buffer pinning, the
seq-tagged persist protocol (no desync after a timed-out wait), streamed
tmp-safe shard writes, per-stripe digest verification, and the
facade/session ticket surface."""
import os
import pickle
import tempfile
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ReftConfig, ReftGroup
from repro.core.loader import (
    FileSource, LoadStats, ShmSource, build_plan, probe_crc, stripe_table,
)
from repro.core.recovery import (
    attach_survivors, restore_from_checkpoint,
)
from repro.core.smp import PERSIST_CHUNK_BYTES, ReadOnlyNode, _stream_write, \
    _tmp_name
from repro.core.snapshot import SnapshotEngine


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 32)),
                   "b": jnp.ones((17,), jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((64, 32))},
    }


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def bump(state, k):
    return jax.tree.map(lambda x: x + k, state)


# ------------------------------------------------------------- unit level
def test_stream_write_is_chunked_and_byte_identical():
    """The RSS-doubling `.tobytes()` is gone: the shard streams to disk
    in fixed chunks, never materializing a second full copy."""
    class Rec:
        def __init__(self):
            self.sizes = []
            self.blob = b""

        def write(self, b):
            mv = memoryview(b)
            self.sizes.append(mv.nbytes)
            self.blob += mv.tobytes()

    arr = np.arange(1000, dtype=np.uint8)
    f = Rec()
    n = _stream_write(f, arr, chunk_bytes=64)
    assert n == 1000
    assert max(f.sizes) <= 64 and len(f.sizes) == 16
    assert f.blob == arr.tobytes()
    assert PERSIST_CHUNK_BYTES >= 1 << 20       # sane default granularity


def test_tmp_names_are_unique_per_seq():
    """Two persists targeting the same path never collide on one tmp."""
    a = _tmp_name("/x/step-1-node-0.reft", 1)
    b = _tmp_name("/x/step-1-node-0.reft", 2)
    assert a != b and a.endswith(".tmp") and str(os.getpid()) in a


# ------------------------------------------------------ protocol + pinning
def test_persist_wait_timeout_does_not_desync_protocol(tmp_path):
    """Regression: a timed-out persist_wait used to leave the late
    ("persisted", ...) reply in the pipe, where the next recv expecting
    ("clean", step) or ("pong", ...) consumed it.  With seq tagging the
    stale reply is discarded and every later exchange stays aligned."""
    state = small_state()
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=4096))
    try:
        eng.snapshot_sync(state, 1)
        path = str(tmp_path / "slow.reft")
        seq = eng.smp.persist_send(path, delay_s=0.8)
        with pytest.raises(TimeoutError):
            eng.smp.persist_wait(seq, timeout=0.05)
        # the very exchanges the stale reply used to corrupt:
        assert eng.snapshot_sync(bump(state, 1), 2) == 2
        assert eng.smp.ping() > 0
        assert eng.snapshot_sync(bump(state, 2), 3) == 3
        # a second persist completes with ITS OWN reply, not the stale one
        path2 = str(tmp_path / "fast.reft")
        assert eng.smp.persist(path2, step=3) == path2
        deadline = time.monotonic() + 10
        while not os.path.exists(path):          # abandoned one still lands
            assert time.monotonic() < deadline
            time.sleep(0.02)
        assert not eng.degraded
    finally:
        eng.close()


def test_smp_drains_snapshots_during_persist_and_pin_is_immutable(tmp_path):
    """Tentpole semantics: with the persist on the SMP's background
    thread, bucket/end traffic keeps flowing during the write, and the
    pinned buffer is never selected as dirty — the bytes that land on
    disk are exactly the bytes of the pinned step, byte for byte, even
    though later snapshots rotated every other buffer."""
    state = small_state(1)
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=4096))
    try:
        eng.snapshot_sync(state, 1)
        total = eng.spec.total_bytes
        view = ReadOnlyNode(eng.run, 0, 1, total)
        oracle = view.read_range(1, 0, eng.layout.buf_bytes).tobytes()
        view.close()

        path = str(tmp_path / "pinned.reft")
        seq = eng.smp.persist_send(path, step=1, delay_s=1.0)
        t0 = time.perf_counter()
        assert eng.snapshot_sync(bump(state, 1), 2) == 2
        assert eng.snapshot_sync(bump(state, 2), 3) == 3
        snap_secs = time.perf_counter() - t0
        # both snapshots completed while the persist was still sleeping
        assert snap_secs < 0.9, snap_secs
        assert eng.smp.persist_poll(seq) is None      # genuinely in flight
        assert eng.smp.persist_wait(seq, timeout=30) == path

        with open(path, "rb") as f:
            head = pickle.load(f)
            payload = f.read()
        assert head["step"] == 1
        assert payload == oracle, "pinned buffer was re-dirtied mid-write"
    finally:
        eng.close()


def test_two_queued_persists_of_one_buffer_hold_the_pin(tmp_path):
    """The pin is a REFCOUNT: when two persists select the same buffer,
    the first finishing must not release it under the still-queued
    second — both files must carry the pinned step's exact bytes even
    while later snapshots rotate every other buffer."""
    state = small_state(11)
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=4096))
    try:
        eng.snapshot_sync(state, 1)
        view = ReadOnlyNode(eng.run, 0, 1, eng.spec.total_bytes)
        oracle = view.read_range(1, 0, eng.layout.buf_bytes).tobytes()
        view.close()
        p1 = str(tmp_path / "a.reft")
        p2 = str(tmp_path / "b.reft")
        s1 = eng.smp.persist_send(p1, step=1, delay_s=0.4)
        s2 = eng.smp.persist_send(p2, step=1, delay_s=0.4)
        for k in (2, 3, 4):                    # rotate buffers hard
            eng.snapshot_sync(bump(state, k), k)
        assert eng.smp.persist_wait(s1, timeout=30) == p1
        for k in (5, 6):                       # job 2 still holds the pin
            eng.snapshot_sync(bump(state, k), k)
        assert eng.smp.persist_wait(s2, timeout=30) == p2
        for p in (p1, p2):
            with open(p, "rb") as f:
                head = pickle.load(f)
                payload = f.read()
            assert head["step"] == 1 and payload == oracle, p
    finally:
        eng.close()


def test_persist_failure_unlinks_tmp_and_does_not_wedge(tmp_path):
    """An injected replace failure (the target path is a directory, the
    same failure class as ENOSPC after the write) must leave NO stray
    .tmp behind, surface as an error, and leave the engine fully
    functional (persist errors demote the round, not the engine)."""
    state = small_state(2)
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=4096))
    try:
        eng.snapshot_sync(state, 1)
        bad = str(tmp_path / "step-1-node-0.reft")
        os.makedirs(bad)                      # os.replace(file, dir) fails
        with pytest.raises(RuntimeError, match="persist failed"):
            eng.persist(bad, step=1)
        leftovers = [f for f in os.listdir(tmp_path) if ".tmp" in f]
        assert leftovers == [], leftovers
        assert eng.stats["persist_errors"] == 1
        assert not eng.degraded
        # engine keeps snapshotting AND persisting after the failure
        assert eng.snapshot_sync(bump(state, 1), 2) == 2
        good = str(tmp_path / "ok.reft")
        assert eng.persist(good, step=2) == good
    finally:
        eng.close()


def test_engine_ticket_stats(tmp_path):
    state = small_state(3)
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(bucket_bytes=4096, persist_delay_s=0.3))
    try:
        eng.snapshot_sync(state, 1)
        seq = eng.persist_async(str(tmp_path / "t.reft"))
        assert eng.stats["persist_inflight"] == 1
        done = []
        deadline = time.monotonic() + 10
        while not done and time.monotonic() < deadline:
            done = eng.poll_persists()
            time.sleep(0.02)
        assert done and done[0]["seq"] == seq and done[0]["error"] is None
        assert done[0]["step"] == 1
        assert eng.stats["persists"] == 1
        assert eng.stats["persist_inflight"] == 0
        # collected by polling, not blocking: the whole lifetime overlapped
        assert eng.stats["persist_overlap_seconds"] > 0.2
    finally:
        eng.close()


# ------------------------------------------------------------ group level
def test_group_async_checkpoint_round_and_byte_identity(tmp_path):
    """checkpoint_async returns immediately; drain_persists lands an
    SG-consistent family that restores byte-identically — the serial
    oracle being the state the snapshot captured."""
    state = small_state(4)
    g = ReftGroup(2, state, ReftConfig(bucket_bytes=2048, stage_slots=4,
                                       ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10 ** 6,
                                       persist_delay_s=0.4))
    try:
        g.snapshot(state, 1)
        t0 = time.perf_counter()
        step = g.checkpoint_async()
        assert step == 1
        assert time.perf_counter() - t0 < 0.3      # no disk I/O inline
        assert g.persist_inflight() == 1
        # snapshots keep flowing while both SMPs write
        st2 = bump(state, 1)
        g.snapshot(st2, 2)
        rounds = g.drain_persists(30)
        assert [r["step"] for r in rounds] == [1] and rounds[0]["ok"]
        assert g.persist_inflight() == 0
        rec, got, _ = restore_from_checkpoint(str(tmp_path), 2, state)
        assert got == 1 and trees_equal(rec, state)
    finally:
        g.close()


# ----------------------------------------------------------- facade level
def test_facade_after_step_nonblocking_and_drain(tmp_path):
    """Acceptance: after_step with a persist in flight returns without
    blocking on disk I/O (bounded well under the simulated write time),
    completion is collected by polling, and drain() joins the rest."""
    from repro.api import CheckpointSession, CheckpointSpec

    template = small_state(5)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path), sg_size=2,
                          snapshot_every_steps=1, checkpoint_every_steps=1,
                          resume=False,
                          options={"persist_delay_s": 0.6})
    with CheckpointSession(spec, template) as sess:
        state = bump(template, 1)
        assert sess.snapshot(state, 1, wait=True)
        t0 = time.perf_counter()
        did = sess.after_step(bump(state, 1), 2)
        elapsed = time.perf_counter() - t0
        # newest SG-clean step: 1, or already 2 when the tiny async
        # snapshot outran the fire — either way nothing touched disk
        fired = did["persist"]
        assert fired in (1, 2)
        assert elapsed < 0.4, f"after_step blocked on disk ({elapsed:.2f}s)"
        assert sess.stats()["persist_inflight"] == 1
        sess.drain()
        assert sess.stats()["persist_inflight"] == 0
        ev = [e for e in sess.events if e.kind == "persist"]
        assert ev and ev[-1].step == fired
        assert sess.checkpointer.manager.latest() == fired


def test_facade_persist_error_event_without_degrading(tmp_path):
    from repro.api import CheckpointSpec

    template = small_state(6)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path), sg_size=2,
                          resume=False)
    with spec.build(template) as ck:
        ck.snapshot(template, 1, wait=True)
        os.makedirs(os.path.join(str(tmp_path), "step-1-node-0.reft"))
        assert ck.persist(wait=False) == 1
        deadline = time.monotonic() + 15
        while not any(e.kind == "persist-error" for e in ck.events):
            assert time.monotonic() < deadline
            ck.poll_persists()
            time.sleep(0.02)
        assert ck.health()["healthy"]              # engines unharmed
        assert ck.snapshot(bump(template, 1), 2, wait=True)


def test_elastic_resume_from_async_persisted_family(tmp_path):
    """CI-shaped end to end: a 4-member session persists asynchronously
    and exits (drain-on-close); a 2-member session resumes byte-
    identically from the family (reshard-on-restore)."""
    from repro.api import CheckpointSession, CheckpointSpec

    template = small_state(7)
    state = bump(template, 3)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path), sg_size=4,
                          resume=False, options={"persist_delay_s": 0.2})
    with CheckpointSession(spec, template) as sess:
        assert sess.snapshot(state, 2, wait=True)
        assert sess.persist(wait=False) == 2       # async; close() drains

    spec2 = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                           sg_size=2, resume=True)
    with CheckpointSession(spec2, template) as sess:
        assert sess.restored is not None
        assert sess.restored.step == 2
        assert sess.restored.load.resharded
        assert trees_equal(sess.restored.state, state)


# ------------------------------------------------------ per-stripe digests
def test_partial_plan_verifies_only_read_stripes(tmp_path):
    """Acceptance: a PARTIAL restore plan verifies via per-stripe digests
    — corruption in a stripe the plan does not read goes unnoticed (and
    unpaid-for), corruption in a read stripe demotes the member and the
    restore self-heals from parity, byte-identically."""
    state = small_state(8)
    g = ReftGroup(4, state, ReftConfig(bucket_bytes=2048, stage_slots=4,
                                       ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10 ** 6))
    try:
        g.snapshot(state, 1)
        total = g.total_bytes
        assert g.checkpoint() == 1
    finally:
        g.close()

    # the family's per-member layout: block (stripe 0, idx 0) is global
    # bytes [0, bs) and lives on node 1 as its local block 0
    src = FileSource({n: os.path.join(str(tmp_path),
                                      f"step-1-node-{n}.reft")
                      for n in range(4)})
    bs = src.layout.bs
    own = src.layout.own_bytes
    data_off = {n: src._data_off[n] for n in src.nodes}
    assert stripe_table(src.meta(1)) is not None
    src.close()

    need = [(16, min(1000, total))]

    def flip(node, local_off):
        p = os.path.join(str(tmp_path), f"step-1-node-{node}.reft")
        with open(p, "r+b") as f:
            f.seek(data_off[node] + local_off)
            b = f.read(1)
            f.seek(data_off[node] + local_off)
            f.write(bytes([b[0] ^ 0xFF]))

    # corruption OUTSIDE the plan (node 1's second block): partial restore
    # neither reads nor pays for it
    flip(1, bs + 7)
    st = LoadStats()
    rec, got, _ = restore_from_checkpoint(str(tmp_path), 4, state,
                                          need=need, stats=st)
    assert got == 1
    assert st.probe_segments > 0, "stripe digests were not used"
    # whole-region probing of the one read member alone would cost `own`
    assert st.bytes_read < own, (st.bytes_read, own)
    full = np.concatenate([np.asarray(x).reshape(-1).view(np.uint8)
                           for x in jax.tree.leaves(state)])
    rec_flat = np.concatenate([np.asarray(x).reshape(-1).view(np.uint8)
                               for x in jax.tree.leaves(rec)])
    lo, hi = need[0]
    assert np.array_equal(rec_flat[lo:hi], full[lo:hi])

    # corruption INSIDE the read stripe: the digest catches it, the
    # member demotes, and parity decode reproduces the exact bytes
    flip(1, 32)
    st2 = LoadStats()
    rec2, got2, _ = restore_from_checkpoint(str(tmp_path), 4, state,
                                            need=need, stats=st2)
    assert got2 == 1
    assert st2.decoded_bytes > 0, "corrupt stripe did not demote-decode"
    rec2_flat = np.concatenate([np.asarray(x).reshape(-1).view(np.uint8)
                                for x in jax.tree.leaves(rec2)])
    assert np.array_equal(rec2_flat[lo:hi], full[lo:hi])


def test_stripe_tables_identical_host_and_device_encode():
    """The device encode path's per-bucket digests fold into the SAME
    per-stripe table the SMP computes on the host path."""
    state = small_state(9)
    tables = {}
    for mode in ("off", "on"):
        eng = SnapshotEngine(0, 2, state,
                             ReftConfig(bucket_bytes=4096,
                                        device_encode=mode))
        try:
            eng.snapshot_sync(state, 1)
            view = ReadOnlyNode(eng.run, 0, 2, eng.spec.total_bytes)
            try:
                meta = pickle.loads(view.meta(1))
            finally:
                view.close()
            tables[mode] = (meta["crc_own"], stripe_table(meta))
        finally:
            eng.close()
    assert tables["off"][1] is not None
    assert tables["off"] == tables["on"]


def test_shm_partial_probe_uses_stripes(tmp_path):
    """Same acceptance over live SMP segments (tier 1/2): the probe of a
    partial plan reads only the touched stripe segments."""
    state = small_state(10)
    g = ReftGroup(4, state, ReftConfig(bucket_bytes=2048, stage_slots=4,
                                       ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10 ** 6))
    try:
        g.snapshot(state, 1)
        total = g.total_bytes
        views = attach_survivors(g.run, [0, 1, 2, 3], 4, total)
        try:
            plan = build_plan(4, total, need=[(0, 512)])
            st = LoadStats()
            bad = probe_crc(plan, ShmSource(views, 1), stats=st)
            assert bad == []
            assert st.probe_segments == 1
            assert st.bytes_read <= g.engines[0].layout.bs
        finally:
            for v in views.values():
                v.close()
    finally:
        g.close()
