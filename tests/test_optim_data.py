"""Optimizer math + data-pipeline determinism / restartability."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticDataset, input_specs, make_batch
from repro.optim.adam import AdamConfig, adam_init, adam_update


def test_adam_first_step_is_lr_sized():
    """After bias correction, |delta| ~= lr for any gradient scale."""
    cfg = AdamConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 123.0)}
    st = adam_init(p)
    p2, st2, _ = adam_update(cfg, g, st, p)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               -cfg.lr * np.ones(4), rtol=1e-4)
    assert int(st2["step"]) == 1


def test_adam_grad_clip():
    cfg = AdamConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.asarray([3.0, 4.0, 0.0])}       # norm 5 -> scaled by 1/5
    _, _, gnorm = adam_update(cfg, g, adam_init(p), p)
    np.testing.assert_allclose(float(gnorm), 5.0, rtol=1e-5)


def test_adam_moments_fp32_regardless_of_param_dtype():
    p = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st = adam_init(p)
    assert st["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, st2, _ = adam_update(AdamConfig(), g, st, p)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2["nu"]["w"].dtype == jnp.float32


def test_dataset_deterministic_and_restartable():
    cfg = get_config("qwen3-8b").reduced()
    shape = InputShape("t", 16, 2, "train")
    ds1 = SyntheticDataset(cfg, shape, seed=9)
    b1 = [next(ds1) for _ in range(3)]
    mid_state = ds1.state()
    b_after = next(ds1)

    ds2 = SyntheticDataset(cfg, shape, seed=0)
    ds2.restore(mid_state)
    b_resumed = next(ds2)
    np.testing.assert_array_equal(np.asarray(b_after["tokens"]),
                                  np.asarray(b_resumed["tokens"]))
    # and full determinism from scratch
    ds3 = SyntheticDataset(cfg, shape, seed=9)
    np.testing.assert_array_equal(np.asarray(b1[0]["tokens"]),
                                  np.asarray(next(ds3)["tokens"]))


@pytest.mark.parametrize("arch", ["qwen3-8b", "hubert-xlarge",
                                  "phi-3-vision-4.2b", "mamba2-130m"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_match_make_batch(arch, shape):
    """Dry-run specs and concrete batches agree on shapes/dtypes."""
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape]
    from repro.configs import shape_supported
    if not shape_supported(cfg, sh)[0]:
        pytest.skip("unsupported pair")
    specs = input_specs(cfg, sh)
    small_seq = cfg.num_patches + 32 if sh.kind != "decode" else sh.seq_len
    small = InputShape(sh.name, small_seq, 2, sh.kind)
    batch = make_batch(cfg, small)
    assert set(specs) == set(batch)
    for k in specs:
        assert specs[k].dtype == batch[k].dtype
        assert len(specs[k].shape) == batch[k].ndim


def test_vlm_spec_accounts_for_patches():
    cfg = get_config("phi-3-vision-4.2b")
    sh = INPUT_SHAPES["train_4k"]
    specs = input_specs(cfg, sh)
    assert specs["patches"].shape == (256, cfg.num_patches, cfg.d_model)
    assert specs["tokens"].shape == (256, 4096 - cfg.num_patches)
    assert specs["labels"].shape == (256, 4096)
