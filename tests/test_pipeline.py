"""HASC saving pipeline: schedule ordering, interference, backpressure,
wait-timeout semantics, leaf-cache eviction, per-level accounting,
device-side encode equivalence, multi-flight overlap, saving-path
affinity."""
import os
import tempfile
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.pipeline import (
    LeafReader, StepBoundaryGate, build_schedule, leaf_budget,
    resolve_affinity, step_boundary,
)
from repro.core.snapshot import ReftConfig, SnapshotEngine
from repro.core.treebytes import make_flat_spec


def opt_state(n=1 << 14, seed=0):
    """params + adam moments, moments deliberately NOT first in flatten
    order (dict order: mu/nu sort after params? flatten order is key-sorted
    -> 'mu' < 'nu' < 'params'; use explicit names to pin params first)."""
    k = jax.random.PRNGKey(seed)
    return {
        "a_params": {"w": jax.random.normal(k, (n,), jnp.float32),
                     "b": jnp.ones((257,), jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((n,), jnp.float32),
                "nu": jnp.zeros((n,), jnp.float32)},
        "rng": jax.random.PRNGKey(seed + 1),
    }


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------------- scheduling
def test_bucket_schedule_opt_first():
    state = opt_state()
    spec = make_flat_spec(state)
    own = [(0, 0, spec.total_bytes)]
    sched = build_schedule(spec, own, [], 4096, opt_first=True)
    # all bytes covered exactly once
    covered = sorted((t.lo, t.hi) for t in sched)
    assert covered[0][0] == 0 and covered[-1][1] == spec.total_bytes
    assert all(a2 == b1 for (_, b1), (a2, _) in zip(covered, covered[1:]))
    # optimizer-moment buckets drain first
    flags = [t.opt for t in sched]
    assert any(flags), "schedule found no optimizer leaves"
    assert not any(flags[flags.index(False):]), \
        "a non-opt bucket precedes an opt bucket"
    # and the opt buckets really point at moment leaves
    first = sched[0]
    assert "opt" in spec.leaves[first.leaf_lo].path.lower()


def test_bucket_schedule_unordered_matches_plan_order():
    state = opt_state()
    spec = make_flat_spec(state)
    own = [(0, 0, spec.total_bytes)]
    sched = build_schedule(spec, own, [], 4096, opt_first=False)
    los = [t.lo for t in sched]
    assert los == sorted(los)


def test_leaf_budget_counts_all_plan_bytes():
    state = opt_state()
    spec = make_flat_spec(state)
    budget = leaf_budget(spec, [(0, spec.total_bytes)])
    assert sum(budget.values()) == spec.total_bytes
    half = spec.total_bytes // 2
    budget2 = leaf_budget(spec, [(0, half)])
    assert sum(budget2.values()) == half


# --------------------------------------------------------------- reader
def test_leaf_reader_evicts_consumed_leaves():
    state = opt_state()
    spec = make_flat_spec(state)
    budget = leaf_budget(spec, [(0, spec.total_bytes)])
    r = LeafReader(spec, jax.tree_util.tree_leaves(state), budget)
    out = np.empty(4096, np.uint8)
    for lo in range(0, spec.total_bytes, 4096):
        hi = min(lo + 4096, spec.total_bytes)
        r.read(lo, hi, out[:hi - lo])
    assert r.cached_leaves() == 0, "host cache not evicted after consumption"


def test_leaf_reader_unbudgeted_keeps_cache():
    state = opt_state()
    spec = make_flat_spec(state)
    r = LeafReader(spec, jax.tree_util.tree_leaves(state))
    out = np.empty(spec.total_bytes, np.uint8)
    r.read(0, spec.total_bytes, out)
    assert r.cached_leaves() == len(spec.leaves)


# ------------------------------------------------------------ interference
@pytest.mark.parametrize("pipelined", [True, False])
def test_training_steps_proceed_while_snapshot_in_flight(pipelined):
    state = {"opt_mu": jnp.zeros((1 << 18,), jnp.float32),
             "w": jnp.ones((1 << 18,), jnp.float32)}
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(pipeline=pipelined, bucket_bytes=1 << 12,
                                    stage_slots=4))
    try:
        assert eng.snapshot_async(state, 1)
        steps_during_flight = 0
        deadline = time.monotonic() + 30
        while eng.in_flight() and time.monotonic() < deadline:
            # a "training step": touch the accelerator state, tick the gate
            _ = float(jnp.sum(state["w"][:16]))
            step_boundary()
            steps_during_flight += 1
        assert steps_during_flight > 0, \
            "no training step completed while the snapshot was in flight"
        assert eng.wait() == 1
        from repro.core.recovery import restore_state
        rec, step, _ = restore_state(eng.run, 1, eng.spec.total_bytes,
                                     state, [0])
        assert step == 1 and trees_equal(rec, state)
    finally:
        eng.close()


# ------------------------------------------------------------ backpressure
def test_backpressure_ring_full_stalls_without_data_loss():
    """stage ring of 1 slot + tiny buckets: L1 must stall on credits while
    the SMP drains; the snapshot still completes bit-identically."""
    state = opt_state(1 << 12)
    cfg = ReftConfig(bucket_bytes=512, stage_slots=1, scratch_buffers=2)
    eng = SnapshotEngine(0, 1, state, cfg)
    try:
        assert eng.snapshot_async(state, 7)
        assert eng.wait() == 7
        assert eng.stats["l1_stall_seconds"] >= 0.0
        assert eng.stats["bytes_sent"] >= eng.spec.total_bytes
        from repro.core.recovery import restore_state
        rec, step, _ = restore_state(eng.run, 1, eng.spec.total_bytes,
                                     state, [0])
        assert step == 7 and trees_equal(rec, state)
    finally:
        eng.close()


def test_sg4_pipelined_snapshot_raim5_roundtrip():
    """Full SG with parity stripes through the pipeline: single-node loss
    still decodes bit-identically (recovery contract unchanged)."""
    from repro.core import ReftGroup
    import tempfile
    state = opt_state(1 << 12)
    cfg = ReftConfig(bucket_bytes=512, stage_slots=4,
                     ckpt_dir=tempfile.mkdtemp(),
                     checkpoint_every_snapshots=10 ** 6)
    g = ReftGroup(4, state, cfg)
    try:
        g.snapshot(state, 3, extra_meta={"k": 3})
        g.inject_node_failure(2)
        rec, step, extra, tier = g.recover()
        assert tier == "raim5" and step == 3 and extra == {"k": 3}
        assert trees_equal(rec, state)
        lv = g.level_seconds()
        assert lv["l1"] > 0 and lv["l2"] > 0 and lv["l3"] > 0
    finally:
        g.close()


# ------------------------------------------------------- wait() semantics
@pytest.mark.parametrize("pipelined", [True, False])
def test_wait_timeout_keeps_flight_live(pipelined):
    """Satellite fix: a timed-out wait() must NOT drop the handle — a
    second snapshot can never overlap a live one."""
    state = {"opt_mu": jnp.zeros((1 << 19,), jnp.float32)}
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(pipeline=pipelined, bucket_bytes=1 << 11,
                                    stage_slots=2))
    try:
        assert eng.snapshot_async(state, 1)
        with pytest.raises(TimeoutError):
            eng.wait(timeout=0.001)
        # the flight is still owned: a second snapshot is refused, and a
        # patient wait() drains the ORIGINAL flight
        assert not eng.snapshot_async(state, 2)
        assert eng.wait() == 1
        assert eng.stats["snapshots"] == 1
    finally:
        eng.close()


def test_recovery_decodes_single_laggard_member():
    """A member whose async rounds lag (buffer rotation evicted the steps
    its peers still hold) is equivalent to one failed node at the newest
    step: recovery must RAIM5-decode its shard, not fall through to the
    (possibly empty) checkpoint tier."""
    from repro.core import ReftGroup
    import tempfile
    state = opt_state(1 << 12)
    cfg = ReftConfig(bucket_bytes=1024, stage_slots=4,
                     ckpt_dir=tempfile.mkdtemp(),
                     checkpoint_every_snapshots=10 ** 6)
    g = ReftGroup(4, state, cfg)
    try:
        g.snapshot(state, 2, extra_meta={"k": 2})       # all members
        # member 0 lags: only the others complete rounds 4, 6, 8, so their
        # 3-buffer rotation evicts step 2 — no step is clean on ALL four
        for s in (4, 6, 8):
            st = jax.tree.map(
                lambda x, s=s: x + s if x.dtype != jnp.uint32 else x, state)
            for e in g.engines[1:]:
                assert e.snapshot_async(st, s, {"k": s})
            for e in g.engines[1:]:
                e.wait()
        last = jax.tree.map(lambda x: x + 8 if x.dtype != jnp.uint32 else x,
                            state)
        rec, step, extra, tier = g.recover()
        assert step == 8 and tier == "raim5" and extra == {"k": 8}
        assert trees_equal(rec, last)
    finally:
        g.close()


def test_single_node_corrupt_newest_falls_back_to_older_step():
    """n==1 with a CRC-corrupt newest snapshot must fall back to the older
    clean step (never pick a step with zero usable sources), and raise
    RecoveryError — not crash — when every step is corrupt."""
    from repro.core.recovery import RecoveryError, restore_state
    from tests.test_integrity_and_policy import _corrupt_clean_buffer
    state = opt_state(1 << 10)
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=2048))
    try:
        eng.snapshot_sync(state, 1)
        st2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.uint32 else x,
                           state)
        eng.snapshot_sync(st2, 2)
        assert _corrupt_clean_buffer(eng.run, 0, 1, eng.spec.total_bytes) == 2
        rec, step, _ = restore_state(eng.run, 1, eng.spec.total_bytes,
                                     state, [0])
        assert step == 1 and trees_equal(rec, state)
        # corrupt the older step too -> every candidate has zero usable
        # sources -> clean RecoveryError (tier 3 takes over), not a crash
        _corrupt_clean_buffer_at(eng.run, 0, 1, eng.spec.total_bytes)
        with pytest.raises(RecoveryError):
            restore_state(eng.run, 1, eng.spec.total_bytes, state, [0])
    finally:
        eng.close()


def _corrupt_clean_buffer_at(run, node, step, total_bytes):
    from repro.core.smp import ReadOnlyNode, _attach, _seg
    view = ReadOnlyNode(run, node, 1, total_bytes)
    idx = view.clean_steps()[step]
    view.close()
    shm = _attach(_seg(run, node, f"buf{idx}"))
    shm.buf[100] = (shm.buf[100] + 1) % 256
    shm.close()


def test_smp_death_mid_flight_degrades_not_wedges():
    """SMP killed mid-flight with a tiny ring: the stager must not block
    forever on ring credits the dead SMP can never release — the engine
    degrades and training-side calls keep returning."""
    state = {"opt_mu": jnp.zeros((1 << 18,), jnp.float32)}
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(bucket_bytes=1 << 11, stage_slots=1))
    try:
        assert eng.snapshot_async(state, 1)
        eng.smp.proc.kill()                   # not via inject: state stays
        step = eng.wait(timeout=60)           # returns, does NOT wedge
        assert eng.degraded
        assert step == -1                     # nothing ever became clean
        assert not eng.snapshot_async(state, 2)
    finally:
        eng.close()


def test_flight_internal_timeout_degrades_not_wedges():
    """A flight that FAILS with an internal TimeoutError (SMP ack timeout)
    is a dead flight: the engine must degrade — like the serial path —
    not keep the corpse as 'still live' and wedge every later call."""
    state = opt_state(1 << 10)
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=1 << 12))
    try:
        def _ack_timeout(timeout=60.0):
            raise TimeoutError("SMP ack timeout (simulated)")
        eng.smp.wait_clean = _ack_timeout
        assert eng.snapshot_async(state, 1)
        assert eng.wait() == -1          # no clean step; no exception
        assert eng.degraded
        assert eng._flight is None       # corpse collected, not kept live
        assert not eng.snapshot_async(state, 2)      # degraded: refused
    finally:
        eng.close()


# ------------------------------------------------------------- yield gate
def test_boundary_gate_inactive_without_trainer():
    g = StepBoundaryGate()
    assert not g.active()
    t0 = time.perf_counter()
    assert g.wait_boundary(0.5) is False        # returns immediately
    assert time.perf_counter() - t0 < 0.25
    g.notify()
    assert g.active()


def test_boundary_gate_releases_on_tick():
    import threading
    g = StepBoundaryGate()
    g.notify()                                  # mark active
    got = []
    t = threading.Thread(target=lambda: got.append(g.wait_boundary(5.0)))
    t.start()
    time.sleep(0.05)
    g.notify()
    t.join(timeout=5)
    assert got == [True]


# ----------------------------------------------------- device encode path
def test_device_encode_roundtrip_single_node():
    """device_encode="on" (interpret-mode kernels on CPU CI): snapshot ->
    restore is bit-identical, and the device-combined CRC satisfies
    recovery's verify_crc — a wrong digest would demote the only member
    to corrupt and the restore would raise."""
    state = opt_state(1 << 12)
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(bucket_bytes=2048, device_encode="on"))
    try:
        assert eng.stats["device_encode"] is True
        assert eng.snapshot_sync(state, 3) == 3
        from repro.core.recovery import restore_state
        rec, step, _ = restore_state(eng.run, 1, eng.spec.total_bytes,
                                     state, [0])
        assert step == 3 and trees_equal(rec, state)
    finally:
        eng.close()


def test_device_encode_byte_identical_to_host_path():
    """Host vs device encode of the SAME state must publish byte-identical
    own bytes, parity bytes, and own-region CRC — `raim5.decode_node` is
    encode-agnostic exactly because of this.  Odd bucket/leaf sizes
    exercise the padded-lane tails."""
    import pickle

    from repro.core import ReftGroup
    from repro.core.smp import ReadOnlyNode
    state = opt_state(1 << 12)
    probes = {}
    for mode in ("off", "on"):
        cfg = ReftConfig(bucket_bytes=768, stage_slots=4,
                         device_encode=mode, ckpt_dir=tempfile.mkdtemp(),
                         checkpoint_every_snapshots=10 ** 6)
        g = ReftGroup(3, state, cfg)
        try:
            assert g.snapshot(state, 2)
            view = ReadOnlyNode(g.run, 1, 3, g.total_bytes)
            try:
                probes[mode] = (view.read_own(2).tobytes(),
                                view.read_parity(2).tobytes(),
                                pickle.loads(view.meta(2))["crc_own"])
            finally:
                view.close()
        finally:
            g.close()
    assert probes["off"][0] == probes["on"][0], "own bytes differ"
    assert probes["off"][1] == probes["on"][1], "parity bytes differ"
    assert probes["off"][2] == probes["on"][2], "own-region CRC differs"


def test_sg4_device_encode_raim5_roundtrip():
    """Full SG with device-encoded (kind-2) parity: single-node loss still
    decodes bit-identically from the kernel-encoded parity blocks."""
    from repro.core import ReftGroup
    state = opt_state(1 << 12)
    cfg = ReftConfig(bucket_bytes=512, stage_slots=4,
                     ckpt_dir=tempfile.mkdtemp(),
                     checkpoint_every_snapshots=10 ** 6, device_encode="on")
    g = ReftGroup(4, state, cfg)
    try:
        assert g.snapshot(state, 3, extra_meta={"k": 3})
        # device path sends ONE encoded parity block, not n-1 stripe blocks
        assert g.engines[0].stats["bytes_sent"] < 2 * g.total_bytes / 4 * 1.5
        g.inject_node_failure(2)
        rec, step, extra, tier = g.recover()
        assert tier == "raim5" and step == 3 and extra == {"k": 3}
        assert trees_equal(rec, state)
    finally:
        g.close()


# --------------------------------------------------------- multi-flight
@pytest.mark.parametrize("device_encode", ["off", "on"])
def test_multi_flight_overlap_no_data_loss_bounded_scratch(device_encode):
    """max_flights=2: snapshot N+1 launches while N is still draining; both
    land bit-identically in the SMP triple buffer (no loss, no clobber)
    and the SHARED scratch pool never exceeds `scratch_buffers` credits."""
    state = {"opt_mu": jnp.zeros((1 << 15,), jnp.float32),
             "w": jnp.ones((1 << 15,), jnp.float32)}
    state2 = jax.tree.map(lambda x: x + 1, state)
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(bucket_bytes=1 << 12, stage_slots=4,
                                    max_flights=2, scratch_buffers=2,
                                    device_encode=device_encode))
    try:
        assert eng.snapshot_async(state, 1)
        assert eng.snapshot_async(state2, 2)          # overlapped launch
        assert not eng.snapshot_async(state2, 3)      # over the credit
        assert eng.wait() == 2
        assert eng.stats["snapshots"] == 2
        assert eng.stats["overlapped_flights"] >= 1
        pool = eng._pipeline
        assert pool._free.qsize() == pool.scratch_buffers   # fixed scratch
        from repro.core.recovery import restore_state
        from repro.core.smp import ReadOnlyNode
        from repro.core.treebytes import tree_to_buffer
        rec, step, _ = restore_state(eng.run, 1, eng.spec.total_bytes,
                                     state, [0])
        assert step == 2 and trees_equal(rec, state2)
        view = ReadOnlyNode(eng.run, 0, 1, eng.spec.total_bytes)
        try:
            assert {1, 2} <= set(view.clean_steps())
            flat1 = np.empty(eng.spec.total_bytes, np.uint8)
            tree_to_buffer(state, eng.spec, flat1)
            assert np.array_equal(
                view.read_own(1)[:eng.spec.total_bytes], flat1)
        finally:
            view.close()
    finally:
        eng.close()


# ------------------------------------------------------ batched leaf d2h
def test_leaf_reader_batched_fetch(monkeypatch):
    """Satellite: the prefetch window's leaves move host-side with ONE
    jax.device_get(list), not one synchronous np.asarray per leaf, and
    the result is byte-identical to the per-leaf path."""
    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(len(x) if isinstance(x, list) else 1)
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    state = opt_state()
    spec = make_flat_spec(state)
    leaves = jax.tree_util.tree_leaves(state)
    r = LeafReader(spec, leaves)
    r.fetch(range(len(leaves)))
    assert calls == [len(leaves)] and r.batched_fetches == 1
    out = np.empty(spec.total_bytes, np.uint8)
    r.read(0, spec.total_bytes, out)
    assert calls == [len(leaves)], "read after fetch re-transferred leaves"
    r2 = LeafReader(spec, leaves)
    out2 = np.empty(spec.total_bytes, np.uint8)
    r2.read(0, spec.total_bytes, out2)
    assert np.array_equal(out, out2)


# ------------------------------------------------------- saving affinity
def test_affinity_resolution_best_effort():
    assert resolve_affinity(None) is None
    assert resolve_affinity("off") is None
    # malformed knobs degrade to None — never fail engine construction
    assert resolve_affinity("garbage") is None
    assert resolve_affinity(object()) is None
    if hasattr(os, "sched_getaffinity"):
        avail = sorted(os.sched_getaffinity(0))
        auto = resolve_affinity("auto")
        assert auto is None or set(auto) <= set(avail)
        assert resolve_affinity((avail[0],)) == (avail[0],)
        assert resolve_affinity(avail[0]) == (avail[0],)          # bare int
        got = resolve_affinity(",".join(str(c) for c in avail))   # "0,1"
        assert got == tuple(avail)
        assert resolve_affinity((10 ** 6,)) is None   # outside allowed set


def test_stager_affinity_surfaced_in_stats():
    if not hasattr(os, "sched_setaffinity"):
        pytest.skip("no sched_setaffinity on this platform")
    avail = sorted(os.sched_getaffinity(0))
    state = {"opt_mu": jnp.zeros((1 << 14,), jnp.float32)}
    eng = SnapshotEngine(0, 1, state,
                         ReftConfig(bucket_bytes=1 << 12,
                                    pin_cpus=(avail[-1],)))
    try:
        eng.snapshot_sync(state, 1)
        assert eng.stats["stager_affinity"] == (avail[-1],)
    finally:
        eng.close()


# ---------------------------------------------------------- facade events
def test_reft_backend_reports_levels():
    from repro.api import CheckpointSpec
    import tempfile
    state = opt_state(1 << 12)
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="reft", ckpt_dir=d, sg_size=2,
                              resume=False, bucket_bytes=1 << 12)
        with spec.build(state) as ck:
            assert ck.snapshot(state, 1, wait=True)
            st = ck.stats()
            assert st["engine_l1_seconds"] > 0
            assert st["engine_l2_seconds"] > 0
            assert st["engine_l3_seconds"] > 0
            ev = [e for e in ck.events if e.kind == "snapshot"][-1]
            assert ev.levels is not None and ev.levels["l1"] > 0


def test_serial_fallback_via_options():
    from repro.api import CheckpointSpec
    import tempfile
    state = opt_state(1 << 12)
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="reft", ckpt_dir=d, sg_size=2,
                              resume=False, bucket_bytes=1 << 12,
                              options={"pipeline": False})
        with spec.build(state) as ck:
            assert ck.group.engines[0]._pipeline is None
            assert ck.snapshot(state, 1, wait=True)
            res = ck.restore()
            assert res.step == 1 and trees_equal(res.state, state)
