"""Sharding rules: spec adaptation, divisibility, coverage of every leaf."""
from types import SimpleNamespace

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.dist.api import adapt_spec
from repro.dist.shardings import param_specs, state_specs
from repro.models import model as M
from repro.train.steps import init_train_state


def fake_mesh(**axes):
    return SimpleNamespace(axis_names=tuple(axes),
                           axis_sizes=tuple(axes.values()))


def test_adapt_drops_missing_axes():
    mesh = fake_mesh(data=16, model=16)
    assert adapt_spec(P("pod", "model"), (32, 32), mesh) == P(None, "model")


def test_adapt_drops_nondividing():
    mesh = fake_mesh(data=16, model=16)
    # 8 % 16 != 0 -> dropped
    assert adapt_spec(P("model", None), (8, 64), mesh) == P(None, None)
    assert adapt_spec(P("model", None), (32, 64), mesh) == P("model", None)


def test_adapt_tuple_prefix():
    mesh = fake_mesh(pod=2, data=16, model=16)
    # 64 divides by pod*data=32 but not pod*data*model
    sp = adapt_spec(P(("pod", "data", "model"),), (64,), mesh)
    assert sp == P(("pod", "data"),)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_cover_and_divide(arch):
    """Every full-size param leaf gets a spec whose axes divide its dims on
    the production (16,16) mesh — this is what makes the dry-run lower."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, shapes)
    mesh = fake_mesh(data=16, model=16)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    n_model_sharded = 0
    for (path, spec), (_, sh) in zip(
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0],
            jax.tree_util.tree_flatten_with_path(shapes)[0]):
        assert len(spec) <= len(sh.shape), (path, spec, sh.shape)
        adapted = adapt_spec(spec, sh.shape, mesh)
        for dim, entry in enumerate(adapted):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            tot = 1
            for nm in names:
                tot *= sizes[nm]
            assert sh.shape[dim] % tot == 0
            if "model" in names:
                n_model_sharded += 1
    assert n_model_sharded >= 4, "big matrices must be model-sharded"


def test_state_specs_structure():
    cfg = get_config("qwen3-8b")
    st = jax.eval_shape(lambda: init_train_state(cfg, 0).tree())
    sp = state_specs(cfg, st)
    assert sp["step"] == P() and sp["rng"] == P()
    # optimizer moments mirror params
    flat_p = jax.tree.leaves(sp["params"],
                             is_leaf=lambda x: isinstance(x, P))
    flat_m = jax.tree.leaves(sp["opt_state"]["mu"],
                             is_leaf=lambda x: isinstance(x, P))
    assert flat_p == flat_m


def test_smoke_mesh_lowering():
    """The whole jit(in_shardings=...) machinery works on the host mesh
    with a reduced config (end-to-end minus the 512 fake devices)."""
    import dataclasses
    import jax.numpy as jnp
    from repro.configs.base import INPUT_SHAPES, InputShape
    from repro.launch import dryrun as DR
    from repro.launch.mesh import make_smoke_mesh

    small = InputShape("tiny", 64, 2, "train")
    INPUT_SHAPES["tiny"] = small
    try:
        cfg = get_config("gemma3-4b").reduced()
        cfg = dataclasses.replace(cfg, name="gemma3-4b")
        mesh = make_smoke_mesh()
        lowered, meta = DR.build_lowered("gemma3-4b", "tiny", mesh, cfg=cfg)
        compiled = lowered.compile()
        assert DR.cost_dict(compiled)["flops"] > 0
    finally:
        del INPUT_SHAPES["tiny"]
