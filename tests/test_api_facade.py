"""Unified checkpointing facade: backend parity, session lifecycle,
degraded-SMP handling, event emission."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    CheckpointSession, CheckpointSpec, available_backends,
    create_checkpointer,
)
from repro.core.recovery import RecoveryError


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (512, 8)),
            "mu": jnp.zeros((123,)), "step": jnp.int32(0)}


def advance(state, step):
    """Deterministic pseudo-training update."""
    return {"w": state["w"] + jnp.float32(step),
            "mu": state["mu"] * jnp.float32(-1.0),
            "step": jnp.int32(step)}


def eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_registry_has_builtin_backends():
    names = available_backends()
    for expect in ("reft", "sync_disk", "async_disk", "null"):
        assert expect in names


def test_unknown_backend_is_a_clear_error(tmp_path):
    spec = CheckpointSpec(backend="wat", ckpt_dir=str(tmp_path))
    with pytest.raises(KeyError, match="wat"):
        create_checkpointer(spec, make_state())


@pytest.mark.parametrize("backend", ["reft", "sync_disk", "async_disk"])
def test_backend_swap_parity(tmp_path, backend):
    """The SAME CheckpointSession calls restore bit-identical state on
    every backend — the apples-to-apples property the paper's comparison
    needs."""
    template = make_state()
    spec = CheckpointSpec(backend=backend, ckpt_dir=str(tmp_path),
                          sg_size=4, resume=False)
    with CheckpointSession(spec, template) as sess:
        state = template
        for step in (1, 2, 3):
            state = advance(state, step)
            assert sess.snapshot(state, step, extra_meta={"at": step},
                                 wait=True)
        sess.inject("node", node=1)
        res = sess.restore()
        assert res.step == 3
        assert res.extra_meta == {"at": 3}
        assert eq(res.state, state), f"{backend} restore not bit-exact"
        # every backend reconstructs the SAME bytes
        assert eq(res.state, advance(advance(advance(template, 1), 2), 3))


def test_null_backend_runs_but_cannot_restore(tmp_path):
    spec = CheckpointSpec(backend="null", ckpt_dir=str(tmp_path))
    with CheckpointSession(spec, make_state()) as sess:
        assert sess.snapshot(make_state(), 1)
        assert sess.health()["healthy"]
        with pytest.raises(RecoveryError):
            sess.checkpointer.restore()


def test_session_restore_on_entry(tmp_path):
    """A relaunched session resumes from what the previous one persisted."""
    template = make_state(1)
    state = advance(advance(template, 1), 2)
    spec = CheckpointSpec(backend="sync_disk", ckpt_dir=str(tmp_path),
                          resume=False)
    with CheckpointSession(spec, template) as sess:
        sess.snapshot(state, 2, extra_meta={"at": 2}, wait=True)

    spec2 = CheckpointSpec(backend="sync_disk", ckpt_dir=str(tmp_path),
                           resume=True)
    with CheckpointSession(spec2, template) as sess:
        assert sess.restored is not None
        assert sess.restored.step == 2
        assert sess.restored.extra_meta == {"at": 2}
        assert eq(sess.restored.state, state)


def test_session_cadence(tmp_path):
    """after_step honours snapshot/checkpoint intervals from the spec."""
    template = make_state(2)
    spec = CheckpointSpec(backend="sync_disk", ckpt_dir=str(tmp_path),
                          snapshot_every_steps=2, checkpoint_every_steps=4,
                          resume=False)
    with CheckpointSession(spec, template) as sess:
        snaps = []
        state = template
        for step in range(1, 9):
            state = advance(state, step)
            did = sess.after_step(state, step)
            if did["snapshot"]:
                snaps.append(step)
        assert snaps == [1, 3, 5, 7]
    st = sess.stats()
    assert st["snapshot"] == 4


def test_degraded_smp_keeps_training(tmp_path):
    """Losing a fault-tolerance sidecar must never kill training: the
    engine degrades, health() reports it, and recovery still works from
    the surviving members (RAIM5)."""
    template = make_state(3)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                          sg_size=4, resume=False)
    with CheckpointSession(spec, template) as sess:
        state = advance(template, 1)
        assert sess.snapshot(state, 1, wait=True)

        sess.checkpointer.group.engines[2].smp.kill()   # SMP-only crash
        state = advance(state, 2)
        # snapshots continue without raising; the dead member drops out
        for step in (2, 3):
            sess.snapshot(state, step, wait=True)
        h = sess.health()
        assert 2 in h["degraded"] and not h["healthy"]
        assert any(e.kind == "degraded" for e in sess.events)

        res = sess.restore()                  # decode node 2 from parity
        assert res.tier in ("raim5", "in-memory")
        assert eq(res.state, state)


def test_events_are_structured(tmp_path):
    spec = CheckpointSpec(backend="sync_disk", ckpt_dir=str(tmp_path),
                          resume=False)
    seen = []
    with CheckpointSession(spec, make_state(),
                           on_event=seen.append) as sess:
        st = advance(make_state(), 1)
        sess.snapshot(st, 1, wait=True)
        sess.persist()
        sess.restore()
    kinds = [e.kind for e in seen]
    assert "snapshot" in kinds and "restore" in kinds
    snap = next(e for e in seen if e.kind == "snapshot")
    assert snap.backend == "sync_disk" and snap.step == 1
    assert snap.nbytes > 0
