"""Architecture registry: exact assigned numbers + analytic param counts."""
import pytest

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config,
                           list_configs, shape_supported)


def test_all_assigned_registered():
    for a in ASSIGNED_ARCHS:
        assert get_config(a).name == a
    assert len(ASSIGNED_ARCHS) == 10


EXACT = {
    "starcoder2-3b": dict(num_layers=30, d_model=3072, num_heads=24,
                          num_kv_heads=2, d_ff=12288, vocab_size=49152),
    "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                          num_kv_heads=16, d_ff=5120, vocab_size=504),
    "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=65536,
                           num_experts=16, experts_per_token=2),
    "phi-3-vision-4.2b": dict(num_layers=32, d_model=3072, num_heads=32,
                              num_kv_heads=32, d_ff=8192, vocab_size=32064),
    "dbrx-132b": dict(num_layers=40, d_model=6144, num_heads=48,
                      num_kv_heads=8, d_ff=10752, vocab_size=100352,
                      num_experts=16, experts_per_token=4),
    "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                            num_kv_heads=8, d_ff=2048, vocab_size=163840,
                            num_experts=384, experts_per_token=8),
    "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                     num_kv_heads=8, d_ff=12288, vocab_size=151936),
    "mamba2-130m": dict(num_layers=24, d_model=768, d_ff=0,
                        vocab_size=50280, ssm_state=128),
    "deepseek-67b": dict(num_layers=95, d_model=8192, num_heads=64,
                         num_kv_heads=8, d_ff=22016, vocab_size=102400),
    "gemma3-4b": dict(num_layers=34, d_model=2560, num_heads=8,
                      num_kv_heads=4, d_ff=10240, vocab_size=262144),
}


@pytest.mark.parametrize("arch", sorted(EXACT))
def test_exact_assigned_numbers(arch):
    cfg = get_config(arch)
    for k, v in EXACT[arch].items():
        assert getattr(cfg, k) == v, (arch, k)


# Analytic parameter counts should land near the model names' headline sizes
BALLPARK = {
    "starcoder2-3b": (2.5e9, 4.5e9),
    "jamba-v0.1-52b": (40e9, 65e9),
    "phi-3-vision-4.2b": (3.3e9, 5.5e9),
    "dbrx-132b": (110e9, 150e9),
    "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
    "qwen3-8b": (6.5e9, 9.5e9),
    "mamba2-130m": (0.1e9, 0.2e9),
    "deepseek-67b": (58e9, 75e9),
    "gemma3-4b": (3.0e9, 6.0e9),
    "hubert-xlarge": (0.8e9, 1.4e9),
}


@pytest.mark.parametrize("arch", sorted(BALLPARK))
def test_param_count_ballpark(arch):
    n = get_config(arch).param_count()
    lo, hi = BALLPARK[arch]
    assert lo <= n <= hi, f"{arch}: {n:,}"


def test_kimi_active_params_32b():
    cfg = get_config("kimi-k2-1t-a32b")
    a = cfg.active_param_count()
    assert 20e9 <= a <= 45e9, a        # "a32b"
    assert a < cfg.param_count() / 10


def test_shape_support_matrix():
    cfg = get_config("hubert-xlarge")
    assert not shape_supported(cfg, INPUT_SHAPES["decode_32k"])[0]
    assert not shape_supported(cfg, INPUT_SHAPES["long_500k"])[0]
    assert shape_supported(cfg, INPUT_SHAPES["train_4k"])[0]
    # sub-quadratic archs run long_500k
    for a in ["mamba2-130m", "jamba-v0.1-52b", "gemma3-4b", "starcoder2-3b"]:
        assert shape_supported(get_config(a), INPUT_SHAPES["long_500k"])[0], a
    # pure full-attention archs skip it
    for a in ["qwen3-8b", "deepseek-67b", "dbrx-132b", "kimi-k2-1t-a32b",
              "phi-3-vision-4.2b"]:
        assert not shape_supported(get_config(a),
                                   INPUT_SHAPES["long_500k"])[0], a


def test_reduced_is_small():
    for a in ASSIGNED_ARCHS:
        r = get_config(a).reduced()
        assert r.num_layers == 2 and r.d_model <= 512
        assert r.num_experts <= 4
        assert r.param_count() < 20e6


def test_input_shapes_exact():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
