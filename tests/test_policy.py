"""Reliability model (Eqs. 1-11) sanity + Figure 8 reproduction."""
import math

import pytest
from hypothesis import given, strategies as st

from repro.core import policy


def test_weibull_basic():
    assert policy.weibull_survival(0.0, 100) == 1.0
    assert policy.weibull_survival(0.1, 0) == 1.0
    assert 0 < policy.weibull_survival(0.01, 10, 1.3) < 1


@given(t=st.floats(0.1, 100), lam=st.floats(1e-6, 1e-2),
       c=st.floats(0.5, 2.0))
def test_survival_monotone_decreasing(t, lam, c):
    assert policy.weibull_survival(lam, t, c) >= \
        policy.weibull_survival(lam, t * 2, c) - 1e-12


@given(k=st.sampled_from([6, 12, 24, 48]), t=st.floats(0.1, 50),
       lam=st.floats(1e-6, 1e-3))
def test_reft_beats_checkpoint_survival(k, t, lam):
    """Eq. 2 vs Eq. 3: REFT's in-memory parameters always survive with at
    least checkpoint-only probability (same hw rate; sw failures excluded
    by SMP decoupling)."""
    n = 6
    p_re = policy.reft_survival(k, n, t, lam_hw=lam, lam_smp=0.0)
    p_ck = policy.ckpt_survival(k, t, lam_hw=lam, lam_sw=lam)
    assert p_re >= p_ck - 1e-12


def test_figure8_shape():
    """3072-GPU system, 6 DP paths (Fig. 8): with hw/sw rates 1e-4, the
    safe horizon at threshold 0.9 is dramatically longer with REFT."""
    k, n = 3072 // 4, 6          # nodes of 4 GPUs, SGs of 6
    k = (k // n) * n
    lam = 1e-4
    c = 1.3
    t_reft = policy.safe_horizon(
        lambda t: policy.reft_survival(k, n, t, lam_hw=lam, c=c))
    t_ck = policy.safe_horizon(
        lambda t: policy.ckpt_survival(k, t, lam_hw=lam, lam_sw=lam, c=c))
    assert t_reft > 10 * t_ck     # paper reports 16.22d vs 0.5d (32x)


def test_optimal_interval_formula():
    # Eq. 5: T = sqrt(2 O / lam)
    assert policy.optimal_interval(2.0, 1e-4) == \
        pytest.approx(math.sqrt(2 * 2.0 / 1e-4))
    assert policy.optimal_interval(0.0, 1e-4) == 0.0
    assert policy.optimal_interval(1.0, 0.0) == math.inf


@given(lam=st.floats(1e-8, 0.2), n=st.integers(2, 10))
def test_reft_fail_rate_much_smaller(lam, n):
    """Eq. 7: needing >=2 failures per SG is strictly rarer than a single
    failure."""
    r = policy.reft_fail_rate(lam, n)
    assert 0 <= r <= 1
    assert r <= lam * n           # union bound on pairs is way below this


def test_effective_save_overhead_relu():
    assert policy.effective_save_overhead(3.0, 5.0) == 0.0   # fully hidden
    assert policy.effective_save_overhead(5.0, 3.0) == 2.0


def test_plan_frequencies_orders():
    """Snapshots must be at least as frequent as checkpoints (Eqs. 9-11)."""
    plan = policy.plan_frequencies(t_snapshot=0.5, t_checkpoint=30.0,
                                   t_comp=1.0, lam_node=1e-4, n=4)
    assert plan.snapshot_interval <= plan.checkpoint_interval
    assert plan.o_snapshot == 0.0         # hidden behind compute
    assert plan.lam_unrecoverable < 1e-4


def test_total_overhead_tradeoff():
    """Eq. 4 has an interior optimum: the optimal interval beats both a
    too-frequent and a too-rare schedule."""
    o_save, lam, T = 2.0, 1e-4, 1e6
    t_opt = policy.optimal_interval(o_save, lam)
    f = lambda ts: policy.total_overhead(T, ts, o_save, lam,
                                         t_sch=30.0, t_load=10.0)
    assert f(t_opt) <= f(t_opt / 10) and f(t_opt) <= f(t_opt * 10)
