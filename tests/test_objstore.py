"""Tier-4 object-store durability: store primitives, retry/backoff, CRC
composition properties, stripe-multipart upload + ranged remote restore,
the recovery ladder's tier-3 -> tier-4 fallthrough, fault injection with
zero data loss, and the persist_bw_limit token bucket."""
import glob
import os
import pickle
import random
import zlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import CheckpointSpec, RestoreTarget
from repro.api.registry import available_backends, create_checkpointer
from repro.core.crcutil import crc32_combine, crc32_concat
from repro.core.loader import ObjectSource
from repro.store import (
    FlakyStore, LocalObjectStore, NotFoundError, RetryPolicy, StoreError,
    TransientStoreError, build_manifest, call_with_retries, delete_family,
    list_step_prefixes, load_manifest, object_families, put_manifest,
    shard_key, store_from_config, upload_shard,
)


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (512, 8), jnp.float32),
            "b": jnp.arange(64, dtype=jnp.int32),
            "step": jnp.asarray(7, jnp.int32)}


def assert_trees_equal(a, b):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


# --------------------------------------------------------- store basics
def test_local_store_multipart_roundtrip(tmp_path):
    s = LocalObjectStore(str(tmp_path))
    s.put_part("fam/a.bin", 0, b"hello ")
    s.put_part("fam/a.bin", 1, b"world")
    # parts are invisible until compose (torn upload == no object)
    assert s.list() == []
    assert not s.exists("fam/a.bin")
    assert s.compose("fam/a.bin", 2) == 11
    assert s.list() == ["fam/a.bin"]
    assert bytes(s.read_range("fam/a.bin", 0, 11)) == b"hello world"
    assert bytes(s.read_range("fam/a.bin", 6, 11)) == b"world"
    assert s.size("fam/a.bin") == 11
    # compose consumed the parts
    with pytest.raises(StoreError):
        s.compose("fam/a.bin", 2)


def test_local_store_missing_and_bad_keys(tmp_path):
    s = LocalObjectStore(str(tmp_path))
    with pytest.raises(NotFoundError):
        s.read_range("nope", 0, 1)
    with pytest.raises(NotFoundError):
        s.size("nope")
    s.delete("nope")                       # idempotent
    for bad in ("", "/abs", "a/../b"):
        with pytest.raises(StoreError):
            s.put(bad, b"x")


def test_local_store_delete_prefix_sweeps_scratch(tmp_path):
    s = LocalObjectStore(str(tmp_path))
    s.put("fam/step-1/a", b"x")
    s.put_part("fam/step-1/torn", 0, b"orphan part")
    assert s.delete_prefix("fam/step-1") == 1
    assert s.list() == []
    # scratch of the torn upload swept too
    assert not any("torn" in f for _, _, fs in os.walk(str(tmp_path))
                   for f in fs)


def test_store_from_config_roundtrip(tmp_path):
    s = LocalObjectStore(str(tmp_path))
    f = FlakyStore(s, latency_s=0.0, error_rate=0.5, fail_every=3, seed=9)
    rebuilt = store_from_config(f.config)
    assert isinstance(rebuilt, FlakyStore)
    assert isinstance(rebuilt.inner, LocalObjectStore)
    assert rebuilt.fail_every == 3 and rebuilt.inner.root == s.root
    with pytest.raises(StoreError):
        store_from_config({"kind": "s3"})


# -------------------------------------------------------- retry/backoff
def test_retry_bounded_backoff(tmp_path):
    s = FlakyStore(LocalObjectStore(str(tmp_path)), fail_every=2)
    sleeps = []
    pol = RetryPolicy(attempts=4, base_s=0.01, max_s=0.04, mult=2.0)
    # every 2nd op faults: each logical op needs exactly one retry
    for i in range(4):
        _, retries = call_with_retries(
            lambda i=i: s.put(f"k{i}", b"v"), pol, sleep=sleeps.append)
    assert all(s.exists(f"k{i}") for i in range(4))
    assert sleeps and all(0.01 <= t <= 0.04 for t in sleeps)


def test_retry_exhaustion_propagates():
    calls = []

    def always_503():
        calls.append(1)
        raise TransientStoreError("503")

    with pytest.raises(TransientStoreError):
        call_with_retries(always_503,
                          RetryPolicy(attempts=3, base_s=0.0),
                          sleep=lambda t: None)
    assert len(calls) == 3                 # bounded, not infinite


def test_terminal_errors_not_retried(tmp_path):
    s = LocalObjectStore(str(tmp_path))
    calls = []

    def missing():
        calls.append(1)
        return s.size("absent")

    with pytest.raises(NotFoundError):
        call_with_retries(missing, RetryPolicy(attempts=5, base_s=0.0))
    assert len(calls) == 1


# ------------------------------------------------- CRC composition props
def test_crc_combine_matches_zlib_random_splits():
    rng = random.Random(0)
    for _ in range(200):
        blob = bytes(rng.getrandbits(8)
                     for _ in range(rng.randrange(0, 64)))
        cut = rng.randint(0, len(blob))
        a, b = blob[:cut], blob[cut:]
        got = crc32_combine(zlib.crc32(a), zlib.crc32(b), len(b))
        assert got == zlib.crc32(blob), (len(a), len(b))


def test_crc_concat_multipart_vs_whole_object():
    """The invariant the upload path rests on: folding per-part digests
    (stripe-sized parts, zero-length tails, single-byte tails included)
    reproduces the whole-object zlib CRC."""
    rng = random.Random(1)
    for _ in range(100):
        blob = bytes(rng.getrandbits(8)
                     for _ in range(rng.randrange(1, 200)))
        parts, i = [], 0
        while i < len(blob):
            step = rng.choice([0, 1, 1, rng.randrange(1, 40)])
            parts.append(blob[i:i + step])
            i += step if step else 0
            if step == 0:
                parts[-1] = b""            # explicit empty segment
        parts.append(b"")                  # zero-length tail part
        assert b"".join(parts) == blob
        got = crc32_concat((zlib.crc32(p), len(p)) for p in parts)
        assert got == zlib.crc32(blob)


def test_crc_combine_masks_wide_inputs():
    """Digests can ride in containers wider than 32 bits (uint64 device
    lanes); bits >= 32 used to index past the GF(2) matrix."""
    c = zlib.crc32(b"payload")
    wide = (1 << 40) | c
    assert crc32_combine(wide, 0, 0) == c
    assert crc32_combine(wide, zlib.crc32(b"x"), 1) == \
        crc32_combine(c, zlib.crc32(b"x"), 1) == zlib.crc32(b"payloadx")
    assert crc32_combine(0, wide, 7) == crc32_combine(0, c, 7)
    assert crc32_combine(np.uint64(c), np.uint64(zlib.crc32(b"x")),
                         np.int64(1)) == zlib.crc32(b"payloadx")


# ------------------------------------- upload + ObjectSource (no SMP)
def test_upload_shard_stripes_and_ranged_reads(tmp_path):
    store = LocalObjectStore(str(tmp_path))
    rng = np.random.default_rng(2)
    head = pickle.dumps({"n": 1, "total_bytes": 96, "step": 5,
                         "meta": pickle.dumps({})})
    buf = rng.integers(0, 256, size=96, dtype=np.uint8)
    rec = upload_shard(store, "fam/step-5/node-0.reft", head, buf,
                       seg=32, own_bytes=96)
    assert rec["parts"] == 1 + 3           # head + 3 stripe parts
    assert rec["data_off"] == len(head)
    assert store.size("fam/step-5/node-0.reft") == len(head) + 96
    got = store.read_range("fam/step-5/node-0.reft",
                           len(head), len(head) + 96)
    np.testing.assert_array_equal(got, buf)


def test_manifest_completeness_marker(tmp_path):
    store = LocalObjectStore(str(tmp_path))
    store.put(shard_key("families", 3, 0), b"shardbytes")
    # shard objects alone do NOT make a family: no manifest, not listed
    assert object_families(store, "families") == {}
    assert list_step_prefixes(store, "families") == {3}
    man = build_manifest("run", 3, 1, 10, {0: {
        "key": shard_key("families", 3, 0), "nbytes": 10, "data_off": 0,
        "parts": 1}})
    put_manifest(store, "families", man)
    assert object_families(store, "families") == {3: "families/step-3"}
    got = load_manifest(store, "families", 3)
    assert got["nodes"][0]["key"] == man["nodes"]["0"]["key"]
    assert delete_family(store, "families", 3) == 2
    assert object_families(store, "families") == {}


def test_manager_treats_remote_families_like_local(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    store = LocalObjectStore(str(tmp_path / "obj"))
    mgr = CheckpointManager(str(tmp_path / "ckpt"), 2, keep=2,
                            store=store)
    for s in (1, 2, 3, 4):
        for nd in (0, 1):
            store.put(shard_key("families", s, nd), b"x" * 8)
        put_manifest(store, "families",
                     build_manifest("r", s, 2, 16,
                                    {nd: {"key": shard_key("families", s,
                                                           nd),
                                          "nbytes": 8, "data_off": 0,
                                          "parts": 1} for nd in (0, 1)}))
    # torn remote family (objects, no manifest) newest: spared by GC
    store.put(shard_key("families", 9, 0), b"inflight")
    assert mgr.latest() == 4               # remote-only family surfaces
    mgr.register_inflight(4)
    assert mgr.latest() == 3               # in-flight never surfaced
    mgr.resolve_inflight(4)
    mgr.commit()                           # keep=2 -> remote 1, 2 GC'd
    assert sorted(object_families(store, "families")) == [3, 4]
    assert 9 in list_step_prefixes(store, "families")  # newest torn spared


# ----------------------------------------------------- SMP-backed e2e
def test_backend_registered():
    assert "objstore" in available_backends()


def test_remote_restore_elastic_after_local_loss(tmp_path):
    """Acceptance path: persist (stripe-multipart upload) -> delete ALL
    local `.reft` files -> restore from the object store via ranged
    reads onto a different sg_size, byte-identical."""
    state = small_state()
    spec = CheckpointSpec(backend="objstore", ckpt_dir=str(tmp_path),
                          sg_size=2, options={"scrub_every_s": 0.0})
    ck = create_checkpointer(spec, state)
    try:
        ck.snapshot(state, 7, extra_meta={"ds": 1}, wait=True)
        assert ck.persist(wait=True) == 7
        st = ck.stats()
        assert st["persist_upload_bytes"] > 0
        assert object_families(ck.store, ck.store_prefix) == \
            {7: f"{ck.store_prefix}/step-7"}
        for p in glob.glob(os.path.join(str(tmp_path), "*.reft")):
            os.unlink(p)
        ck.inject_failure(0, "node")
        ck.inject_failure(1, "node")
        res = ck.restore(target=RestoreTarget(sg_size=3))
        assert res.tier == "objstore" and res.load.source == "object"
        assert res.load.saved_n == 2 and res.load.resharded
        assert res.step == 7 and res.extra_meta == {"ds": 1}
        assert_trees_equal(res.state, state)
    finally:
        ck.close()


def test_tier3_to_tier4_fallthrough_on_corrupt_local(tmp_path):
    """Corrupt every local `.reft` family: the ladder must reject tier 3
    and fall through to the remote rung, reporting it in LoadStats."""
    state = small_state(seed=3)
    spec = CheckpointSpec(backend="objstore", ckpt_dir=str(tmp_path),
                          sg_size=2, options={"scrub_every_s": 0.0})
    ck = create_checkpointer(spec, state)
    try:
        ck.snapshot(state, 7, wait=True)
        ck.persist(wait=True)
        for p in glob.glob(os.path.join(str(tmp_path), "*.reft")):
            with open(p, "r+b") as f:      # garbage head: unparseable
                f.write(b"\x00" * 64)
        ck.inject_failure(0, "node")
        ck.inject_failure(1, "node")
        res = ck.restore()
        assert res.tier == "objstore" and res.load.source == "object"
        assert_trees_equal(res.state, state)
    finally:
        ck.close()


def test_flaky_store_zero_data_loss(tmp_path):
    """Latency + deterministic transient 5xx faults on every data-path
    op: uploads and restores complete via bounded retry/backoff with the
    state byte-identical."""
    state = small_state(seed=4)
    store_cfg = {"kind": "flaky",
                 "inner": {"kind": "local",
                           "root": str(tmp_path / "obj")},
                 "latency_s": 0.0005, "fail_every": 3}
    spec = CheckpointSpec(backend="objstore", ckpt_dir=str(tmp_path),
                          sg_size=2,
                          options={"scrub_every_s": 0.0,
                                   "store": store_cfg,
                                   "store_retry": {"attempts": 5,
                                                   "base_s": 0.001}})
    ck = create_checkpointer(spec, state)
    try:
        ck.snapshot(state, 7, wait=True)
        assert ck.persist(wait=True) == 7
        assert ck.stats()["persist_upload_retries"] > 0
        for p in glob.glob(os.path.join(str(tmp_path), "*.reft")):
            os.unlink(p)
        ck.inject_failure(0, "node")
        ck.inject_failure(1, "node")
        res = ck.restore()
        assert res.tier == "objstore"
        assert_trees_equal(res.state, state)
    finally:
        ck.close()


def test_persist_bw_limit_throttles_and_surfaces(tmp_path):
    """The token bucket slows the SMP's background writes (throttle time
    shows up in stats) without failing the persist."""
    k = jax.random.PRNGKey(5)
    state = {"w": jax.random.normal(k, (1 << 19,), jnp.float32)}  # 2 MiB
    # per-node buffer is 2 MiB (1 MiB own + 1 MiB parity); at 4 MB/s the
    # bucket's burst is 1 MB, so the tail of every write must wait
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                          sg_size=2,
                          options={"persist_bw_limit": 4e6})
    ck = create_checkpointer(spec, state)
    try:
        ck.snapshot(state, 1, wait=True)
        assert ck.persist(wait=True) == 1
        st = ck.stats()
        assert st["persist_bw_limit"] == 4e6
        assert st["persist_throttle_seconds"] > 0.0
        assert st["persist_errors"] == 0
    finally:
        ck.close()


def test_object_source_matches_file_source(tmp_path):
    """Same persisted family through both durable sources: identical
    bytes, identical meta, ranged reads agree."""
    from repro.core.coordinator import ReftGroup
    from repro.core.loader import FileSource
    from repro.core.snapshot import ReftConfig

    state = small_state(seed=6)
    store = LocalObjectStore(str(tmp_path / "obj"))
    g = ReftGroup(2, state, ReftConfig(ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10**9))
    try:
        g.snapshot(state, 1)
        g.wait()
        step = g.checkpoint_async(remote={"store": store.config,
                                          "prefix": "families"})
        rounds = g.drain_persists()
        rnd = next(r for r in rounds if r["step"] == step)
        assert rnd["ok"], rnd["errors"]
        put_manifest(store, "families",
                     build_manifest(g.run, step, 2, g.total_bytes,
                                    rnd["uploads"]))
        man = load_manifest(store, "families", step)
        osrc = ObjectSource(store, man)
        fsrc = FileSource({nd: os.path.join(
            str(tmp_path), f"step-{step}-node-{nd}.reft")
            for nd in range(2)})
        try:
            assert (osrc.n, osrc.total_bytes, osrc.step) == \
                (fsrc.n, fsrc.total_bytes, fsrc.step)
            for nd in range(2):
                np.testing.assert_array_equal(
                    osrc.read_local(nd, 3, 777), fsrc.read_local(nd, 3, 777))
                assert osrc.meta(nd)["spec"] == fsrc.meta(nd)["spec"]
            np.testing.assert_array_equal(
                osrc.read_parity_range(0, 0, 64),
                fsrc.read_parity_range(0, 0, 64))
        finally:
            osrc.close()
            fsrc.close()
    finally:
        g.close()
