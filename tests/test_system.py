"""End-to-end behaviour: the training driver with mid-run fault injection
(deliverable b's driver, exercised as a test)."""
import sys

import pytest


def test_train_driver_with_failures(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "opt-125m", "--reduced", "--steps", "16",
               "--batch", "2", "--seq", "64", "--sg-size", "4",
               "--snapshot-every", "2", "--ckpt-dir", str(tmp_path),
               "--inject", "6:software", "--inject", "12:node"])
    assert rc == 0


def test_train_driver_no_reft(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "opt-125m", "--reduced", "--steps", "6",
               "--batch", "2", "--seq", "64", "--no-reft"])
    assert rc == 0


def test_quickstart_example_runs():
    sys.path.insert(0, "examples")
    try:
        import quickstart
        quickstart.main()
    finally:
        sys.path.pop(0)
