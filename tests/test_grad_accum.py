"""Gradient accumulation: microbatched step == full-batch step."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.pipeline import make_batch
from repro.train.steps import init_train_state, make_train_step


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-130m"])
def test_microbatch_equals_full(arch):
    cfg = get_config(arch).reduced()
    shape = InputShape("t", 32, 4, "train")
    batch = make_batch(cfg, shape, seed=3)
    s0 = init_train_state(cfg, 0).tree()

    s_full, m_full = jax.jit(make_train_step(cfg))(s0, batch)
    s_mb, m_mb = jax.jit(make_train_step(cfg, microbatches=2))(s0, batch)

    np.testing.assert_allclose(float(m_full["loss"]), float(m_mb["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_mb["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-5, rtol=2e-4)


def test_microbatch_requires_divisible_batch():
    cfg = get_config("qwen3-8b").reduced()
    shape = InputShape("t", 16, 3, "train")
    batch = make_batch(cfg, shape, seed=1)
    s0 = init_train_state(cfg, 0).tree()
    with pytest.raises(AssertionError):
        make_train_step(cfg, microbatches=2)(s0, batch)
