"""Beyond-paper extensions: CRC corruption detection with RAIM5 repair,
and the Appendix-A adaptive snapshot frequency."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Reft, ReftConfig, ReftGroup
from repro.core.smp import ReadOnlyNode, _attach, _seg


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (256, 32)),
            "mu": jnp.zeros((256, 32)), "step": jnp.int32(0)}


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _corrupt_clean_buffer(run, node, n, total_bytes):
    """Flip a byte inside the latest clean snapshot's own region."""
    view = ReadOnlyNode(run, node, n, total_bytes)
    step = view.latest_clean()
    idx = view.clean_steps()[step]
    view.close()
    shm = _attach(_seg(run, node, f"buf{idx}"))
    shm.buf[100] = (shm.buf[100] + 1) % 256
    shm.close()
    return step


def test_corruption_detected_and_repaired_via_parity(tmp_path):
    state = small_state()
    g = ReftGroup(4, state, ReftConfig(ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10 ** 6))
    try:
        g.snapshot(state, 1)
        _corrupt_clean_buffer(g.run, 2, 4, g.total_bytes)
        rec, step, extra, tier = g.recover()
        assert step == 1
        assert trees_equal(rec, state)      # bit-exact despite corruption
    finally:
        g.close()


def test_corruption_plus_node_loss_falls_to_checkpoint(tmp_path):
    state = small_state(1)
    g = ReftGroup(4, state, ReftConfig(ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10 ** 6))
    try:
        g.snapshot(state, 1)
        g.checkpoint()
        g.inject_node_failure(0)
        _corrupt_clean_buffer(g.run, 3, 4, g.total_bytes)
        rec, step, extra, tier = g.recover()
        assert tier == "checkpoint"         # 2 unusable members in the SG
        assert trees_equal(rec, state)
    finally:
        g.close()


def test_auto_interval_retunes(tmp_path):
    """Fast snapshots (hidden behind compute) -> every step; if we force a
    huge lam and slow snapshot stats, the interval grows (Eq. 9)."""
    state = small_state(2)
    g = ReftGroup(1, state, ReftConfig(ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10 ** 6))
    try:
        reft = Reft(g, auto=True, lam_node=1e-4, warmup=2)
        for step in range(1, 6):
            time.sleep(0.02)                 # simulated compute
            reft.maybe_snapshot(state, step, wait=True)
        assert reft.snapshot_every == 1      # overhead fully hidden

        # pretend snapshots are expensive: o_save > 0 -> interval > 1
        g.engines[0].stats["seconds"] = 100.0
        g.engines[0].stats["snapshots"] = 1
        reft._retune()
        assert reft.snapshot_every > 1
    finally:
        g.close()
