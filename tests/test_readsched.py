"""Straggler-aware restore: chunked work-stealing reads, EWMA bandwidth
model, parity-alternative routing, hedged tail reads, and pipelined
decode (`repro.core.readsched`) — byte-identity against the FCFS oracle
is the hard invariant throughout."""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ReftConfig, ReftGroup, raim5
from repro.core.loader import (
    CrcMismatch, FlatSink, LoadStats, ShmSource, build_plan, load_bytes,
    member_shard_need,
)
from repro.core.readsched import (
    BucketedSource, ChunkScheduler, SchedConfig, SourceBandwidth,
    SourceLost, ThrottledSource,
)
from repro.core.recovery import attach_survivors, restore_bytes, restore_state
from repro.core.treebytes import make_flat_spec


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 32)),
                   "b": jnp.ones((17,), jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((64, 32)), "step": jnp.int32(0)},
        "rng": jax.random.PRNGKey(seed + 1),
    }


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture
def group(tmp_path):
    state = small_state()
    cfg = ReftConfig(bucket_bytes=1024, stage_slots=4,
                     ckpt_dir=str(tmp_path),
                     checkpoint_every_snapshots=10 ** 6)
    g = ReftGroup(4, state, cfg)
    yield g, state
    g.close()


@pytest.fixture
def views(group):
    g, state = group
    g.snapshot(state, 1)
    vs = attach_survivors(g.run, list(range(4)), 4, g.total_bytes)
    yield g, vs
    for v in vs.values():
        v.close()


def _oracle(views, n, total_bytes, failed=None, need=None):
    """FCFS legacy executor = the byte-identity oracle."""
    plan = build_plan(n, total_bytes, need=need, failed=failed)
    buf, _ = load_bytes(plan, ShmSource(views, 1), verify=True)
    return buf


class DyingSource:
    """ShmSource wrapper: node `die_node`'s reads raise after the first
    `allow` successful calls (a member whose SMP/NIC dies mid-restore).
    An optional per-read `delay_s` on that node makes it measurably slow
    first, so the EWMA model sees a laggard before the death."""

    def __init__(self, inner, die_node, allow=0, delay_s=0.0):
        self._inner = inner
        self.die_node = die_node
        self.allow = allow
        self.delay_s = delay_s
        self._calls = 0
        self._lock = threading.Lock()
        self.kind = getattr(inner, "kind", "")

    def _gate(self, node):
        if node != self.die_node:
            return
        with self._lock:
            self._calls += 1
            if self._calls > self.allow:
                raise OSError(f"node {node} connection reset")
        if self.delay_s:
            import time
            time.sleep(self.delay_s)

    def nodes(self):
        return self._inner.nodes()

    def meta(self, node):
        return self._inner.meta(node)

    def read_local(self, node, lo, hi):
        self._gate(node)
        return self._inner.read_local(node, lo, hi)

    def read_block_range(self, node, stripe, index, o1, o2):
        self._gate(node)
        return self._inner.read_block_range(node, stripe, index, o1, o2)

    def read_parity_range(self, stripe, o1, o2):
        self._gate(stripe)
        return self._inner.read_parity_range(stripe, o1, o2)


class AuditedSink:
    """FlatSink that records every written extent and fails the test on
    any overlap — the hedge/steal claim discipline must make double
    writes impossible."""

    def __init__(self, total_bytes):
        self._sink = FlatSink(total_bytes)
        self._lock = threading.Lock()
        self.extents = []

    @property
    def buf(self):
        return self._sink.buf

    def write(self, g, data):
        with self._lock:
            a, b = g, g + data.nbytes
            for x, y in self.extents:
                assert b <= x or a >= y, \
                    f"overlapping write [{a},{b}) vs [{x},{y})"
            self.extents.append((a, b))
        self._sink.write(g, data)


# ------------------------------------------------------- bandwidth model
def test_source_bandwidth_ewma_priors_and_death():
    bw = SourceBandwidth(alpha=0.5, priors={"shm:0": 100.0, "shm:9": -1})
    assert bw.bandwidth("shm:0") == 100.0
    assert bw.samples("shm:0") == 0          # priors carry no live samples
    assert bw.bandwidth("shm:9") is None     # non-positive prior dropped
    bw.observe("shm:0", 300, 1.0)
    assert bw.bandwidth("shm:0") == pytest.approx(200.0)   # 0.5/0.5 blend
    assert bw.samples("shm:0") == 1
    bw.observe("shm:1", 50, 0.0)             # degenerate timing ignored
    assert bw.bandwidth("shm:1") is None
    bw.mark_dead("shm:0")
    assert bw.bandwidth("shm:0") is None
    assert "shm:0" not in bw.snapshot()


# -------------------------------------------- byte identity vs the oracle
@pytest.mark.parametrize("mode", ["steal", "adaptive"])
@pytest.mark.parametrize("chunk", [777, 4096])
def test_scheduler_byte_identical_to_fcfs(views, mode, chunk):
    g, vs = views
    want = _oracle(vs, 4, g.total_bytes)
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode=mode, chunk_bytes=chunk)
    got, st = load_bytes(plan, ShmSource(vs, 1), verify=True, sched=cfg)
    np.testing.assert_array_equal(got, want)
    assert st.sched == mode
    # full verification discipline: every member folds crc_own, except a
    # member the adaptive path rerouted under scheduling jitter (rare) —
    # its sticky blocks were digest-checked instead
    assert set(st.crc_members) == set(range(4)) - set(st.rerouted_members)
    if mode == "steal":
        assert st.rerouted_members == ()     # steal never reroutes


def test_steal_moves_work_off_slow_member(views):
    """With one member throttled, fast members' workers steal its queued
    chunks; result stays byte-identical and fully verified."""
    g, vs = views
    want = _oracle(vs, 4, g.total_bytes)
    slow = ThrottledSource(ShmSource(vs, 1), {2: 200_000.0})
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="steal", chunk_bytes=512)
    got, st = load_bytes(plan, slow, verify=True, sched=cfg)
    np.testing.assert_array_equal(got, want)
    assert st.stolen_chunks > 0
    assert st.crc_members == (0, 1, 2, 3)
    assert "slow+shm:2" in st.source_bandwidth


def test_adaptive_reroutes_laggard_to_parity(views):
    """A member slow enough that parity reconstruction beats waiting gets
    its queued chunks converted to decode work mid-flight — today parity
    only serves dead members.  Byte identity must survive the reroute,
    and the laggard's directly-read blocks are digest-checked."""
    g, vs = views
    want = _oracle(vs, 4, g.total_bytes)
    slow = ThrottledSource(ShmSource(vs, 1), {1: 20_000.0})
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="adaptive", chunk_bytes=512, min_samples=1,
                      reroute_factor=1.0)
    got, st = load_bytes(plan, slow, verify=True, sched=cfg)
    np.testing.assert_array_equal(got, want)
    assert st.rerouted_members == (1,)
    assert st.parity_rerouted_bytes > 0
    # the rerouted member can't fold crc_own (decoded blocks were never
    # read); everyone else still verifies in full
    assert set(st.crc_members) == {0, 2, 3}


def test_laggard_dies_after_reroute_with_landed_bytes_demotes(views):
    """The laggard dies after being rerouted, leaving a partially-read
    sticky block whose landed bytes can no longer be digest-verified:
    the scheduler must surface SourceLost (never silently trust them),
    and the ladder-style demote-and-replan recovers byte-identically."""
    g, vs = views
    want = _oracle(vs, 4, g.total_bytes)
    # node 1: one slow successful read (feeds the EWMA a laggard sample),
    # every later read raises — death strikes while block 0 is half-read.
    # Fast priors on the healthy members keep a single jittery chunk
    # timing from ever qualifying them for the reroute, so the laggard
    # is deterministically the member that gets converted; min_samples=1
    # still defers the reroute until node 1's sticky read has landed.
    src = DyingSource(ShmSource(vs, 1), die_node=1, allow=1, delay_s=0.05)
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="adaptive", chunk_bytes=512, min_samples=1,
                      reroute_factor=1.0, min_eta_s=0.0,
                      inflight_per_source=1,
                      priors={"shm:0": 1e9, "shm:2": 1e9, "shm:3": 1e9})
    with pytest.raises(SourceLost) as ei:
        load_bytes(plan, src, verify=True, sched=cfg)
    assert ei.value.node == 1
    plan2 = build_plan(4, g.total_bytes, failed=1)
    got, st = load_bytes(plan2, ShmSource(vs, 1), verify=True, sched=cfg)
    np.testing.assert_array_equal(got, want)
    assert st.decoded_bytes > 0


def test_known_slow_prior_reroutes_before_death_never_retouched(views):
    """Cross-restore priors mark the laggard slow BEFORE any read (the
    FailureObserver feedback path): the adaptive scheduler reroutes its
    entire plan share to parity decode up front, so when the member dies
    on first touch the restore completes without it — at most one read
    ever reaches the dead source."""
    g, vs = views
    want = _oracle(vs, 4, g.total_bytes)
    src = DyingSource(ShmSource(vs, 1), die_node=1, allow=0)
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="adaptive", chunk_bytes=512, min_samples=0,
                      reroute_factor=1.0, min_eta_s=0.0,
                      inflight_per_source=1,
                      priors={"shm:1": 1.0, "shm:0": 1e9,
                              "shm:2": 1e9, "shm:3": 1e9})
    got, st = load_bytes(plan, src, verify=True, sched=cfg)
    np.testing.assert_array_equal(got, want)
    assert st.rerouted_members == (1,)
    assert st.parity_rerouted_bytes > 0
    assert set(st.crc_members) == {0, 2, 3}
    assert src._calls <= 1                   # the dead member: one touch max


def test_death_without_parity_budget_raises_sourcelost(views):
    """mode="steal" has no parity-alternative routing: a member dying
    mid-read surfaces SourceLost, and the ladder-style re-plan with that
    member marked failed recovers byte-identically (fresh sink)."""
    g, vs = views
    want = _oracle(vs, 4, g.total_bytes)
    src = DyingSource(ShmSource(vs, 1), die_node=3, allow=1)
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="steal", chunk_bytes=512,
                      inflight_per_source=1)
    with pytest.raises(SourceLost) as ei:
        load_bytes(plan, src, verify=True, sched=cfg)
    assert ei.value.node == 3
    # demote-and-replan, exactly what _load_with_demotion does
    plan2 = build_plan(4, g.total_bytes, failed=3)
    got, st = load_bytes(plan2, ShmSource(vs, 1), verify=True, sched=cfg)
    np.testing.assert_array_equal(got, want)
    assert st.decoded_bytes > 0


# --------------------------------------------------- hedged duplicate reads
def test_hedged_reads_never_double_write(views):
    """Aggressive hedging (every running chunk is hedge-eligible almost
    immediately) against a uniformly slow source: claims are CAS-style,
    so the audited sink must never see overlapping writes and the result
    stays byte-identical."""
    g, vs = views
    want = _oracle(vs, 4, g.total_bytes)
    slow = ThrottledSource(ShmSource(vs, 1),
                           {i: 2_000_000.0 for i in range(4)})
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="adaptive", chunk_bytes=2048,
                      hedge_factor=0.001, max_hedges=64,
                      reroute_factor=10 ** 9)   # isolate hedging
    sink = AuditedSink(g.total_bytes)
    sched = ChunkScheduler(plan, slow, sink, verify=True, cfg=cfg,
                           stats=LoadStats())
    st = sched.run()
    np.testing.assert_array_equal(sink.buf, want)
    assert st.hedged_reads > 0
    assert st.hedged_wins <= st.hedged_reads
    # every plan byte written exactly once
    assert sum(b - a for a, b in sink.extents) == plan.read_bytes


# -------------------------------------------------- elastic + facade paths
def test_elastic_reshard_through_stealing_path(views):
    """n->m member-shard need (the elastic restore read pattern) through
    the gather/steal path matches the oracle on every needed byte."""
    g, vs = views
    m = 2
    for member in range(m):
        need = member_shard_need(m, member, g.total_bytes)
        want = _oracle(vs, 4, g.total_bytes, need=need)
        cfg = SchedConfig(mode="adaptive", chunk_bytes=700)
        st = LoadStats()
        got = restore_bytes(vs, 4, g.total_bytes, 1, need=need,
                            stats=st, sched=cfg)
        np.testing.assert_array_equal(got, want)
        assert st.bytes_needed == sum(b - a for a, b in need)


def test_restore_state_end_to_end_with_scheduler(group):
    """Facade path: restore_state(sched=...) after a real node failure —
    planned decode runs pipelined with reads, tree is exact, and the
    span-based timing attribution is self-consistent."""
    g, state = group
    g.snapshot(state, 1)
    g.inject_node_failure(2)
    alive = [0, 1, 3]
    st = LoadStats()
    cfg = SchedConfig(mode="adaptive", chunk_bytes=1024)
    tree, step, _ = restore_state(g.run, 4, g.total_bytes, state, alive,
                                  stats=st, sched=cfg)
    assert step == 1 and trees_equal(tree, state)
    assert st.sched == "adaptive"
    assert st.decoded_bytes > 0
    assert st.read_seconds >= 0 and st.decode_seconds >= 0
    assert st.overlap_seconds <= st.read_seconds + 1e-9
    assert st.overlap_seconds <= st.decode_seconds + 1e-9
    busy = st.read_seconds + st.decode_seconds - st.overlap_seconds
    assert busy <= st.wall_seconds + 0.25


def test_fcfs_mode_runs_legacy_executor(views):
    g, vs = views
    plan = build_plan(4, g.total_bytes)
    got, st = load_bytes(plan, ShmSource(vs, 1), verify=True,
                         sched=SchedConfig(mode="fcfs"))
    np.testing.assert_array_equal(got, _oracle(vs, 4, g.total_bytes))
    assert st.sched == "fcfs"
    assert st.stolen_chunks == 0 and st.rerouted_members == ()


# ------------------------------------------------------ restore_bw_limit
def test_restore_bw_limit_charges_every_read(views):
    """A non-zero restore_bw_limit routes all reads through a token
    bucket; a spy bucket must see every direct byte charged."""
    g, vs = views

    class SpyBucket:
        def __init__(self):
            self.consumed = 0
            self._lock = threading.Lock()

        def consume(self, n):
            with self._lock:
                self.consumed += n

    bucket = SpyBucket()
    src = BucketedSource(ShmSource(vs, 1), bucket)
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="steal", chunk_bytes=2048)
    got, st = load_bytes(plan, src, verify=True, sched=cfg)
    np.testing.assert_array_equal(got, _oracle(vs, 4, g.total_bytes))
    assert bucket.consumed == st.bytes_read > 0


def test_restore_bw_limit_wraps_and_stays_correct(views):
    """execute_plan itself wraps the source when the config carries a
    limit — correctness (and verification) are unaffected."""
    g, vs = views
    plan = build_plan(4, g.total_bytes)
    cfg = SchedConfig(mode="adaptive", chunk_bytes=2048,
                      restore_bw_limit=1 << 30)   # huge: no real throttle
    got, st = load_bytes(plan, ShmSource(vs, 1), verify=True, sched=cfg)
    np.testing.assert_array_equal(got, _oracle(vs, 4, g.total_bytes))
    assert st.crc_members == (0, 1, 2, 3)


# ------------------------------------------------------- verification edges
def test_corrupt_stripe_detected_on_rerouted_members_sticky_blocks(views):
    """A rerouted member's directly-read ("sticky") blocks are verified
    against the per-stripe digest table — corruption there must still
    raise CrcMismatch even though crc_own can no longer be folded."""
    g, vs = views
    plan = build_plan(4, g.total_bytes)
    bs = raim5.block_size(g.total_bytes, 4)

    class CorruptFirstBlock:
        """Node 1 serves a flipped byte inside block 0, slowly."""
        kind = "shm"

        def __init__(self, inner):
            self._inner = inner

        def nodes(self):
            return self._inner.nodes()

        def meta(self, node):
            return self._inner.meta(node)

        def read_local(self, node, lo, hi):
            import time
            data = self._inner.read_local(node, lo, hi)
            if node == 1:
                time.sleep(0.02)
                if lo < bs:                      # inside block 0
                    data = data.copy()
                    data[0] ^= 0xFF
            return data

        def read_block_range(self, node, stripe, index, o1, o2):
            return self._inner.read_block_range(node, stripe, index, o1, o2)

        def read_parity_range(self, stripe, o1, o2):
            return self._inner.read_parity_range(stripe, o1, o2)

    cfg = SchedConfig(mode="adaptive", chunk_bytes=512, min_samples=1,
                      reroute_factor=1.0, inflight_per_source=1)
    with pytest.raises(CrcMismatch) as ei:
        load_bytes(plan, CorruptFirstBlock(ShmSource(vs, 1)),
                   verify=True, sched=cfg)
    assert ei.value.node == 1


def test_tier3_file_restore_through_scheduler(tmp_path):
    """Byte-identity holds for tier-3 `.reft` family restores routed
    through the chunk scheduler (FileSource, full verify)."""
    from repro.api import CheckpointSession, CheckpointSpec
    from repro.core.recovery import restore_from_checkpoint
    template = small_state(9)
    state = jax.tree.map(
        lambda x: x + 1 if x.dtype != jnp.uint32 else x, template)
    spec = CheckpointSpec(backend="reft", ckpt_dir=str(tmp_path),
                          sg_size=4, resume=False)
    with CheckpointSession(spec, template) as sess:
        assert sess.snapshot(state, 2, wait=True)
        assert sess.persist() == 2
    st = LoadStats()
    cfg = SchedConfig(mode="adaptive", chunk_bytes=1024)
    tree, step, _ = restore_from_checkpoint(str(tmp_path), 4, template,
                                            stats=st, sched=cfg)
    assert step == 2 and trees_equal(tree, state)
    assert st.sched == "adaptive" and st.source == "file"
    assert st.crc_members == (0, 1, 2, 3)


def test_tier4_objstore_restore_through_scheduler(tmp_path):
    """The ladder's fourth rung (ranged remote reads) also routes through
    the scheduler when the spec opts in via `restore_sched`."""
    import glob
    import os
    from repro.api import CheckpointSpec
    template = small_state(11)
    spec = CheckpointSpec(backend="objstore", ckpt_dir=str(tmp_path),
                          sg_size=2, resume=False,
                          options={"scrub_every_s": 0.0,
                                   "restore_sched": "adaptive"})
    ck = spec.build(template)
    try:
        assert ck.snapshot(template, 7, wait=True)
        assert ck.persist(wait=True) == 7
        for p in glob.glob(os.path.join(str(tmp_path), "*.reft")):
            os.unlink(p)
        ck.inject_failure(0, "node")
        ck.inject_failure(1, "node")
        res = ck.restore()
        assert res.tier == "objstore" and res.load.source == "object"
        assert res.load.sched == "adaptive"
        assert trees_equal(res.state, template)
    finally:
        ck.close()


def test_gather_partial_plan_through_scheduler(views):
    """Partial-need plans (no full-member verify stream) run through the
    gather tiling and stay byte-identical on the needed ranges."""
    g, vs = views
    state = small_state()
    spec = make_flat_spec(state)
    from repro.core.loader import need_for_leaves
    need = need_for_leaves(spec, ("w",))
    plan = build_plan(4, g.total_bytes, need=need)
    cfg = SchedConfig(mode="steal", chunk_bytes=300)
    got, st = load_bytes(plan, ShmSource(vs, 1), verify=False, sched=cfg)
    want = _oracle(vs, 4, g.total_bytes, need=need)
    for a, b in plan.need:
        np.testing.assert_array_equal(got[a:b], want[a:b])
    assert st.bytes_read < g.total_bytes
