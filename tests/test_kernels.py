"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""
import zlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import (encode_bucket, ssd_scan, swa_attention,
                           xor_parity_decode, xor_parity_encode)
from repro.kernels.ref import (encode_bucket_ref, ssd_scan_ref,
                               swa_attention_ref, xor_reduce_ref)
from repro.kernels.xor_parity import xor_reduce


# ------------------------------------------------------------ xor_parity
@pytest.mark.parametrize("k", [2, 3, 5, 7])
@pytest.mark.parametrize("n", [128, 384, 4096, 65536])
def test_xor_reduce_sweep(k, n):
    rng = np.random.default_rng(k * n)
    blocks = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(k, n), dtype=np.uint64)
        .astype(np.uint32))
    out = xor_reduce(blocks)
    assert bool(jnp.all(out == xor_reduce_ref(blocks)))


@pytest.mark.parametrize("n", [1, 7, 127, 129, 255, 4097])
def test_xor_reduce_odd_sizes_padded_tile(n):
    """Satellite fix: an odd lane count degrades to a zero-padded
    128-lane tile, not a be=1 one-element-per-grid-cell grind (and the
    interpret default now comes from the JAX backend — no explicit
    flag here)."""
    rng = np.random.default_rng(n)
    blocks = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(3, n), dtype=np.uint64)
        .astype(np.uint32))
    out = xor_reduce(blocks)
    assert out.shape == (n,)
    assert bool(jnp.all(out == xor_reduce_ref(blocks)))


# ----------------------------------------------------- stage encode kernel
@pytest.mark.parametrize("crc_impl", ["pallas", "jnp"])
@pytest.mark.parametrize("nbytes", [4, 5, 7, 100, 1001, 4096])
def test_encode_bucket_crc_matches_zlib(crc_impl, nbytes):
    rng = np.random.default_rng(nbytes)
    npad = -(-nbytes // 512) * 512
    data = np.zeros(npad, np.uint8)
    data[:nbytes] = rng.integers(0, 256, nbytes, dtype=np.uint8)
    lanes = jax.lax.bitcast_convert_type(
        jnp.asarray(data).reshape(-1, 4), jnp.uint32).reshape(1, -1)
    out, crc = encode_bucket(lanes, nbytes=nbytes, crc_impl=crc_impl)
    assert int(crc[0]) == zlib.crc32(data[:nbytes].tobytes())
    assert np.array_equal(np.asarray(out).view(np.uint8), data)


@pytest.mark.parametrize("k", [2, 3, 5])
def test_encode_bucket_xor_fold_matches_ref(k):
    rng = np.random.default_rng(k)
    blocks = rng.integers(0, 2 ** 32, (k, 256), dtype=np.uint64) \
        .astype(np.uint32)
    out, crc = encode_bucket(jnp.asarray(blocks), nbytes=1024,
                             want_crc=True)
    ref, ref_crc = encode_bucket_ref(blocks, 1024)
    assert np.array_equal(np.asarray(out), ref)
    assert int(crc[0]) == ref_crc
    # parity callers skip the (sequential) CRC
    out2, crc2 = encode_bucket(jnp.asarray(blocks), nbytes=1024,
                               want_crc=False)
    assert np.array_equal(np.asarray(out2), ref)
    assert int(crc2[0]) == 0


@pytest.mark.parametrize("nbytes", [(1 << 20) + 13, 4 << 20])
def test_encode_bucket_tiled_large_matches_zlib(nbytes):
    """Satellite: buckets past MAX_CELL_LANES tile over a grid (each cell
    checksums only its slice) and the per-tile digests recombine via
    crc32_combine into exactly zlib's answer."""
    from repro.kernels.stage import (LANE_BYTES, MAX_CELL_LANES, bucket_crc,
                                     resolve_tile_lanes)
    rng = np.random.default_rng(nbytes)
    npad = -(-nbytes // LANE_BYTES) * LANE_BYTES
    data = np.zeros(npad, np.uint8)
    data[:nbytes] = rng.integers(0, 256, nbytes, dtype=np.uint8)
    lanes = jax.lax.bitcast_convert_type(
        jnp.asarray(data).reshape(-1, 4), jnp.uint32).reshape(1, -1)
    assert lanes.shape[1] > MAX_CELL_LANES          # really tiled
    assert resolve_tile_lanes(lanes.shape[1]) is not None
    out, crc = encode_bucket(lanes, nbytes=nbytes)
    assert np.asarray(crc).size > 1                 # per-tile digests
    assert bucket_crc(crc, nbytes) == zlib.crc32(data[:nbytes].tobytes())
    assert np.array_equal(np.asarray(out).view(np.uint8), data)
    # explicit tile width: same answer through a different tiling
    out2, crc2 = encode_bucket(lanes, nbytes=nbytes, tile_lanes=1 << 14)
    assert np.asarray(crc2).size != np.asarray(crc).size
    assert bucket_crc(crc2, nbytes, tile_lanes=1 << 14) \
        == zlib.crc32(data[:nbytes].tobytes())
    # folding an explicit tiling WITHOUT tile_lanes must refuse, not
    # silently combine wrong per-part lengths
    with pytest.raises(AssertionError):
        bucket_crc(crc2, nbytes)


def test_encode_bucket_tiled_xor_fold():
    from repro.kernels.stage import bucket_crc
    rng = np.random.default_rng(3)
    k, n = 3, 1 << 17                               # > MAX_CELL_LANES
    blocks = rng.integers(0, 2 ** 32, (k, n), dtype=np.uint64) \
        .astype(np.uint32)
    out, crc = encode_bucket(jnp.asarray(blocks), nbytes=4 * n)
    ref = blocks[0] ^ blocks[1] ^ blocks[2]
    assert np.array_equal(np.asarray(out), ref)
    assert bucket_crc(crc, 4 * n) == zlib.crc32(ref.tobytes())


def test_crc32_combine_matches_zlib():
    from repro.core.crcutil import crc32_combine, crc32_concat
    rng = np.random.default_rng(0)
    parts = [rng.integers(0, 256, n, dtype=np.uint8).tobytes()
             for n in (0, 1, 3, 100, 4096, 65537)]
    whole = b"".join(parts)
    crc = crc32_concat((zlib.crc32(p), len(p)) for p in parts)
    assert crc == zlib.crc32(whole)
    assert crc32_combine(0, zlib.crc32(b"x"), 1) == zlib.crc32(b"x")


@pytest.mark.parametrize("nbytes", [1, 7, 100, 1000, 4096, 100001])
def test_xor_parity_bytes_roundtrip(nbytes):
    rng = np.random.default_rng(nbytes)
    blocks = rng.integers(0, 256, size=(4, nbytes), dtype=np.uint8)
    parity = np.asarray(xor_parity_encode(jnp.asarray(blocks)))
    np.testing.assert_array_equal(
        parity, blocks[0] ^ blocks[1] ^ blocks[2] ^ blocks[3])
    for missing in range(4):
        surv = np.delete(blocks, missing, axis=0)
        rec = np.asarray(xor_parity_decode(jnp.asarray(surv),
                                           jnp.asarray(parity)))
        np.testing.assert_array_equal(rec, blocks[missing])


# ------------------------------------------------------------- ssd_scan
@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 64, 4, 8, 16, 16),
    (1, 256, 2, 64, 128, 128),
    (2, 128, 3, 32, 64, 32),
    (1, 96, 1, 16, 32, 48),       # non-power-of-two chunking
])
def test_ssd_scan_sweep(B, S, H, P, N, Q):
    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 5)
    u = jax.random.normal(ks[0], (B, S, H, P))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    h0 = jax.random.normal(ks[4], (B, H, P, N))
    yk, hk = ssd_scan(u, a, Bm, Cm, h0, chunk=Q)
    yr, hr = ssd_scan_ref(u, a, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               atol=5e-4, rtol=1e-3)


def test_ssd_scan_bf16_inputs():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    B, S, H, P, N = 1, 64, 2, 16, 32
    u = jax.random.normal(ks[0], (B, S, H, P), jnp.bfloat16)
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N), jnp.bfloat16)
    Cm = jax.random.normal(ks[3], (B, S, N), jnp.bfloat16)
    yk, hk = ssd_scan(u.astype(jnp.float32), a, Bm.astype(jnp.float32),
                      Cm.astype(jnp.float32), chunk=16)
    yr, hr = ssd_scan_ref(u.astype(jnp.float32), a, Bm.astype(jnp.float32),
                          Cm.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-2,
                               rtol=2e-2)


# -------------------------------------------------------- swa_attention
@pytest.mark.parametrize("B,S,KV,G,hd,w,causal", [
    (2, 128, 2, 3, 16, None, True),
    (1, 256, 2, 2, 64, 37, True),
    (2, 128, 1, 4, 32, 64, False),
    (1, 512, 2, 1, 16, 128, True),
    (1, 128, 4, 1, 8, 1, True),       # degenerate window
])
def test_swa_attention_sweep(B, S, KV, G, hd, w, causal):
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    o = swa_attention(q, k, v, window=w, causal=causal,
                      block_q=64, block_k=32)
    r = swa_attention_ref(q, k, v, window=(w or 1 << 30), causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=2e-5, rtol=1e-4)


def test_swa_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 2, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.bfloat16)
    o = swa_attention(q, k, v, window=32, block_q=64, block_k=64)
    r = swa_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), window=32)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r),
                               atol=3e-2, rtol=3e-2)


def test_swa_skips_out_of_band_blocks_same_result():
    """Band skipping is an optimization, never a semantic change."""
    from repro.models.flash import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, KV, G, hd, w = 1, 256, 1, 2, 16, 32
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = flash_attention(q, k, v, window=jnp.int32(w), block_q=64,
                           block_k=32)
    band = flash_attention(q, k, v, window=jnp.int32(w), block_q=64,
                           block_k=32, band=w)
    np.testing.assert_allclose(np.asarray(full), np.asarray(band),
                               atol=1e-5, rtol=1e-5)
