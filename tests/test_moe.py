"""MoE dispatch vs a per-expert python-loop oracle, including the capacity
drop rule (tokens sorted stably by expert; first C per expert kept)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
import dataclasses

from repro.models.moe import _capacity, init_moe, moe_ffn


def oracle(p, cfg, x):
    """Straightforward python/numpy reimplementation."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(T, k, E, cfg.capacity_factor)
    xf = np.asarray(x, np.float32).reshape(T, D)
    logits = xf @ np.asarray(p["router"], np.float32)
    ex = np.exp(logits - logits.max(-1, keepdims=True))
    probs = ex / ex.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1, kind="stable")
    sel = order[:, :k]
    w = np.take_along_axis(probs, sel, axis=-1)
    w = w / w.sum(-1, keepdims=True)

    # stable sort of (token,slot) pairs by expert -> rank within expert
    eids = sel.reshape(-1)
    sort_order = np.argsort(eids, kind="stable")
    rank = np.zeros(T * k, np.int64)
    counts = {}
    for pos in sort_order:
        e = eids[pos]
        rank[pos] = counts.get(e, 0)
        counts[e] = rank[pos] + 1

    y = np.zeros((T, D), np.float32)
    wg = np.asarray(p["wi_gate"], np.float32)
    wu = np.asarray(p["wi_up"], np.float32)
    wo = np.asarray(p["wo"], np.float32)
    for t in range(T):
        for j in range(k):
            flat = t * k + j
            e = sel[t, j]
            if rank[flat] >= C:
                continue                      # dropped
            h = xf[t] @ wg[e]
            h = h / (1 + np.exp(-h)) * (xf[t] @ wu[e])
            y[t] += w[t, j] * (h @ wo[e])
    return y.reshape(B, S, D)


@pytest.mark.parametrize("cf", [8.0, 0.5])   # drop-free and heavy-drop
def test_moe_matches_oracle(cf):
    base = get_config("dbrx-132b").reduced()
    cfg = dataclasses.replace(base, capacity_factor=cf)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    y, aux = moe_ffn(p, cfg, x)
    ref = oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y, np.float32), ref,
                               atol=2e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_top1_and_many_experts():
    base = get_config("kimi-k2-1t-a32b").reduced()
    cfg = dataclasses.replace(base, num_experts=4, experts_per_token=1,
                              capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))
    y, _ = moe_ffn(p, cfg, x)
    ref = oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y, np.float32), ref,
                               atol=2e-4, rtol=1e-3)


def test_moe_grads_flow_through_router():
    cfg = get_config("dbrx-132b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))

    def loss(p):
        y, aux = moe_ffn(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["wi_gate"]))) > 0
