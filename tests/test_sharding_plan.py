"""Mesh -> sharding-group plan invariants."""
import pytest
from hypothesis import given, strategies as st

from repro.core.sharding_plan import build_plan, plan_summary


def test_production_mesh_plan_shape():
    plans = build_plan(10 ** 9, data=16, model=16, pods=1, chips_per_host=4)
    s = plan_summary(plans)
    assert s["hosts"] == 64 and s["sgs"] == 4 and s["sg_size"] == 16
    # each host saves ~2 * slice/n bytes (own shard + parity stripe)
    slice_bytes = 10 ** 9 / 4
    assert s["max_snapshot_bytes_per_host"] < 2.2 * slice_bytes / 16


def test_multi_pod_multiplies_sgs_not_size():
    p1 = plan_summary(build_plan(10 ** 8, pods=1))
    p2 = plan_summary(build_plan(10 ** 8, pods=2))
    assert p2["sgs"] == 2 * p1["sgs"]
    assert p2["sg_size"] == p1["sg_size"]


@given(total=st.integers(1, 10 ** 7),
       data=st.sampled_from([2, 4, 8, 16]),
       model=st.sampled_from([4, 8, 16]),
       pods=st.sampled_from([1, 2]))
def test_every_byte_protected(total, data, model, pods):
    """Union of all members' OWN data blocks covers each SG slice exactly;
    ranges never cross slice boundaries."""
    from repro.core import raim5
    plans = build_plan(total, data=data, model=model, pods=pods,
                       chips_per_host=4)
    slices = {}
    for p in plans.values():
        if p.slice_hi > p.slice_lo:
            slices.setdefault(p.sg_id, (p.slice_lo, p.slice_hi))
        for a, b in p.snapshot_ranges:
            assert p.slice_lo <= a <= b <= p.slice_hi
    # coverage: own-block ranges (first n-1 ranges) across members tile slice
    for sg, (lo, hi) in slices.items():
        if pods > 1 and sg[0] > 0:
            continue
        members = sorted((p for p in plans.values() if p.sg_id == sg),
                         key=lambda p: p.member)
        covered = set()
        for p in members:
            own = p.snapshot_ranges[:p.sg_size - 1] if p.sg_size > 1 \
                else p.snapshot_ranges
            for a, b in own:
                covered.update(range(a - lo, b - lo))
        assert covered == set(range(hi - lo))
