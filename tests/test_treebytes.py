"""Flat byte-stream <-> pytree roundtrip properties (hypothesis)."""
import numpy as np
from hypothesis import given, strategies as st

import jax

from repro.core.treebytes import (
    buffer_to_tree, crc32_of, iter_buckets, make_flat_spec, tree_to_buffer,
    FlatSpec,
)

_DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8,
           np.float16]


@st.composite
def pytrees(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    n_leaves = draw(st.integers(1, 8))
    out = {}
    for i in range(n_leaves):
        dt = _DTYPES[draw(st.integers(0, len(_DTYPES) - 1))]
        ndim = draw(st.integers(0, 3))
        shape = tuple(draw(st.integers(1, 7)) for _ in range(ndim))
        arr = (rng.standard_normal(shape) * 100).astype(dt) \
            if np.issubdtype(dt, np.floating) else \
            rng.integers(0, 100, size=shape).astype(dt)
        key = f"leaf{i}"
        if draw(st.booleans()):
            out.setdefault("nested", {})[key] = arr
        else:
            out[key] = arr
    return out


@given(tree=pytrees())
def test_roundtrip_bitexact(tree):
    spec = make_flat_spec(tree)
    buf = np.zeros(spec.total_bytes, np.uint8)
    tree_to_buffer(tree, spec, buf)
    rec = buffer_to_tree(tree, spec, buf)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(rec)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == b.dtype


@given(tree=pytrees(), lo_frac=st.floats(0, 1), hi_frac=st.floats(0, 1))
def test_partial_ranges_compose(tree, lo_frac, hi_frac):
    spec = make_flat_spec(tree)
    t = spec.total_bytes
    full = np.zeros(t, np.uint8)
    tree_to_buffer(tree, spec, full)
    cut = int(min(lo_frac, hi_frac) * t)
    a = np.zeros(cut, np.uint8)
    b = np.zeros(t - cut, np.uint8)
    tree_to_buffer(tree, spec, a, 0, cut)
    tree_to_buffer(tree, spec, b, cut, t)
    np.testing.assert_array_equal(np.concatenate([a, b]), full)


@given(total=st.integers(1, 10000), bucket=st.integers(1, 4096))
def test_iter_buckets_cover_exactly(total, bucket):
    ranges = list(iter_buckets(0, total, bucket))
    assert ranges[0][0] == 0 and ranges[-1][1] == total
    for (a1, b1), (a2, b2) in zip(ranges, ranges[1:]):
        assert b1 == a2
    assert all(b - a <= bucket for a, b in ranges)


def test_spec_json_roundtrip():
    tree = {"a": np.ones((3, 4), np.float32), "b": np.int64(7)}
    spec = make_flat_spec(tree)
    spec2 = FlatSpec.from_json(spec.to_json())
    assert spec2 == spec


def test_jax_and_numpy_leaves_equivalent():
    import jax.numpy as jnp
    t_np = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    t_jx = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
    s1, s2 = make_flat_spec(t_np), make_flat_spec(t_jx)
    b1 = np.zeros(s1.total_bytes, np.uint8)
    b2 = np.zeros(s2.total_bytes, np.uint8)
    tree_to_buffer(t_np, s1, b1)
    tree_to_buffer(t_jx, s2, b2)
    np.testing.assert_array_equal(b1, b2)
    assert crc32_of(b1) == crc32_of(b2)
