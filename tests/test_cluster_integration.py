"""Real-process failure injection: SIGKILL trainers/SMPs, unlink shared
memory, recover bit-exact (the paper's §6 restart experiment in miniature).
"""
import numpy as np
import pytest

from repro.core.cluster import LocalCluster


def bitexact(a, b):
    import jax
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture
def cluster(tmp_path):
    c = LocalCluster(4, seed=11, nbytes=1 << 15, snapshot_every=1,
                     ckpt_dir=str(tmp_path))
    yield c
    c.close()


def test_software_failure_inmemory_resume(cluster):
    c = cluster
    c.run_rounds(4)
    c.kill_trainer(2)                       # SIGKILL; SMP orphaned alive
    state, step, tier = c.recover()
    assert tier == "in-memory" and step == 4
    assert bitexact(state, c.expected_state(step))
    c.restart_node(2, state)
    c.run_rounds(2)                         # cluster proceeds healthily
    assert c.nodes[2].last_step == 6


def test_node_failure_raim5_decode(cluster):
    c = cluster
    c.run_rounds(3)
    c.kill_node(1)                          # trainer+SMP dead, memory wiped
    state, step, tier = c.recover()
    assert tier == "raim5" and step == 3
    assert bitexact(state, c.expected_state(step))


def test_double_failure_falls_back_to_ckpt(cluster):
    c = cluster
    c.run_rounds(3)
    c.checkpoint()
    c.run_rounds(2)
    c.kill_node(0)
    c.kill_node(3)
    state, step, tier = c.recover()
    assert tier == "checkpoint" and step == 3     # ckpt taken at step 3
    assert bitexact(state, c.expected_state(step))


def test_smp_only_crash_keeps_training(cluster):
    """SMP dies but trainer lives: training continues; protection is
    degraded until heal (we just assert no training disruption)."""
    c = cluster
    c.run_rounds(2)
    c.kill_smp(3)
    c.run_rounds(2)                          # rounds still complete
    assert all(np_.last_step == 4 for np_ in c.nodes.values())
