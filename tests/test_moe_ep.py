"""Expert-parallel MoE (shard_map) vs the GSPMD baseline — bit-identical
outputs on a real multi-device mesh (8 forced CPU devices, subprocess so
the device-count flag can't leak into other tests)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_ffn_gspmd, moe_ffn_ep

    from repro.dist.api import use_mesh

    cfg = get_config("dbrx-132b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    at = getattr(jax.sharding, "AxisType", None)
    kw = {"axis_types": (at.Auto,) * 2} if at is not None else {}
    mesh = jax.make_mesh((2, 4), ("data", "model"), **kw)
    y_ref, _ = moe_ffn_gspmd(p, cfg, x)
    with use_mesh(mesh):
        y_ep, _ = jax.jit(lambda p, x: moe_ffn_ep(p, cfg, x))(p, x)
        cfg2 = dataclasses.replace(cfg, fsdp=True)
        y_fs, _ = jax.jit(lambda p, x: moe_ffn_ep(p, cfg2, x))(p, x)
    assert float(jnp.max(jnp.abs(y_ep - y_ref))) < 1e-5
    assert float(jnp.max(jnp.abs(y_fs - y_ref))) < 1e-5
    print("EP_OK")
""")


def test_moe_ep_matches_gspmd_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "EP_OK" in out.stdout, out.stderr[-2000:]


def test_moe_ep_falls_back_without_mesh():
    """No mesh context -> EP path silently equals the baseline."""
    import jax
    import jax.numpy as jnp
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_ffn

    cfg = dataclasses.replace(get_config("dbrx-132b").reduced(),
                              moe_ep=True)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))
    y, aux = moe_ffn(p, cfg, x)
    assert y.shape == x.shape and bool(jnp.isfinite(aux))
