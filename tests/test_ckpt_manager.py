"""Checkpoint retention manager: completeness, manifest, GC."""
import os

import pytest

from repro.ckpt.manager import CheckpointManager, scan_shards


def _touch(d, step, node):
    with open(os.path.join(d, f"step-{step}-node-{node}.reft"), "wb") as f:
        f.write(b"x")


def test_complete_steps_and_latest(tmp_path):
    d = str(tmp_path)
    m = CheckpointManager(d, 3, keep=2)
    for s in (1, 2):
        for n in range(3):
            _touch(d, s, n)
    _touch(d, 3, 0)                  # torn checkpoint (1 of 3 shards)
    assert m.complete_steps() == [1, 2]
    assert m.latest() == 2


def test_commit_gc_keeps_latest_k(tmp_path):
    d = str(tmp_path)
    m = CheckpointManager(d, 2, keep=2)
    for s in (1, 2, 3, 4):
        for n in range(2):
            _touch(d, s, n)
    _touch(d, 2, 0)  # no-op overwrite
    manifest = m.commit()
    assert manifest["complete_steps"] == [3, 4]
    assert set(scan_shards(d)) == {3, 4}
    assert m.read_manifest()["complete_steps"] == [3, 4]


def test_torn_old_checkpoints_are_gced(tmp_path):
    d = str(tmp_path)
    m = CheckpointManager(d, 2, keep=1)
    for n in range(2):
        _touch(d, 5, n)
    _touch(d, 3, 1)                  # torn + older than kept
    m.commit()
    assert set(scan_shards(d)) == {5}


def test_torn_new_families_no_longer_leak(tmp_path):
    """Regression: torn families at steps >= the newest kept step used to
    survive GC forever.  Only the single newest torn family (possibly an
    in-flight persist) may remain."""
    d = str(tmp_path)
    m = CheckpointManager(d, 2, keep=2)
    for s in (4, 5):
        for n in range(2):
            _touch(d, s, n)
    _touch(d, 6, 0)                  # crashed partial checkpoint
    _touch(d, 7, 1)                  # torn family that may be in flight
    m.commit()
    # complete 4,5 kept; torn 6 GC'd; only the newest torn (7) spared
    assert set(scan_shards(d)) == {4, 5, 7}
    m.commit()                       # idempotent: 7 still newest torn
    assert set(scan_shards(d)) == {4, 5, 7}


def test_inflight_persists_are_gc_exempt(tmp_path):
    """Async persists register their step before any shard lands: the
    growing (torn) families must survive every commit until resolved —
    even when several are in the air at once (the newest-torn spare
    alone would sacrifice all but one)."""
    d = str(tmp_path)
    m = CheckpointManager(d, 2, keep=1)
    for n in range(2):
        _touch(d, 5, n)
    m.register_inflight(6)
    m.register_inflight(7)
    _touch(d, 6, 0)                  # both in-flight families are torn
    _touch(d, 7, 0)
    m.commit()
    assert set(scan_shards(d)) == {5, 6, 7}
    assert m.latest() == 5           # a registered step is never reported
    _touch(d, 6, 1)                  # family 6 completes...
    m.resolve_inflight(6)
    _touch(d, 7, 1)
    m.resolve_inflight(7)
    m.commit()                       # ...and normal keep-1 retention resumes
    assert set(scan_shards(d)) == {7}
    assert m.latest() == 7


def test_integration_with_reft_group(tmp_path):
    import jax.numpy as jnp
    from repro.core import ReftConfig, ReftGroup
    state = {"w": jnp.ones((128,))}
    g = ReftGroup(2, state, ReftConfig(ckpt_dir=str(tmp_path),
                                       checkpoint_every_snapshots=10 ** 6))
    try:
        for s in (1, 2, 3):
            g.snapshot(state, s)
            g.checkpoint()
        m = CheckpointManager(str(tmp_path), 2, keep=2)
        manifest = m.commit()
        assert manifest["complete_steps"] == [2, 3]
        from repro.core.recovery import restore_from_checkpoint
        rec, step, _ = restore_from_checkpoint(str(tmp_path), 2, state)
        assert step == 3
    finally:
        g.close()
