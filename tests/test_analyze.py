"""Tests for repro.analyze: lint rules (positive / negative / pragma),
lockgraph ABBA + cycle detection, the SMP protocol model checker (real
table accepted, broken variants rejected), the runtime TraceValidator,
and the SMPHandle close idempotency the validator guards."""
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.analyze.lint import RULES, lint_source
from repro.analyze.lockgraph import (LockOrderViolation, LockTracer,
                                     TracedCondition, TracedLock,
                                     current_tracer, install,
                                     named_condition, named_lock, uninstall)
from repro.analyze.protocol import (FLIGHT_FSM, CheckConfig,
                                    ProtocolViolation, TraceValidator,
                                    model_check)

REPO = os.path.join(os.path.dirname(__file__), "..")


def rules_of(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------------- lint
class TestLintRules:
    def test_anz001_mutable_default_positive(self):
        src = "def f(x=[]):\n    return x\n"
        assert rules_of(lint_source(src)) == ["ANZ001"]
        src = "def f(x=dict()):\n    return x\n"
        assert rules_of(lint_source(src)) == ["ANZ001"]
        # the PR 1 bug class: one shared config instance per *import*
        src = "def f(cfg=ReftConfig()):\n    return cfg\n"
        assert rules_of(lint_source(src)) == ["ANZ001"]

    def test_anz001_dataclass_field_positive(self):
        src = ("from dataclasses import dataclass\n"
               "@dataclass\n"
               "class C:\n"
               "    xs: list = []\n")
        assert rules_of(lint_source(src)) == ["ANZ001"]

    def test_anz001_negative(self):
        src = ("from dataclasses import dataclass, field\n"
               "@dataclass\n"
               "class C:\n"
               "    xs: list = field(default_factory=list)\n"
               "    n: int = 3\n"
               "def f(x=None, y=(), z=3):\n"
               "    return x\n")
        assert lint_source(src) == []

    def test_anz001_pragma(self):
        src = "def f(x=[]):  # analyze: ok ANZ001\n    return x\n"
        sup = []
        assert lint_source(src, suppressed_out=sup) == []
        assert rules_of(sup) == ["ANZ001"]

    def test_anz002_blocking_under_lock_positive(self):
        src = ("def f(self):\n"
               "    with self._lock:\n"
               "        time.sleep(1)\n")
        assert "ANZ002" in rules_of(lint_source(src))
        src = ("def f(self):\n"
               "    with self._rx_lock:\n"
               "        msg = conn.recv()\n")
        assert "ANZ002" in rules_of(lint_source(src))

    def test_anz002_negative(self):
        # sleep outside the lock, and Condition.wait (which releases)
        src = ("def f(self):\n"
               "    with self._lock:\n"
               "        x = 1\n"
               "    time.sleep(1)\n"
               "    with self._cond:\n"
               "        self._cond.wait(1.0)\n")
        assert "ANZ002" not in rules_of(lint_source(src))

    def test_anz002_pragma(self):
        src = ("def f(self):\n"
               "    with self._lock:\n"
               "        # analyze: ok ANZ002\n"
               "        time.sleep(1)\n")
        assert "ANZ002" not in rules_of(lint_source(src))

    def test_anz003_send_outside_lock_positive(self):
        src = "def f(conn):\n    conn.send(('x',))\n"
        assert rules_of(lint_source(src)) == ["ANZ003"]

    def test_anz003_negative(self):
        src = ("def f(self):\n"
               "    with self._tx_lock:\n"
               "        self._conn.send(('x',))\n")
        assert lint_source(src) == []
        # non-pipe receivers are not flagged
        src = "def f(sock_like):\n    requests.send(x)\n"
        assert lint_source(src) == []

    def test_anz003_pragma(self):
        src = "def f(conn):\n    conn.send(('x',))  # analyze: ok ANZ003\n"
        assert lint_source(src) == []

    def test_anz004_tmp_without_finally_positive(self):
        src = ("def f(path):\n"
               "    tmp = path + '.tmp'\n"
               "    with open(tmp, 'w') as fh:\n"
               "        fh.write('x')\n")
        assert "ANZ004" in rules_of(lint_source(src))

    def test_anz004_negative(self):
        src = ("def f(path):\n"
               "    tmp = path + '.tmp'\n"
               "    try:\n"
               "        with open(tmp, 'w') as fh:\n"
               "            fh.write('x')\n"
               "        os.replace(tmp, path)\n"
               "    finally:\n"
               "        try:\n"
               "            os.unlink(tmp)\n"
               "        except FileNotFoundError:\n"
               "            pass\n")
        assert "ANZ004" not in rules_of(lint_source(src))
        # reads don't leak partial files
        src = "def f(tmp):\n    with open(tmp, 'r') as fh:\n        fh.read()\n"
        assert "ANZ004" not in rules_of(lint_source(src))

    def test_anz004_pragma(self):
        src = ("def f(tmp):\n"
               "    fh = open(tmp, 'w')  # analyze: ok ANZ004\n")
        assert "ANZ004" not in rules_of(lint_source(src))

    def test_anz005_bare_except_positive(self):
        src = "try:\n    x()\nexcept:\n    pass\n"
        assert rules_of(lint_source(src)) == ["ANZ005"]

    def test_anz005_negative(self):
        src = "try:\n    x()\nexcept Exception:\n    pass\n"
        assert lint_source(src) == []

    def test_anz005_pragma(self):
        src = "try:\n    x()\nexcept:  # analyze: ok ANZ005\n    pass\n"
        assert lint_source(src) == []

    def test_anz006_nondeterminism_in_planner_positive(self):
        src = ("def plan_scenarios(seed):\n"
               "    return time.time()\n")
        assert rules_of(lint_source(src)) == ["ANZ006"]
        src = ("def plan_x(seed):\n"
               "    import uuid\n"
               "    return uuid.uuid4()\n")
        assert "ANZ006" in rules_of(lint_source(src))

    def test_anz006_negative(self):
        # seeded RNG is the *point*; and non-planner scope is exempt
        src = ("def plan_scenarios(seed):\n"
               "    rng = np.random.default_rng(seed)\n"
               "    return rng.random()\n"
               "def helper():\n"
               "    return time.time()\n")
        assert "ANZ006" not in rules_of(lint_source(src))

    def test_anz006_pragma(self):
        src = ("def plan_x(seed):\n"
               "    return time.time()  # analyze: ok ANZ006\n")
        assert "ANZ006" not in rules_of(lint_source(src))

    def test_anz007_sleep_in_loop_positive(self):
        src = ("def f():\n"
               "    while not done():\n"
               "        time.sleep(0.1)\n")
        assert rules_of(lint_source(src)) == ["ANZ007"]

    def test_anz007_negative(self):
        src = "def f():\n    time.sleep(0.1)\n"
        assert lint_source(src) == []

    def test_anz007_pragma_previous_line(self):
        src = ("def f():\n"
               "    while not done():\n"
               "        # analyze: ok ANZ007\n"
               "        time.sleep(0.1)\n")
        assert lint_source(src) == []

    def test_rule_catalog_is_complete(self):
        assert set(RULES) == {f"ANZ00{i}" for i in range(1, 8)}

    def test_repo_tree_is_clean(self):
        """Acceptance gate: the shipped tree has no unsuppressed findings
        and the bounded model check passes — same command CI runs."""
        r = subprocess.run(
            [sys.executable, "-m", "repro.analyze", "--strict", "src"],
            cwd=REPO, capture_output=True, text=True, timeout=300,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(REPO, "src")})
        assert r.returncode == 0, r.stderr


# -------------------------------------------------------------- lockgraph
class TestLockgraph:
    def test_consistent_order_passes(self):
        tr = LockTracer()
        a, b = TracedLock("A", tr), TracedLock("B", tr)

        def use():
            with a:
                with b:
                    pass
        t = threading.Thread(target=use)
        t.start()
        t.join()
        use()
        tr.check()            # no raise
        assert ("A", "B") in {tuple(e) for e in tr.summary()["edges"]}

    def test_abba_detected_eagerly(self):
        tr = LockTracer()
        a, b = TracedLock("A", tr), TracedLock("B", tr)
        with a:
            with b:
                pass
        # reversed order on another thread: the classic deadlock setup,
        # caught at acquisition without needing the actual interleaving
        def reversed_order():
            with b:
                with a:
                    pass
        t = threading.Thread(target=reversed_order)
        t.start()
        t.join()
        assert tr.violations and tr.violations[0]["kind"] == \
            "inconsistent-order"
        with pytest.raises(LockOrderViolation):
            tr.check()

    def test_three_lock_cycle(self):
        tr = LockTracer(keep_stacks=False)
        a, b, c = (TracedLock(n, tr) for n in "ABC")
        for first, second in ((a, b), (b, c), (c, a)):
            with first:
                with second:
                    pass
        assert tr.cycles()
        with pytest.raises(LockOrderViolation):
            tr.check()

    def test_condition_wait_releases_held_record(self):
        tr = LockTracer()
        cond = TracedCondition("C", tr)
        other = TracedLock("L", tr)
        done = threading.Event()

        def waiter():
            with cond:
                cond.wait(0.2)
            done.set()
        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.05)
        # while the waiter sleeps in wait(), C is NOT held: acquiring
        # C->L here must not create an L-after-C edge from its thread
        with cond:
            cond.notify_all()
        t.join()
        assert done.is_set()
        tr.check()

    def test_factories_plain_without_tracer(self):
        # restore any session-wide tracer afterwards (ANALYZE_LOCKGRAPH=1
        # runs must keep collecting their corpus after this test)
        prev = current_tracer()
        uninstall()
        try:
            lk = named_lock("x")
            assert isinstance(lk, type(threading.Lock()))
            assert isinstance(named_condition("x"), threading.Condition)
        finally:
            if prev is not None:
                install(prev)

    def test_factories_traced_with_tracer(self):
        prev = current_tracer()
        tr = install()
        try:
            lk = named_lock("smp.test")
            assert isinstance(lk, TracedLock)
            with lk:
                pass
            assert "smp.test" in tr.locks_seen
        finally:
            if prev is not None:
                install(prev)
            else:
                uninstall()


# --------------------------------------------------------- model checker
class TestModelChecker:
    def test_real_table_fully_explored_clean(self):
        res = model_check()
        assert res.complete
        assert res.ok, (res.violations[:2], res.wedges[:2])
        assert res.states > 1000        # genuinely exhaustive, not trivial
        assert res.transitions > res.states

    def test_unpin_before_pin_rejected(self):
        res = model_check(CheckConfig(variant="unpin-before-pin"))
        assert not res.ok
        kinds = " ".join(v["kind"] for v in res.violations)
        assert "double-unpin" in kinds
        # counterexamples carry a replayable action trace
        assert all(v["trace"] for v in res.violations)

    def test_begin_picks_latest_rejected(self):
        res = model_check(CheckConfig(variant="begin-picks-latest"))
        assert not res.ok
        assert any("latest" in v["kind"] for v in res.violations)

    def test_broken_fsm_wedges(self):
        # a table that forgets open->end can never publish a snapshot:
        # the checker reports the wedge (open flight, no enabled action)
        fsm = {k: v for k, v in FLIGHT_FSM.items()
               if k != ("open", "end") and k[1] != "stop"}
        res = model_check(CheckConfig(fsm=fsm, allow_death=False,
                                      allow_timeout=False,
                                      max_persists=0))
        assert res.wedges


# -------------------------------------------------------- trace validator
class TestTraceValidator:
    def run_happy_path(self, v):
        v.rx(("ready",))
        v.tx(("begin", 1))
        v.tx(("bucket", 0, 0, 0, 4096))
        v.tx(("end", 1, b"meta"))
        v.rx(("clean", 1))
        v.tx(("ping",))
        v.rx(("pong", 123.0))
        v.tx(("persist", 1, "/p", None, 0.0))
        v.rx(("persisted", 1, "/p", 1, {}))
        v.tx(("stop",))

    def test_happy_path_accepted(self):
        v = TraceValidator()
        self.run_happy_path(v)
        assert v.violations == []
        assert v.phase == "stopped"

    def test_broken_table_rejects_real_trace(self):
        fsm = {k: n for k, n in FLIGHT_FSM.items() if k != ("open", "end")}
        v = TraceValidator(fsm=fsm)
        with pytest.raises(ProtocolViolation):
            self.run_happy_path(v)

    def test_double_begin_rejected(self):
        v = TraceValidator()
        v.rx(("ready",))
        v.tx(("begin", 1))
        with pytest.raises(ProtocolViolation):
            v.tx(("begin", 2))

    def test_clean_desync_rejected(self):
        v = TraceValidator()
        v.rx(("ready",))
        v.tx(("begin", 1))
        v.tx(("end", 1, b""))
        with pytest.raises(ProtocolViolation):
            v.rx(("clean", 7))

    def test_unknown_persist_reply_rejected(self):
        v = TraceValidator()
        v.rx(("ready",))
        with pytest.raises(ProtocolViolation):
            v.rx(("persisted", 9, "/p", 1, {}))

    def test_stale_reply_tolerated(self):
        v = TraceValidator()
        v.rx(("ready",))
        v.tx(("persist", 1, "/p", None, 0.0))
        v.mark_stale(1)
        v.rx(("persisted", 1, "/p", 1, {}))      # late, discarded, legal
        assert v.violations == []

    def test_post_stop_persist_reply_tolerated(self):
        v = TraceValidator()
        v.rx(("ready",))
        v.tx(("persist", 1, "/p", None, 0.0))
        v.tx(("stop",))
        v.rx(("persisted", 1, "/p", 1, {}))      # drain during close
        assert v.violations == []

    def test_send_after_stop_rejected(self):
        v = TraceValidator()
        v.rx(("ready",))
        v.tx(("stop",))
        with pytest.raises(ProtocolViolation):
            v.tx(("persist", 1, "/p", None, 0.0))

    def test_pong_without_ping_rejected(self):
        v = TraceValidator()
        v.rx(("ready",))
        with pytest.raises(ProtocolViolation):
            v.rx(("pong", 1.0))


# --------------------------------------------- SMPHandle close idempotency
@pytest.fixture(scope="module")
def jax_state():
    import jax
    import jax.numpy as jnp
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (64, 32)),
            "b": jnp.ones((17,), jnp.bfloat16)}


class TestCloseIdempotency:
    def make_engine(self, jax_state):
        from repro.core import ReftConfig
        from repro.core.snapshot import SnapshotEngine
        cfg = ReftConfig(bucket_bytes=4096, trace_protocol=True)
        return SnapshotEngine(0, 1, jax_state, cfg)

    def test_double_close_is_safe(self, jax_state):
        eng = self.make_engine(jax_state)
        eng.snapshot_sync(jax_state, 1)
        eng.smp.stop()
        eng.smp.stop()          # second stop: no-op, no raise
        eng.smp.close()         # alias, also a no-op now
        assert eng.smp._validator.violations == []

    def test_close_during_persist_lands_the_shard(self, tmp_path,
                                                  jax_state):
        """stop() while a persist is mid-write: the SMP drains its queue
        before dropping segments, so the accepted durable write still
        lands; the trace validator sees a clean close-during-persist."""
        eng = self.make_engine(jax_state)
        eng.snapshot_sync(jax_state, 1)
        path = str(tmp_path / "mid.reft")
        eng.smp.persist_send(path, delay_s=0.3)
        eng.smp.stop()          # join waits for the drain
        assert os.path.exists(path)
        eng.smp.stop()          # and still idempotent afterwards
        assert eng.smp._validator.violations == []

    def test_engine_close_then_handle_close(self, jax_state):
        eng = self.make_engine(jax_state)
        eng.snapshot_sync(jax_state, 1)
        eng.close()
        eng.smp.close()         # teardown racing user close: no raise
