"""Supervisor subsystem: seeded injection, detection + auto-heal, elastic
reshard, goodput accounting, and the MTBF-fed cadence feedback loop."""
import time

import numpy as np
import pytest

from repro.api import CheckpointSession, CheckpointSpec
from repro.core.cluster import make_state, state_at, update_state
from repro.core.policy import FailureObserver, plan_frequencies
from repro.supervise import (
    GoodputLedger, Scenario, Supervisor, ensure_coverage, parse_scenario,
    plan_scenarios, trees_equal,
)

SG = 4
NBYTES = 1 << 14


def _spec(tmp_path, **kw):
    kw.setdefault("backend", "reft")
    kw.setdefault("sg_size", SG)
    kw.setdefault("snapshot_every_steps", 1)
    kw.setdefault("checkpoint_every_steps", 5)
    kw.setdefault("bucket_bytes", 1 << 20)
    kw.setdefault("resume", False)
    return CheckpointSpec(ckpt_dir=str(tmp_path), **kw)


def _supervise(tmp_path, scenarios, steps=12, seed=5, **spec_kw):
    sup = Supervisor(_spec(tmp_path, **spec_kw),
                     make_state(seed, nbytes_approx=NBYTES),
                     lambda st, s: update_state(st, s),
                     scenarios=scenarios)
    return sup, sup.run(steps)


# ------------------------------------------------------------- injector
def test_plan_scenarios_deterministic():
    a = plan_scenarios(7, n=4, total_steps=40, count=6)
    b = plan_scenarios(7, n=4, total_steps=40, count=6)
    assert a == b
    assert len(a) == 6
    assert all(s.step < s2.step for s, s2 in zip(a, a[1:]))
    # a different seed perturbs the schedule
    c = plan_scenarios(8, n=4, total_steps=40, count=6)
    assert [(s.step, s.kind, s.node) for s in a] != \
           [(s.step, s.kind, s.node) for s in c]


def test_ensure_coverage_hits_required_kinds():
    plan = [Scenario("node", step=s, node=0) for s in (3, 6, 9, 12)]
    out = ensure_coverage(plan, kinds=("node", "smp", "preempt"), n=4)
    kinds = {s.kind for s in out}
    assert {"node", "smp", "preempt"} <= kinds
    assert [s.step for s in out] == [3, 6, 9, 12]   # schedule untouched


def test_parse_scenario_grammar():
    sc = parse_scenario("12:smp:2")
    assert (sc.step, sc.kind, sc.node) == (12, "smp", 2)
    assert parse_scenario("5:preempt").node == 0
    with pytest.raises(ValueError):
        parse_scenario("5:meteor-strike")
    with pytest.raises(ValueError):
        parse_scenario("nope:node")
    with pytest.raises(ValueError):
        Scenario("meteor-strike", step=1)


# -------------------------------------------------------------- ledger
def test_goodput_ledger_accounts_every_second():
    t = [10.0]
    led = GoodputLedger(clock=lambda: t[0])
    t[0] += 3.0
    assert led.mark("compute") == 3.0
    t[0] += 0.5
    led.mark("detect")
    t[0] += 1.5
    led.mark("restore")
    led.transfer("compute", "lost_steps", 1.0)
    led.close()
    s = led.summary()
    assert s["seconds"] == {"compute": 2.0, "lost_steps": 1.0,
                            "checkpoint_stall": 0.0, "detect": 0.5,
                            "restore": 1.5, "overhead": 0.0}
    assert s["wall_seconds"] == 5.0
    assert led.check(tol=1e-9)
    assert s["goodput_frac"] == pytest.approx(0.4)
    with pytest.raises(ValueError):
        led.mark("vibes")


# ------------------------------------------------- MTBF feedback (policy)
def test_observer_posterior_tracks_failures():
    t = [0.0]
    obs = FailureObserver(clock=lambda: t[0], weight=2.0)
    prior = 1e-4
    # no evidence: posterior sits at the prior
    assert obs.lam_node(prior, n=4) == pytest.approx(prior, rel=0.01)
    # a burst of failures over a short window pulls the rate way up
    for _ in range(6):
        t[0] += 10.0
        obs.record_failure()
    lam_burst = obs.lam_node(prior, n=4)
    assert lam_burst > 3 * prior
    assert obs.mtbf() == pytest.approx(10.0)
    # a long quiet stretch relaxes it back down
    t[0] += 200_000.0
    assert obs.lam_node(prior, n=4) < lam_burst / 10


def test_plan_frequencies_restore_cost_shortens_interval():
    base = dict(t_snapshot=2.0, t_checkpoint=30.0, t_comp=1.0,
                lam_node=1e-4, n=4)
    cheap = plan_frequencies(**base)
    costly = plan_frequencies(**base, t_restore_snapshot=500.0,
                              t_restore_checkpoint=5000.0)
    assert costly.snapshot_interval < cheap.snapshot_interval
    assert costly.checkpoint_interval < cheap.checkpoint_interval
    # checkpoint overhead now uses o_ck (was o_sn): a costly checkpoint
    # tier must space checkpoints FURTHER apart than snapshots
    assert cheap.o_checkpoint > cheap.o_snapshot


def test_session_retune_follows_observed_mtbf(tmp_path):
    """Satellite regression: a failure burst shortens the snapshot
    interval; a quiet stretch relaxes it back (vs the same session tuned
    only by the static prior)."""
    t = [0.0]
    obs = FailureObserver(clock=lambda: t[0])
    spec = CheckpointSpec(backend="sync_disk", ckpt_dir=str(tmp_path),
                          resume=False, auto_tune=True, lam_node=1e-5)
    state = make_state(1, nbytes_approx=NBYTES)
    with CheckpointSession(spec, state, observer=obs) as sess:
        for s in range(1, 7):
            state = update_state(state, s)
            sess.snapshot(state, s, wait=True)
            # tiny "measured" compute time so the disk write dominates
            # (o_snapshot > 0 -> the optimal interval is finite and the
            # cadence actually responds to lambda)
            sess._step_times.append(1e-6)
        sess._retune()
        quiet_every = sess.snapshot_every
        # burst: 5 failures in 50 simulated seconds
        for _ in range(5):
            t[0] += 10.0
            obs.record_failure()
        obs.record_restore(2.0, tier="in-memory")
        sess._retune()
        burst_every = sess.snapshot_every
        assert burst_every < quiet_every
        # quiet again: rate decays toward the prior, cadence relaxes
        t[0] += 500_000.0
        sess._retune()
        assert sess.snapshot_every > burst_every


# ------------------------------------------------------ session surface
def test_session_inject_new_kinds(tmp_path):
    state = make_state(2, nbytes_approx=NBYTES)
    with CheckpointSession(_spec(tmp_path), state) as sess:
        state = update_state(state, 1)
        assert sess.snapshot(state, 1, wait=True)
        # slow-persist: latency lands on the engine immediately
        sess.inject("slow-persist", node=1, delay_s=0.05)
        assert sess.checkpointer.group.engines[1].persist_delay_s == 0.05
        # laggard: member stalls and auto-resumes; training never wedges
        sess.inject("laggard", node=2, graceful=False, lag_s=0.2)
        state = update_state(state, 2)
        assert sess.snapshot(state, 2, wait=True)
        # perf faults are not failures: the observer saw none
        assert sess.observer.failures == []
        with pytest.raises(ValueError):
            sess.inject("meteor-strike")


def test_dead_smp_detected_and_healed(tmp_path):
    """dead SMP -> health() flags it even before a send notices ->
    restore + heal respawns the sidecar -> full protection again."""
    state = make_state(3, nbytes_approx=NBYTES)
    with CheckpointSession(_spec(tmp_path), state) as sess:
        state = update_state(state, 1)
        assert sess.snapshot(state, 1, wait=True)
        sess.inject("smp", node=2, graceful=False)
        h = sess.health()
        assert 2 in h["degraded"] and not h["healthy"]
        assert not h["members"][2]["smp_alive"]
        assert len(sess.observer.failures) == 1    # MTBF observation
        res = sess.restore()
        assert trees_equal(res.state, state)
        h = sess.health()                          # heal respawned it
        assert h["healthy"] and h["members"][2]["smp_alive"]
        state = update_state(state, 2)
        assert sess.snapshot(state, 2, wait=True)


# ------------------------------------------------------- supervised runs
def test_midflight_corrupt_stripe_healed_byte_exact(tmp_path):
    """Mid-flight (non-graceful) stripe corruption: the CRC probe finds
    the flipped bytes, the member is evicted, and RAIM5 decodes it back
    byte-identical."""
    scen = [Scenario("corrupt-stripe", step=4, node=1, graceful=False)]
    sup, out = _supervise(tmp_path, scen, steps=8)
    assert out["unrecovered"] == 0
    ev = next(e for e in out["events"] if e["kind"] == "corrupt-stripe")
    assert ev["graceful"] is False
    assert ev["evicted"] == [1]            # detection earned, not assumed
    assert ev["tier"] == "raim5"           # decoded from survivors' parity
    assert ev["bit_exact"] is True
    # the healed member's final state equals the deterministic oracle
    assert trees_equal(out["final_state"],
                       state_at(5, 8, nbytes_approx=NBYTES))


def test_preempt_elastic_reshard_resumes(tmp_path):
    """preempt with a grace window -> durable family persisted -> elastic
    4->2 session rebuild restores it resharded, byte-exact."""
    scen = [Scenario("preempt", step=5, node=3, graceful=False,
                     params={"grace_s": 0.3, "new_sg": 2})]
    sup, out = _supervise(tmp_path, scen, steps=9)
    assert out["unrecovered"] == 0
    ev = next(e for e in out["events"] if e["kind"] == "preempt")
    assert ev["elastic"] == "4->2"
    assert ev["bit_exact"] is True
    assert sup.spec.sg_size == 2
    assert sup.sess.checkpointer.group.n == 2
    assert trees_equal(out["final_state"],
                       state_at(5, 9, nbytes_approx=NBYTES))


def test_compound_smp_death_during_slow_persist_no_wedge(tmp_path):
    """SMP death while a slowed persist is in flight must neither wedge
    the trainer nor lose recoverability."""
    scen = [
        Scenario("slow-persist", step=3, node=1, graceful=False,
                 params={"delay_s": 0.3, "duration_steps": 8}),
        Scenario("smp", step=5, node=1, graceful=False),
    ]
    t0 = time.monotonic()
    sup, out = _supervise(tmp_path, scen, steps=10,
                          checkpoint_every_steps=3)
    assert time.monotonic() - t0 < 120          # no wedge
    assert out["unrecovered"] == 0
    ev = next(e for e in out["events"] if e["kind"] == "smp")
    assert ev["recovered"] and ev["bit_exact"] is True
    assert trees_equal(out["final_state"],
                       state_at(5, 10, nbytes_approx=NBYTES))


def test_supervised_run_goodput_sums_to_wall_clock(tmp_path):
    """Every second of a multi-failure supervised run lands in exactly
    one ledger bucket (sum == wall within 5%), failures feed the MTBF
    posterior, and rolled-back steps are re-attributed as lost."""
    scen = [
        Scenario("smp", step=3, node=2, graceful=False),
        Scenario("node", step=6, node=0, graceful=True),
    ]
    sup, out = _supervise(tmp_path, scen, steps=9)
    assert out["unrecovered"] == 0
    g = out["goodput"]
    assert g["accounting_error"] <= 0.05
    assert abs(sum(g["seconds"].values()) - g["wall_seconds"]) \
        <= 0.05 * g["wall_seconds"]
    assert g["seconds"]["restore"] > 0.0
    assert out["mtbf_s"] < float("inf")
    assert out["lam_node_posterior"] > sup.spec.lam_node
    rolled = sum(e.get("rolled_back", 0) for e in out["events"])
    if rolled:
        assert g["seconds"]["lost_steps"] > 0.0
