import json
import os
import sys

# Tests run on 1 CPU device (the dry-run's 512-device flag is NOT set here
# on purpose — smoke tests and benches must see the real host).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------- lockgraph
# Opt-in dynamic lock-order checking (repro.analyze.lockgraph): with
# ANALYZE_LOCKGRAPH=1 a process-global tracer is installed BEFORE any
# repro module is imported (module-level locks like pipeline.GATE are
# created at import time), so the whole tier-1 run doubles as the dynamic
# corpus.  Any test whose execution adds an ABBA pair fails; the session
# summary (locks seen, order edges, cycles) is dumped to
# ANALYZE_LOCKGRAPH_JSON for the CI artifact.
_LG_TRACER = None
if os.environ.get("ANALYZE_LOCKGRAPH", "") not in ("", "0"):
    from repro.analyze import lockgraph as _lockgraph

    _LG_TRACER = _lockgraph.install()

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is optional: property tests are skipped without it
    collect_ignore = ["test_treebytes.py", "test_policy.py",
                      "test_sharding_plan.py", "test_raim5.py"]
else:
    settings.register_profile("ci", deadline=None, max_examples=25,
                              derandomize=True)
    settings.load_profile("ci")

if _LG_TRACER is not None:
    import pytest

    @pytest.fixture(autouse=True)
    def _lockgraph_guard():
        """Fail the test that introduced a lock-order violation (eager
        ABBA detection happens at acquisition time, on any thread)."""
        before = len(_LG_TRACER.violations)
        yield
        fresh = _LG_TRACER.violations[before:]
        assert not fresh, (
            "lock-order violation(s) during this test: "
            + "; ".join(f"{v['pair'][0]} <-> {v['pair'][1]}" for v in fresh))

    def pytest_sessionfinish(session, exitstatus):
        summary = _LG_TRACER.summary()
        out = os.environ.get("ANALYZE_LOCKGRAPH_JSON")
        if out:
            with open(out, "w") as f:
                json.dump(summary, f, indent=2, sort_keys=True)
        tr = session.config.pluginmanager.get_plugin("terminalreporter")
        if tr is not None:
            tr.write_line(
                f"lockgraph: {len(summary['locks'])} locks, "
                f"{len(summary['edges'])} order edges, "
                f"{summary['acquisitions']} acquisitions, "
                f"{len(summary['cycles'])} cycles, "
                f"{len(summary['violations'])} violations")
        if summary["cycles"] or summary["violations"]:
            session.exitstatus = 3
