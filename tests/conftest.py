import os
import sys

# Tests run on 1 CPU device (the dry-run's 512-device flag is NOT set here
# on purpose — smoke tests and benches must see the real host).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is optional: property tests are skipped without it
    collect_ignore = ["test_treebytes.py", "test_policy.py",
                      "test_sharding_plan.py", "test_raim5.py"]
else:
    settings.register_profile("ci", deadline=None, max_examples=25,
                              derandomize=True)
    settings.load_profile("ci")
