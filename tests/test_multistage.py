"""3D-parallel REFT: per-stage SGs recover independently (paper Fig. 5)."""
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.multistage import (MultiStageGroup, join_stages,
                                   split_state_by_stage)
from repro.core.snapshot import ReftConfig


def state(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    return {
        "blk0": {"w": jax.random.normal(ks[0], (64, 64))},
        "blk1": {"w": jax.random.normal(ks[1], (64, 64))},
        "blk2": {"w": jax.random.normal(ks[2], (64, 64))},
        "blk3": {"w": jax.random.normal(ks[3], (64, 64))},
        "head": jax.random.normal(ks[4], (64, 128)),
        "step": jnp.int32(0),
    }


def eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_split_join_roundtrip():
    s = state()
    for n_pp in (1, 2, 3, 4):
        stages = split_state_by_stage(s, n_pp)
        assert len(stages) == n_pp
        assert all(len(st) > 0 for st in stages)
        assert eq(join_stages(s, stages), s)


def test_concurrent_single_failures_across_stages():
    """One node loss in EVERY stage simultaneously is still recoverable
    (RAIM5 protects one per SG, and SGs are per stage)."""
    s = state(1)
    g = MultiStageGroup(2, 3, s, ReftConfig(ckpt_dir=tempfile.mkdtemp(),
                                            checkpoint_every_snapshots=10**6))
    try:
        g.snapshot(s, 1)
        g.inject_node_failure(0, 1)
        g.inject_node_failure(1, 2)     # a second loss, different SG
        rec, step, tier = g.recover()
        assert tier == "raim5" and step == 1
        assert eq(rec, s)
    finally:
        g.close()


def test_mixed_tier_recovery():
    s = state(2)
    g = MultiStageGroup(2, 3, s, ReftConfig(ckpt_dir=tempfile.mkdtemp(),
                                            checkpoint_every_snapshots=10**6))
    try:
        g.snapshot(s, 1)
        g.checkpoint()
        g.inject_software_failure(0, 0)         # stage 0: in-memory
        g.inject_node_failure(1, 0)             # stage 1: raim5
        g.inject_node_failure(1, 1)             # stage 1: second loss -> ckpt
        rec, step, tier = g.recover()
        assert tier == "checkpoint" and step == 1
        assert eq(rec, s)
    finally:
        g.close()
