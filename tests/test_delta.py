"""Dirty-delta snapshotting (ISSUE 7).

Units: range algebra, dirty planning, keyframe policy, persist-chain
log, MoE touch tracking, FSDP/EP sharding rules, chain-aware GC.
Integration (real SMP shards): delta-chain restore byte-identity vs the
full-snapshot oracle (host AND device encode), keyframe forcing at the
dirty-fraction threshold, elastic n->m resume from a delta family, and
the scrubber repairing a corrupt delta object / file.
"""
import glob
import os
from types import SimpleNamespace

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.coordinator import ReftGroup
from repro.core.delta import (
    DeltaLog, DeltaTracker, expert_dirty_ranges, merge_ranges,
    ranges_intersect, task_dirty,
)
from repro.core.recovery import (
    delta_families, latest_checkpoint_step, resolve_chain,
    restorable_steps, restore_from_checkpoint, restore_state,
)
from repro.core.snapshot import ReftConfig, SnapshotEngine
from repro.core.treebytes import make_flat_spec


def trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def mkstate(n_leaves=4, shape=(32, 64), seed=0):
    rng = np.random.RandomState(seed)
    return {f"w{i}": jnp.asarray(rng.rand(*shape), jnp.float32)
            for i in range(n_leaves)}


# ================================================================ units
def test_merge_ranges_and_intersect():
    assert merge_ranges([(5, 10), (0, 6), (20, 20), (12, 14)]) == \
        [(0, 10), (12, 14)]
    r = merge_ranges([(0, 10), (20, 30)])
    assert ranges_intersect(r, 5, 6)
    assert ranges_intersect(r, 9, 25)        # spans the gap
    assert ranges_intersect(r, 29, 100)
    assert not ranges_intersect(r, 10, 20)   # exactly the hole
    assert not ranges_intersect(r, 30, 40)
    assert not ranges_intersect(r, 3, 3)     # empty probe
    assert not ranges_intersect([], 0, 10)


def test_task_dirty_own_and_fused_parity():
    own = SimpleNamespace(kind=0, lo=100, hi=200, sources=None)
    par = SimpleNamespace(kind=2, lo=0, hi=64,
                          sources=[(300, 400), (500, 600)])
    dirty = merge_ranges([(150, 160)])
    assert task_dirty(own, dirty)
    assert not task_dirty(par, dirty)
    # parity refreshes when ANY source block slice moved
    assert task_dirty(par, merge_ranges([(550, 551)]))
    assert not task_dirty(own, merge_ranges([(550, 551)]))


def test_expert_dirty_ranges_stacked_vs_dense():
    E = 4
    spec = make_flat_spec({
        "router": jnp.zeros((8,), jnp.float32),
        "wi_gate": jnp.zeros((E, 2, 2), jnp.float32),
    })
    by_name = {l.path: l for l in spec.leaves}
    gate = next(v for k, v in by_name.items() if "wi_gate" in k)
    router = next(v for k, v in by_name.items() if "router" in k)
    per = gate.nbytes // E
    got = expert_dirty_ranges(spec, [False, True, False, True])
    want = merge_ranges([
        (router.offset, router.offset + router.nbytes),  # dense: whole leaf
        (gate.offset + 1 * per, gate.offset + 2 * per),
        (gate.offset + 3 * per, gate.offset + 4 * per),
    ])
    assert got == want
    # every expert touched == everything dirty
    allr = expert_dirty_ranges(spec, [True] * E)
    assert allr == [(0, spec.total_bytes)]


def test_delta_tracker_policy():
    sched = [SimpleNamespace(kind=0, lo=0, hi=10, sources=None),
             SimpleNamespace(kind=0, lo=10, hi=100, sources=None)]
    t = DeltaTracker(keyframe_every=2, dirty_threshold=0.5)
    assert t.plan(0, sched, None, 100) is None        # no base digests yet
    t.commit(3, {0: 11, 1: 22}, was_delta=False, sent_frac=1.0)
    fd = t.plan(3, sched, None, 100)
    assert fd is not None and fd.base_step == 3 and fd.prev == {0: 11, 1: 22}
    assert t.plan(4, sched, None, 100) is None        # base rotated away
    # dirty fraction above threshold -> keyframe; below -> skip clean tasks
    assert t.plan(3, sched, [(0, 60)], 100) is None
    fd = t.plan(3, sched, [(0, 5)], 100)
    assert fd is not None and fd.skip == frozenset({1})
    # keyframe_every flights since last full -> keyframe
    t.commit(4, {0: 1, 1: 2}, was_delta=True, sent_frac=0.1)
    assert t.plan(4, sched, None, 100) is not None
    t.commit(5, {0: 1, 1: 2}, was_delta=True, sent_frac=0.1)
    assert t.plan(5, sched, None, 100) is None
    # a delta that turned out dense forces the next keyframe
    t2 = DeltaTracker(keyframe_every=100, dirty_threshold=0.5)
    t2.commit(1, {0: 1}, was_delta=False, sent_frac=1.0)
    t2.commit(2, {0: 1}, was_delta=True, sent_frac=0.9)
    assert t2.plan(2, sched, None, 100) is None
    # invalidate drops the base entirely
    t2.invalidate()
    assert t2.base_step == -1 and t2.digests is None


def test_delta_log_extents_since():
    log = DeltaLog()
    log.record(0, None)                      # keyframe
    log.record(1, [(0, 10)])
    log.record(2, [(5, 20), (30, 40)])
    assert log.extents_since(0, 2) == [(0, 20), (30, 40)]
    assert log.extents_since(1, 2) == [(5, 20), (30, 40)]
    assert log.extents_since(2, 2) is None   # step <= base
    assert log.extents_since(-1, 2) is None
    assert log.extents_since(7, 9) is None   # unknown base
    log.record(3, None)                      # keyframe voids the chain
    assert log.extents_since(1, 3) is None
    log.record(4, [])
    assert log.extents_since(3, 4) == []     # nothing changed: empty delta
    small = DeltaLog(cap=2)
    for s in range(3):
        small.record(s, [(s, s + 1)])
    assert 0 not in small.entries            # trimmed
    assert small.extents_since(0, 2) is None


def test_expert_touch_tracker():
    from repro.models.moe import ExpertTouchTracker
    t = ExpertTouchTracker()
    t.record([[0, 1]])                       # disabled: no-op
    t.enable(8)
    t.record(np.array([[1, 3], [5, 1]]))
    t.record(np.array([[99, -2]]))           # out-of-range ids filtered
    mask = t.consume()
    assert mask.tolist() == [False, True, False, True, False, True,
                             False, False]
    assert not t.consume().any()             # consume resets
    t.disable()
    t.record(np.array([[2]]))
    assert not t.peek().any()


def test_shardings_fsdp_and_ep_rules():
    from repro.dist.shardings import param_specs
    shapes = {
        "wi_gate": jax.ShapeDtypeStruct((4, 16, 32), jnp.float32),
        "wo": jax.ShapeDtypeStruct((4, 32, 16), jnp.float32),
        "wq": jax.ShapeDtypeStruct((16, 32), jnp.float32),
        "scale": jax.ShapeDtypeStruct((), jnp.float32),
    }
    # EP + FSDP: experts over "model", fan-in over the batch axes
    cfg = SimpleNamespace(moe_ep=True, num_experts=4, fsdp=True)
    sp = param_specs(cfg, shapes)
    assert sp["wi_gate"] == P("model", ("pod", "data"), None)
    assert sp["wo"] == P("model", ("pod", "data"), None)
    assert sp["wq"] == P(("pod", "data"), "model")    # FSDP fills the
    assert sp["scale"] == P()                         # replicated dim
    # EP without FSDP
    cfg = SimpleNamespace(moe_ep=True, num_experts=4, fsdp=False)
    sp = param_specs(cfg, shapes)
    assert sp["wi_gate"] == P("model", None, None)
    assert sp["wq"] == P(None, "model")
    # expert-count mismatch falls back to the plain table
    cfg = SimpleNamespace(moe_ep=True, num_experts=8, fsdp=False)
    sp = param_specs(cfg, shapes)
    assert sp["wi_gate"] == P(None, None, "model")
    assert sp["wo"] == P(None, "model", None)


# =========================================================== chain + GC
def _touch_family(d, step, nodes, base=None):
    for node in nodes:
        name = (f"step-{step}-node-{node}.reft" if base is None else
                f"step-{step}-from-{base}-node-{node}.reftd")
        open(os.path.join(d, name), "wb").close()


def test_resolve_chain_and_restorable_steps(tmp_path):
    d = str(tmp_path)
    _touch_family(d, 0, [0, 1])
    _touch_family(d, 4, [0, 1], base=0)
    _touch_family(d, 8, [0, 1], base=4)
    _touch_family(d, 9, [0, 1], base=7)      # dangling base
    assert resolve_chain(d, 0) == (0, [])
    assert resolve_chain(d, 8) == (0, [(4, 0), (8, 4)])
    assert resolve_chain(d, 9) is None
    assert restorable_steps(d, 2) == [0, 4, 8]
    assert latest_checkpoint_step(d, 2) == 8
    assert set(delta_families(d)) == {4, 8, 9}
    # torn link poisons every dependent
    os.remove(os.path.join(d, "step-4-from-0-node-1.reftd"))
    assert restorable_steps(d, 2) == [0]
    assert latest_checkpoint_step(d, 2) == 0


def test_plan_gc_keyframe_liveness_and_cascade():
    from repro.ckpt.manager import plan_gc
    fam = {0: None, 4: None, 8: None}
    deps = {4: 0, 8: 4}
    # keeping the chain head keeps its whole ancestry alive
    assert plan_gc(fam, {0, 4, 8}, {8}, deps=deps) == []
    # keeping only the keyframe lets the deltas go
    assert sorted(plan_gc(fam, {0, 4, 8}, {0}, deps=deps)) == [4, 8]
    # a torn middle link cascades: the dependent is dead weight too
    assert sorted(plan_gc(fam, {0, 8}, {8}, deps=deps)) == [4, 8]
    # without deps the old flat policy is unchanged
    assert plan_gc(fam, {0, 4, 8}, {0, 4, 8}) == []


def test_manager_gc_spares_delta_ancestry(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    d = str(tmp_path)
    _touch_family(d, 0, [0, 1])
    _touch_family(d, 4, [0, 1], base=0)
    _touch_family(d, 8, [0, 1], base=4)
    mgr = CheckpointManager(d, 2, keep=1)
    assert mgr.complete_steps() == [0, 4, 8]
    assert mgr.latest() == 8
    mgr.commit()
    # keep=1 keeps step 8 — but its keyframe + middle link must survive
    assert restorable_steps(d, 2) == [0, 4, 8]
    # tear the middle link: dependents stop being restorable, the torn
    # remnant is GC'd (newest torn family is spared as possibly
    # in-flight), and latest falls back to the keyframe
    os.remove(os.path.join(d, "step-4-from-0-node-1.reftd"))
    assert mgr.complete_steps() == [0]
    assert mgr.latest() == 0
    mgr.commit()
    assert not glob.glob(os.path.join(d, "step-4-*"))
    assert restorable_steps(d, 2) == [0]


# ====================================================== SMP integration
def _persist_round(g, d, n, remote=None):
    assert g.checkpoint_async(
        remote=remote,
        delta_base=latest_checkpoint_step(d, n)) is not None
    r = g.drain_persists()[-1]
    assert r["ok"], r
    return r


@pytest.mark.parametrize("device_encode", ["off", "on"])
def test_delta_chain_restore_matches_full_oracle(device_encode, tmp_path):
    """keyframe + delta chain restores byte-identically to the state the
    full-snapshot path would have captured, on both encode paths."""
    d = str(tmp_path)
    cfg = ReftConfig(ckpt_dir=d, bucket_bytes=2048, delta=True,
                     delta_keyframe=8, delta_dirty_threshold=0.9,
                     device_encode=device_encode,
                     checkpoint_every_snapshots=10 ** 9)
    g = ReftGroup(2, mkstate(), cfg)
    states, kinds = {}, []
    st = mkstate()
    try:
        for step in range(4):
            st = dict(st)
            st["w1"] = st["w1"] + (step + 1)
            states[step] = st
            assert g.snapshot(st, step, wait=True)
            kinds.append(_persist_round(g, d, 2)["kind"])
        assert g.engines[0].stats["delta_flights"] >= 1
        assert g.engines[0].stats["skipped_buckets"] > 0   # S1: clean
    finally:                                               # buckets skip
        g.close()
    assert kinds == ["full", "delta", "delta", "delta"]
    assert restorable_steps(d, 2) == [0, 1, 2, 3]
    for step, want in states.items():
        got, at, _ = restore_from_checkpoint(d, 2, mkstate(), step=step)
        assert at == step and trees_equal(got, want)


def test_keyframe_forced_at_dirty_threshold_and_shm_identity():
    """A provider reporting most bytes dirty forces a keyframe (delta
    saves nothing dense); a sparse provider yields a delta flight whose
    published shm shard is still byte-identical to the live state."""
    state = {"a": jnp.zeros((4096,), jnp.float32),
             "b": jnp.ones((4096,), jnp.float32)}
    cfg = ReftConfig(bucket_bytes=2048, delta=True, delta_keyframe=100,
                     delta_dirty_threshold=0.05,
                     checkpoint_every_snapshots=10 ** 9)
    eng = SnapshotEngine(0, 1, state, cfg)
    dirty = [None]
    eng.set_dirty_provider(lambda: dirty[0])
    try:
        total = eng.spec.total_bytes
        assert eng.snapshot_sync(state, 1) == 1      # first: keyframe
        dirty[0] = [(0, total)]                      # dense -> keyframe
        assert eng.snapshot_sync(state, 2) == 2
        assert eng.stats["keyframe_flights"] == 2
        assert eng.stats["delta_flights"] == 0
        state2 = dict(state)
        state2["a"] = state["a"].at[:8].set(7.0)     # sparse real change
        dirty[0] = [(0, 64)]
        assert eng.snapshot_sync(state2, 3) == 3
        assert eng.stats["delta_flights"] == 1
        assert eng.stats["skipped_buckets"] > 0
        rec, at, _ = restore_state(eng.run, 1, total, state, [0])
        assert at == 3 and trees_equal(rec, state2)
    finally:
        eng.close()


def test_delta_family_elastic_resume_and_local_scrub(tmp_path):
    """n=3 delta family: elastic resume into a 5-member SG from a delta
    step, then the scrubber detects + repairs a corrupted `.reftd`."""
    from repro.store.scrub import _head_off, scrub_local_dir
    d = str(tmp_path)
    cfg = ReftConfig(ckpt_dir=d, bucket_bytes=4096, delta=True,
                     delta_keyframe=8, delta_dirty_threshold=0.9,
                     checkpoint_every_snapshots=10 ** 9)
    g = ReftGroup(3, mkstate(8, (64, 64)), cfg)
    states = {}
    st = mkstate(8, (64, 64))
    try:
        for step in range(3):
            st = dict(st)
            st["w2"] = st["w2"] + (step + 1)
            states[step] = st
            assert g.snapshot(st, step, wait=True)
            _persist_round(g, d, 3)
    finally:
        g.close()
    # elastic: the 3-member delta family restores into a 5-member SG
    got, at, _ = restore_from_checkpoint(d, 5, mkstate(8, (64, 64)), step=2)
    assert at == 2 and trees_equal(got, states[2])
    # corrupt one delta shard's payload; scrub repairs it in place
    path = os.path.join(d, "step-2-from-1-node-1.reftd")
    off = _head_off(path)
    with open(path, "r+b") as f:
        f.seek(off)
        f.write(b"\xff" * 32)
    reports = {r.step: r for r in scrub_local_dir(d, repair=True)}
    assert reports[2].kind == "chain"
    assert reports[2].corrupt and reports[2].repaired
    assert not reports[2].unrepairable and not reports[2].errors
    assert all(r.clean for r in scrub_local_dir(d, repair=True))
    got, at, _ = restore_from_checkpoint(d, 3, mkstate(8, (64, 64)), step=2)
    assert at == 2 and trees_equal(got, states[2])


def test_delta_objstore_chain_restore_and_scrub(tmp_path):
    """Tier-4: delta manifests chain by base_step, the remote restore
    walks the chain, and the object scrubber repairs a corrupt delta
    object through the serving layer."""
    from repro.core.recovery import restore_from_objstore
    from repro.store import (
        LocalObjectStore, build_manifest, put_manifest, scrub_object_store,
    )
    from repro.store.manifest import load_manifest, manifest_base_step
    d = str(tmp_path)
    store = LocalObjectStore(os.path.join(d, "obj"))
    remote = {"store": store.config, "prefix": "families"}
    cfg = ReftConfig(ckpt_dir=d, bucket_bytes=4096, delta=True,
                     delta_keyframe=8, delta_dirty_threshold=0.9,
                     checkpoint_every_snapshots=10 ** 9)
    g = ReftGroup(3, mkstate(8, (64, 64)), cfg)
    states = {}
    st = mkstate(8, (64, 64))
    try:
        for step in range(3):
            st = dict(st)
            st["w2"] = st["w2"] + (step + 1)
            states[step] = st
            assert g.snapshot(st, step, wait=True)
            r = _persist_round(g, d, 3, remote=remote)
            man = build_manifest(g.run, r["step"], 3, g.total_bytes,
                                 r["uploads"])
            put_manifest(store, "families", man)
            assert man["kind"] == r["kind"]
    finally:
        g.close()
    man2 = load_manifest(store, "families", 2)
    assert man2["kind"] == "delta" and manifest_base_step(man2) == 1
    got, at, _ = restore_from_objstore(store, "families", 3,
                                       mkstate(8, (64, 64)), step=2)
    assert at == 2 and trees_equal(got, states[2])
    # corrupt a delta object's payload and scrub-repair it
    ent = man2["nodes"][1]
    blob = bytearray(store.read(ent["key"]))
    doff = int(ent["data_off"])
    blob[doff:doff + 64] = b"\xff" * 64
    store.put(ent["key"], bytes(blob))
    reports = {r.step: r for r in scrub_object_store(store, "families",
                                                     repair=True)}
    assert reports[2].kind == "chain"
    assert reports[2].corrupt and reports[2].repaired
    assert not reports[2].unrepairable and not reports[2].errors
    assert all(r.clean for r in scrub_object_store(store, "families",
                                                   repair=True))
    got, at, _ = restore_from_objstore(store, "families", 3,
                                       mkstate(8, (64, 64)), step=2)
    assert at == 2 and trees_equal(got, states[2])


def test_leaf_extents_and_ranged_reader():
    """`leaf_extents` covers every plan range with element-aligned
    per-leaf extents, and a `LeafReader` restricted to those extents
    reads byte-identically to an unrestricted one."""
    from repro.core.pipeline import LeafReader, leaf_budget, leaf_extents
    state = mkstate(3, (16, 32))                 # 3 leaves x 2048 bytes
    spec = make_flat_spec(state)
    leaves = jax.tree.leaves(state)
    # ranges: tail of leaf 0, hole, slice inside leaf 2 (unaligned ends)
    ranges = [(1500, 2100), (4197, 4199)]
    ext = leaf_extents(spec, ranges)
    assert set(ext) == {0, 1, 2}
    for i, (lo, hi) in ext.items():
        ls = spec.leaves[i]
        assert 0 <= lo < hi <= ls.nbytes
        assert lo % 4 == 0 and (hi % 4 == 0 or hi == ls.nbytes)
    a, b = ext[2]
    assert a <= 4197 - 4096 and b >= 4199 - 4096 and b - a <= 12
    plain = LeafReader(spec, leaves)
    ranged = LeafReader(spec, leaves, leaf_budget(spec, ranges), ext)
    for lo, hi in ranges:
        want = np.empty(hi - lo, np.uint8)
        got = np.empty(hi - lo, np.uint8)
        plain.read(lo, hi, want)
        ranged.read(lo, hi, got)
        assert np.array_equal(want, got)


def test_ranged_fetch_delta_flight_identity():
    """With `ranged_fetch="on"` (forced device-side extent slicing, the
    real-accelerator path) a sparse delta flight still publishes a shard
    byte-identical to the live state."""
    state = {"a": jnp.zeros((4096,), jnp.float32),
             "b": jnp.ones((4096,), jnp.float32)}
    cfg = ReftConfig(bucket_bytes=2048, delta=True, delta_keyframe=100,
                     delta_dirty_threshold=0.9, ranged_fetch="on",
                     checkpoint_every_snapshots=10 ** 9)
    eng = SnapshotEngine(0, 1, state, cfg)
    dirty = [None]
    eng.set_dirty_provider(lambda: dirty[0])
    try:
        assert eng.snapshot_sync(state, 1) == 1      # keyframe
        state2 = dict(state)
        state2["a"] = state["a"].at[16:24].set(5.0)
        dirty[0] = [(64, 96)]
        assert eng.snapshot_sync(state2, 2) == 2
        assert eng.stats["delta_flights"] == 1
        assert eng.stats["skipped_buckets"] > 0
        rec, at, _ = restore_state(eng.run, 1, eng.spec.total_bytes,
                                   state, [0])
        assert at == 2 and trees_equal(rec, state2)
    finally:
        eng.close()
