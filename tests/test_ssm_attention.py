"""SSM + attention internals: chunked-vs-recurrent equivalence, prefill ->
decode state handoff, flash-vs-dense, RoPE/window semantics."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.models.flash import flash_attention
from repro.models.ssm import (init_ssm, ssd_chunked, ssd_scan_ref, ssm_block,
                              ssm_decode)


def test_ssd_chunked_equals_recurrence():
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, P, N = 2, 96, 3, 16, 32
    u = jax.random.normal(ks[0], (B, S, H, P))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    h0 = jax.random.normal(ks[4], (B, H, P, N))
    y1, h1 = ssd_chunked(u, a, Bm, Cm, h0=h0, chunk=24)
    y2, h2 = ssd_scan_ref(u, a, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


def test_ssm_block_prefill_then_decode_continuity():
    """Full-seq block state == feeding the same tokens one-by-one."""
    cfg = get_config("mamba2-130m").reduced()
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model))
    y_full, (conv_state, h_full) = ssm_block(p, cfg, x)

    W = cfg.ssm_conv_width
    ch = cfg.d_inner + 2 * cfg.ssm_state
    conv = jnp.zeros((2, W - 1, ch))
    h = jnp.zeros((2, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state))
    ys = []
    for t in range(10):
        y_t, conv, h = ssm_decode(p, cfg, x[:, t:t + 1], conv, h)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=2e-4)


def test_flash_equals_dense_inside_model():
    """Force the flash path by lowering the threshold; results match."""
    import repro.models.attention as A
    cfg = get_config("qwen3-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l_dense, _ = M.forward(cfg, params, batch)
    old = A.FLASH_THRESHOLD
    A.FLASH_THRESHOLD = 16
    try:
        l_flash, _ = M.forward(cfg, params, batch)
    finally:
        A.FLASH_THRESHOLD = old
    np.testing.assert_allclose(float(l_dense), float(l_flash), rtol=1e-5)


def test_banded_attention_matches_masked():
    """cfg.banded_attention (the §Perf optimization) is semantics-free."""
    import repro.models.attention as A
    base = get_config("starcoder2-3b").reduced()   # homogeneous SWA
    cfg_m = dataclasses.replace(base, sliding_window=16)
    cfg_b = dataclasses.replace(cfg_m, banded_attention=True)
    params = M.init_params(cfg_m, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0,
                              cfg_m.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    old = A.FLASH_THRESHOLD
    A.FLASH_THRESHOLD = 16
    try:
        l1, _ = M.forward(cfg_m, params, batch)
        l2, _ = M.forward(cfg_b, params, batch)
    finally:
        A.FLASH_THRESHOLD = old
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_global_vs_local_layers_differ():
    """gemma3's interleave: a distant token influences global layers only."""
    cfg = dataclasses.replace(get_config("gemma3-4b").reduced(),
                              sliding_window=4, global_every=2,
                              num_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    T = 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, T), 0,
                              cfg.vocab_size)
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab_size)
    l1, _ = M.logits_fn(cfg, params, {"tokens": toks, "labels": toks})
    l2, _ = M.logits_fn(cfg, params, {"tokens": toks2, "labels": toks2})
    # token 0 is far outside every local window but the global layer sees it
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_chunked_ce_matches_full():
    from repro.models.layers import chunked_cross_entropy, cross_entropy
    k = jax.random.PRNGKey(0)
    B, S, D, V = 2, 24, 16, 64
    h = jax.random.normal(k, (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, V))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    full = cross_entropy(h @ w, labels)
    for chunk in (4, 6, 24):
        ck = chunked_cross_entropy(h, w, labels, chunk)
        np.testing.assert_allclose(float(full), float(ck), rtol=1e-6)
    ck_unrolled = chunked_cross_entropy(h, w, labels, 8, unroll=True)
    np.testing.assert_allclose(float(full), float(ck_unrolled), rtol=1e-6)
