"""Background integrity scrubber: stripe-digest verification and RAIM5
parity repair over both durable tiers (local `.reft` files and remote
shard objects), plus the cadenced daemon."""
import os
import pickle
import time
import zlib

import numpy as np

from repro.core import raim5
from repro.store import (
    LocalObjectStore, Scrubber, build_manifest, load_manifest,
    put_manifest, shard_key, upload_shard,
)
from repro.store.scrub import (
    scrub_family, scrub_local_dir, scrub_object_store, _FileFamily,
)


# ------------------------------------------------- synthetic families
def make_local_family(ckpt_dir, n=3, bs=512, step=4, seed=0):
    """Hand-rolled `.reft` family in the exact SMP shard layout: pickled
    head (with per-block stripe digests) + own region + parity region.
    Returns (full_state, {node: path}, {node: pristine file bytes})."""
    total = n * (n - 1) * bs if n > 1 else bs
    rng = np.random.default_rng(seed)
    full = rng.integers(0, 256, total, dtype=np.uint8)
    paths, pristine = {}, {}
    for node in range(n):
        if n > 1:
            own = np.concatenate(
                [full[slice(*ref.byte_range(bs, n))]
                 for ref in raim5.data_blocks_of_node(node, n)])
            parity = raim5.encode_parity(node, n, full)
            crcs = [zlib.crc32(own[i * bs:(i + 1) * bs].tobytes())
                    for i in range(n - 1)]
            crc_parity = zlib.crc32(parity.tobytes())
        else:
            own, parity = full, np.zeros(0, np.uint8)
            crcs, crc_parity = [zlib.crc32(full.tobytes())], None
        head = {"node": node, "n": n, "total_bytes": total, "step": step,
                "meta": pickle.dumps({"crc_parity": crc_parity}),
                "crc_stripes": {"seg": bs, "crcs": crcs}}
        blob = pickle.dumps(head) + own.tobytes() + parity.tobytes()
        path = os.path.join(ckpt_dir, f"step-{step}-node-{node}.reft")
        with open(path, "wb") as f:
            f.write(blob)
        paths[node] = path
        pristine[node] = blob
    return full, paths, pristine


def make_object_family(store, prefix="families", n=3, bs=512, step=4,
                       seed=0):
    """Same family uploaded stripe-by-stripe, digests in the manifest."""
    total = n * (n - 1) * bs
    rng = np.random.default_rng(seed)
    full = rng.integers(0, 256, total, dtype=np.uint8)
    nodes = {}
    for node in range(n):
        own = np.concatenate(
            [full[slice(*ref.byte_range(bs, n))]
             for ref in raim5.data_blocks_of_node(node, n)])
        parity = raim5.encode_parity(node, n, full)
        buf = np.concatenate([own, parity])
        head = pickle.dumps({"node": node, "n": n, "total_bytes": total,
                             "step": step, "meta": pickle.dumps({})})
        rec = upload_shard(store, shard_key(prefix, step, node), head,
                           buf, seg=bs, own_bytes=own.nbytes)
        rec["crc_stripes"] = {
            "seg": bs,
            "crcs": [zlib.crc32(own[i * bs:(i + 1) * bs].tobytes())
                     for i in range(n - 1)]}
        rec["crc_parity"] = zlib.crc32(parity.tobytes())
        nodes[node] = rec
    put_manifest(store, prefix,
                 build_manifest("run", step, n, total, nodes))
    return full


def corrupt_local(path, off, junk=b"\xde\xad\xbe\xef"):
    """Patch `junk` at byte `off` of the shard's DATA region."""
    with open(path, "rb") as f:
        pickle.load(f)
        base = f.tell()
    with open(path, "r+b") as f:
        f.seek(base + off)
        f.write(junk)


def corrupt_remote(store, prefix, step, node, off,
                   junk=b"\xde\xad\xbe\xef"):
    ent = load_manifest(store, prefix, step)["nodes"][node]
    store.write_range(ent["key"], int(ent["data_off"]) + off, junk)


# ------------------------------------------------------- local scrubs
def test_clean_family_verifies_every_segment(tmp_path):
    make_local_family(str(tmp_path), n=3, bs=512)
    reports = scrub_local_dir(str(tmp_path))
    assert len(reports) == 1
    r = reports[0]
    assert r.clean and r.kind == "file" and r.members == 3
    assert r.segments == 3 * 3             # (n-1) data + 1 parity per node
    assert r.bytes_verified == 3 * 3 * 512


def test_data_block_detected_and_parity_repaired(tmp_path):
    _, paths, pristine = make_local_family(str(tmp_path), n=3, bs=512)
    corrupt_local(paths[0], 512 + 7)       # node0, local block 1
    r = scrub_local_dir(str(tmp_path))[0]
    assert r.corrupt == ["node0:block1"]
    assert r.repaired == ["node0:block1"] and not r.unrepairable
    with open(paths[0], "rb") as f:        # byte-identical after repair
        assert f.read() == pristine[0]
    assert scrub_local_dir(str(tmp_path))[0].clean


def test_parity_region_repaired_from_data(tmp_path):
    lay_own = 2 * 512                      # n=3: own region = (n-1)*bs
    _, paths, pristine = make_local_family(str(tmp_path), n=3, bs=512)
    corrupt_local(paths[2], lay_own + 100)
    r = scrub_local_dir(str(tmp_path))[0]
    assert r.corrupt == ["node2:parity"]
    assert r.repaired == ["node2:parity"]
    with open(paths[2], "rb") as f:
        assert f.read() == pristine[2]


def test_detect_only_leaves_bytes_alone(tmp_path):
    _, paths, pristine = make_local_family(str(tmp_path), n=3, bs=512)
    corrupt_local(paths[1], 3)
    r = scrub_local_dir(str(tmp_path), repair=False)[0]
    assert r.corrupt and not r.repaired and not r.unrepairable
    with open(paths[1], "rb") as f:        # untouched: still corrupt
        assert f.read() != pristine[1]
    r2 = scrub_local_dir(str(tmp_path), repair=True)[0]
    assert r2.repaired == r.corrupt
    with open(paths[1], "rb") as f:
        assert f.read() == pristine[1]


def test_same_stripe_double_loss_unrepairable(tmp_path):
    # node1:block0 is stripe-0 data; node0 holds stripe 0's parity —
    # each reconstruction needs the other clean, so neither heals
    _, paths, pristine = make_local_family(str(tmp_path), n=3, bs=512)
    corrupt_local(paths[1], 0)
    corrupt_local(paths[0], 2 * 512 + 1)   # node0 parity region
    r = scrub_local_dir(str(tmp_path))[0]
    assert sorted(r.corrupt) == ["node0:parity", "node1:block0"]
    assert not r.repaired
    assert sorted(r.unrepairable) == ["node0:parity", "node1:block0"]


def test_two_data_blocks_same_stripe_unrepairable(tmp_path):
    # stripe 0's two data blocks live on node1 (li 0) and node2 (li 0):
    # each sibling is the other's reconstruction input
    _, paths, _ = make_local_family(str(tmp_path), n=3, bs=512)
    corrupt_local(paths[1], 5)
    corrupt_local(paths[2], 5)
    r = scrub_local_dir(str(tmp_path))[0]
    assert sorted(r.unrepairable) == ["node1:block0", "node2:block0"]


def test_independent_stripes_both_heal(tmp_path):
    _, paths, pristine = make_local_family(str(tmp_path), n=3, bs=512)
    corrupt_local(paths[1], 5)             # stripe 0
    corrupt_local(paths[2], 512 + 5)       # node2 block1 -> stripe 1
    r = scrub_local_dir(str(tmp_path))[0]
    assert len(r.corrupt) == 2
    assert sorted(r.repaired) == ["node1:block0", "node2:block1"]
    for nd in (1, 2):
        with open(paths[nd], "rb") as f:
            assert f.read() == pristine[nd]


def test_n1_family_has_no_parity_to_repair_from(tmp_path):
    _, paths, _ = make_local_family(str(tmp_path), n=1, bs=256)
    corrupt_local(paths[0], 9)
    r = scrub_local_dir(str(tmp_path))[0]
    assert r.corrupt and r.unrepairable == r.corrupt and not r.repaired


def test_torn_and_skipped_families_left_alone(tmp_path):
    make_local_family(str(tmp_path), n=3, bs=512, step=4)
    _, paths5, _ = make_local_family(str(tmp_path), n=3, bs=512, step=5)
    os.unlink(paths5[2])                   # torn: GC's problem, not ours
    make_local_family(str(tmp_path), n=3, bs=512, step=6)
    reports = scrub_local_dir(str(tmp_path), skip_steps=[6])
    assert [r.step for r in reports] == [4]


def test_unreadable_head_is_an_error_not_a_crash(tmp_path):
    make_local_family(str(tmp_path), n=3, bs=512, step=4)
    with open(os.path.join(str(tmp_path), "step-6-node-0.reft"),
              "wb") as f:
        f.write(b"\x00garbage")
    reports = {r.step: r for r in scrub_local_dir(str(tmp_path))}
    assert reports[4].clean
    assert reports[6].errors and not reports[6].corrupt


# ------------------------------------------------------ object scrubs
def test_object_family_detect_and_repair(tmp_path):
    store = LocalObjectStore(str(tmp_path))
    make_object_family(store, n=3, bs=512, step=4)
    key = shard_key("families", 4, 0)
    before = bytes(store.read(key))
    corrupt_remote(store, "families", 4, node=0, off=512 + 3)
    r = scrub_object_store(store, "families")[0]
    assert r.kind == "object"
    assert r.corrupt == ["node0:block1"] == r.repaired
    assert bytes(store.read(key)) == before
    assert scrub_object_store(store, "families")[0].clean


def test_object_repair_without_write_range_falls_back(tmp_path):
    class NoWriteRange:
        """A store that only offers whole-object put: repair must go
        read-patch-put."""
        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, name):
            if name == "write_range":
                raise AttributeError(name)
            return getattr(self._inner, name)

    store = LocalObjectStore(str(tmp_path))
    make_object_family(store, n=3, bs=512, step=4)
    key = shard_key("families", 4, 1)
    before = bytes(store.read(key))
    corrupt_remote(store, "families", 4, node=1, off=6)
    r = scrub_object_store(NoWriteRange(store), "families")[0]
    assert r.repaired == ["node1:block0"]
    assert bytes(store.read(key)) == before


def test_object_scrub_skips_inflight_steps(tmp_path):
    store = LocalObjectStore(str(tmp_path))
    make_object_family(store, n=3, bs=512, step=4)
    make_object_family(store, n=3, bs=512, step=5)
    reports = scrub_object_store(store, "families", skip_steps=[5])
    assert [r.step for r in reports] == [4]


# ----------------------------------------------------------- the daemon
def test_scan_once_covers_both_tiers_and_folds_stats(tmp_path):
    local = tmp_path / "ckpt"
    local.mkdir()
    _, paths, _ = make_local_family(str(local), n=3, bs=512)
    store = LocalObjectStore(str(tmp_path / "obj"))
    make_object_family(store, n=3, bs=512, step=7)
    corrupt_local(paths[0], 1)
    corrupt_remote(store, "families", 7, node=2, off=2)
    seen = []
    sc = Scrubber(ckpt_dir=str(local), store=store, prefix="families",
                  interval_s=0.0, on_report=seen.append)
    reports = sc.scan_once()
    assert {r.kind for r in reports} == {"file", "object"}
    assert sum(len(r.repaired) for r in reports) == 2
    assert seen == reports                 # on_report got every family
    st = sc.stats()
    assert st["scrub_passes"] == 1 and st["scrub_families"] == 2
    assert st["scrub_corrupt"] == 2 == st["scrub_repaired"]
    assert st["scrub_unrepairable"] == 0 == st["scrub_errors"]
    assert st["scrub_segments"] == 2 * 9 and st["scrub_seconds"] > 0
    assert all(r.clean for r in sc.scan_once())


def test_daemon_cadence_and_stop(tmp_path):
    _, paths, pristine = make_local_family(str(tmp_path), n=3, bs=512)
    corrupt_local(paths[1], 4)
    sc = Scrubber(ckpt_dir=str(tmp_path), interval_s=0.05)
    sc.start()
    sc.start()                             # idempotent
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        if sc.stats()["scrub_passes"] >= 2:
            break
        time.sleep(0.02)
    sc.stop()
    st = sc.stats()
    assert st["scrub_passes"] >= 2
    assert st["scrub_repaired"] >= 1       # the daemon itself healed it
    with open(paths[1], "rb") as f:
        assert f.read() == pristine[1]
    time.sleep(0.12)                       # no passes after stop
    assert sc.stats()["scrub_passes"] == st["scrub_passes"]


def test_skip_steps_callable_consulted_each_pass(tmp_path):
    make_local_family(str(tmp_path), n=3, bs=512, step=4)
    make_local_family(str(tmp_path), n=3, bs=512, step=5)
    inflight = [5]
    sc = Scrubber(ckpt_dir=str(tmp_path), interval_s=0.0,
                  skip_steps=lambda: list(inflight))
    assert [r.step for r in sc.scan_once()] == [4]
    inflight.clear()                       # persist landed: scrub it now
    assert [r.step for r in sc.scan_once()] == [4, 5]
