"""Figure 8: parameter survival probability, REFT vs checkpoint-only.

3072-GPU system (768 4-GPU nodes), SGs of 6, hw/sw failure rates 1e-4,
Weibull shapes c in {1.0, 1.3, 1.5, 2.0}.  Reports the safe horizon
(latest t with P >= 0.9) for both schemes and the ratio.
"""
from __future__ import annotations

from repro.core import policy


def run() -> list:
    rows = []
    k = (3072 // 4 // 6) * 6               # nodes, multiple of SG size
    n = 6
    lam = 1e-4
    for c in (1.0, 1.3, 1.5, 2.0):
        t_re = policy.safe_horizon(
            lambda t: policy.reft_survival(k, n, t, lam_hw=lam, c=c))
        t_ck = policy.safe_horizon(
            lambda t: policy.ckpt_survival(k, t, lam_hw=lam, lam_sw=lam,
                                           c=c))
        rows.append(("fig8_safe_horizon", c, t_re, t_ck,
                     t_re / max(t_ck, 1e-9)))
    return rows


def main():
    print("bench,shape_c,reft_horizon,ckpt_horizon,ratio")
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]:.2f},{r[3]:.2f},{r[4]:.1f}x")


if __name__ == "__main__":
    main()
