"""Tier-4 object-store figures: upload overlap, ranged remote restore,
and scrubber detection/repair.

Rows (the `BENCH_objstore.json` CI artifact):
  upload_overlap_stall     trainer-side stall of persist(wait=False)
                           with remote uploads in flight vs the blocking
                           drain — DataStates-LLM's "remote tier must
                           stay lazy" claim in seconds
  upload_drain             wall time of the full async round (local
                           write + stripe-multipart upload + manifest)
  restore_remote_full      ranged remote restore, whole family
  restore_remote_partial   single-leaf partial plan over remote ranges
  restore_local_tier3      local `.reft` FileSource equivalent
  scrub_pass               digest walk over both tiers (clean)
  scrub_repair             injected stripe corruption: detect + parity
                           repair, both tiers

`--scrub-smoke` is the CI gate mode: exit 0 iff an injected corrupt
stripe in a `LocalObjectStore` family is detected AND repaired from
parity (and a local-file corruption likewise).

    PYTHONPATH=src python benchmarks/objstore.py [--smoke]
        [--json BENCH_objstore.json] [--scrub-smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

if __package__ in (None, ""):                    # `python benchmarks/x.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src"))

BYTES_FULL = 32 << 20
BYTES_SMOKE = 4 << 20


def row(name: str, seconds: float, detail: str = "", **extra) -> dict:
    out = {"name": name, "seconds": seconds, "detail": detail}
    out.update(extra)
    return out


def _corrupt_remote(store, prefix, step, node) -> None:
    from repro.store import load_manifest
    ent = load_manifest(store, prefix, step)["nodes"][node]
    store.write_range(ent["key"], int(ent["data_off"]) + 3,
                      b"\xde\xad\xbe\xef")


def _corrupt_local(ckpt_dir, step, node) -> None:
    import pickle
    p = os.path.join(ckpt_dir, f"step-{step}-node-{node}.reft")
    with open(p, "rb") as f:
        pickle.load(f)
        off = f.tell()
    with open(p, "r+b") as f:
        f.seek(off + 3)
        f.write(b"\x55\xaa\x55\xaa")


def run_upload_overlap(nbytes: int) -> list:
    """Async persist+upload stall vs blocking drain, through the facade."""
    from benchmarks.common import make_param_state
    from repro.api import CheckpointSpec

    rows = []
    state = make_param_state(nbytes)
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="objstore", ckpt_dir=d, sg_size=4,
                              resume=False,
                              options={"scrub_every_s": 0.0})
        with spec.build(state) as ck:
            ck.snapshot(state, 1, wait=True)
            t0 = time.perf_counter()
            ck.persist(step=None, wait=False)       # fire
            stall = time.perf_counter() - t0        # trainer-side cost
            t0 = time.perf_counter()
            ck.wait()                               # drain round
            drain = time.perf_counter() - t0
            st = ck.stats()
            rows.append(row("upload_overlap_stall", stall,
                            "persist(wait=False) trainer-side",
                            upload_bytes=st.get("persist_upload_bytes", 0)))
            rows.append(row("upload_drain", drain,
                            "local write + stripe multipart + manifest",
                            upload_seconds=st.get("persist_upload_seconds",
                                                  0.0)))
    return rows


def run_restore_compare(nbytes: int) -> list:
    """Remote ranged restore vs local tier-3, same persisted family."""
    from benchmarks.recovery import run_objstore
    name_map = {"objstore_remote_full": "restore_remote_full",
                "objstore_remote_partial": "restore_remote_partial",
                "objstore_local_tier3_full": "restore_local_tier3"}
    rows = []
    for r in run_objstore(nbytes):
        if r["name"] in name_map:
            r = dict(r)
            r["name"] = name_map[r["name"]]
            rows.append(r)
    return rows


def run_scrub(nbytes: int, smoke_gate: bool = False) -> list:
    """Clean scrub pass timing + injected-corruption detect/repair; with
    `smoke_gate`, raise unless both tiers detect AND repair."""
    from benchmarks.common import make_param_state
    from repro.api import CheckpointSpec

    rows = []
    state = make_param_state(nbytes)
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="objstore", ckpt_dir=d, sg_size=4,
                              resume=False,
                              options={"scrub_every_s": 0.0})
        with spec.build(state) as ck:
            ck.snapshot(state, 1, wait=True)
            step = ck.persist(wait=True)

            t0 = time.perf_counter()
            clean = ck.scrub()
            rows.append(row("scrub_pass", time.perf_counter() - t0,
                            f"families={len(clean)} clean",
                            segments=sum(r.segments for r in clean)))
            assert all(r.clean for r in clean), \
                [r.corrupt + r.errors for r in clean]

            _corrupt_remote(ck.store, ck.store_prefix, step, node=1)
            _corrupt_local(d, step, node=2)
            t0 = time.perf_counter()
            reports = ck.scrub()
            found = [r for r in reports if r.corrupt]
            repaired = [r for r in reports if r.repaired]
            rows.append(row("scrub_repair", time.perf_counter() - t0,
                            f"corrupt={sum(len(r.corrupt) for r in reports)}"
                            f" repaired="
                            f"{sum(len(r.repaired) for r in reports)}"))
            if smoke_gate:
                kinds_found = {r.kind for r in found}
                kinds_fixed = {r.kind for r in repaired}
                assert kinds_found == {"file", "object"}, \
                    f"detection missed a tier: {kinds_found}"
                assert kinds_fixed == {"file", "object"}, \
                    f"repair missed a tier: {kinds_fixed}"
                again = ck.scrub()
                assert all(r.clean for r in again), \
                    [r.corrupt + r.errors for r in again]
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small payload (CI)")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--scrub-smoke", action="store_true",
                    help="CI gate: exit nonzero unless injected stripe "
                         "corruption is detected and parity-repaired in "
                         "both durable tiers")
    args = ap.parse_args(argv)
    nbytes = BYTES_SMOKE if (args.smoke or args.scrub_smoke) else BYTES_FULL

    if args.scrub_smoke:
        run_scrub(nbytes, smoke_gate=True)
        print("[scrub-smoke] detection + parity repair OK in both tiers")
        return 0

    rows = (run_upload_overlap(nbytes) + run_restore_compare(nbytes)
            + run_scrub(nbytes))
    print("bench,seconds,detail")
    for r in rows:
        print(f"{r['name']},{r['seconds']:.4f},{r['detail']}")
    if args.json:
        payload = {"bench": "objstore", "rows": rows}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"[json] wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
