"""Empirical reliability sweep: does the real system match §5's math?

Runs many short LocalCluster episodes; in each, every node independently
fails with probability p per round (random software-or-node failure).  We
record which recovery tier the real system needs and compare the measured
rates against the analytical predictions:

  P(in-memory survivable)  = (1-p_node)^n           (no node loss)
  P(raim5 survivable)      = + n p_node (1-p_node)^(n-1)   (<=1 loss)
  P(needs checkpoint)      = Eq. 7: 1 - above

Recovery is additionally asserted bit-exact in every episode.
"""
from __future__ import annotations

import tempfile

import numpy as np

from repro.api import CheckpointSpec
from repro.core.cluster import LocalCluster
from repro.core.policy import reft_fail_rate

N = 4
EPISODES = 12
ROUNDS = 3
P_NODE = 0.25        # high rate so a dozen episodes see every tier


def run(episodes: int = EPISODES, seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    tiers = {"in-memory": 0, "raim5": 0, "checkpoint": 0}
    exact = 0
    for ep in range(episodes):
        with tempfile.TemporaryDirectory() as d:
            spec = CheckpointSpec(backend="reft", ckpt_dir=d,
                                  snapshot_every_steps=1,
                                  bucket_bytes=1 << 20)
            c = LocalCluster(N, seed=100 + ep, nbytes=1 << 14, spec=spec)
            try:
                c.run_rounds(ROUNDS)
                c.checkpoint()
                c.run_rounds(1)
                # random failure pattern
                killed_nodes = [i for i in range(N)
                                if rng.random() < P_NODE]
                soft = [i for i in range(N)
                        if i not in killed_nodes and rng.random() < P_NODE]
                for i in killed_nodes:
                    c.kill_node(i)
                for i in soft:
                    c.kill_trainer(i)
                state, step, tier = c.recover()
                tiers[tier] += 1
                if np.all([np.array_equal(np.asarray(a), np.asarray(b))
                           for a, b in zip(
                               _leaves(state),
                               _leaves(c.expected_state(step)))]):
                    exact += 1
            finally:
                c.close()

    p_ck_pred = reft_fail_rate(P_NODE, N)
    rows = [
        ("sweep_episodes", episodes, ""),
        ("sweep_bitexact", exact, f"of {episodes}"),
        ("sweep_tier_inmemory", tiers["in-memory"],
         f"pred~{(1-P_NODE)**N * episodes:.1f}"),
        ("sweep_tier_raim5", tiers["raim5"],
         f"pred~{N*P_NODE*(1-P_NODE)**(N-1) * episodes:.1f}"),
        ("sweep_tier_checkpoint", tiers["checkpoint"],
         f"pred~{p_ck_pred * episodes:.1f} (Eq.7)"),
    ]
    return rows


def _leaves(tree):
    import jax
    return jax.tree.leaves(tree)


def main():
    print("bench,count,derived")
    for name, v, d in run():
        print(f"{name},{v},{d}")


if __name__ == "__main__":
    main()
