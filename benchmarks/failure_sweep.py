"""Per-scenario failure sweep: the full taxonomy under the supervisor.

One supervised episode per scenario kind (software, node, smp, laggard,
corrupt-stripe, slow-persist, preempt, plus an elastic n->m preempt):
inject -> detect -> heal/reshard -> verify byte-exact, with every second
attributed in the goodput ledger.  Rows report per-kind recovery tier,
detect/restore latency, and bit-exactness; the aggregate section folds in
the analytic survival model (Fig. 8 safe horizons, formerly
`benchmarks/survival.py`) so one report covers both the measured and the
predicted reliability story.

  PYTHONPATH=src python -m benchmarks.failure_sweep \\
      [--episodes-per-kind 1] [--json BENCH_failure_sweep.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile

from repro.api import CheckpointSpec
from repro.core import policy
from repro.core.cluster import make_state, update_state
from repro.supervise import KINDS, Scenario, Supervisor

N = 4
STEPS = 10
FAIL_STEP = 5
NBYTES = 1 << 14


def episode(kind: str, seed: int, *, new_sg: int = 0) -> dict:
    """One supervised run with a single mid-flight scenario of `kind`."""
    params = {"new_sg": new_sg} if new_sg else {}
    scen = Scenario(kind, step=FAIL_STEP, node=1 + seed % (N - 1),
                    graceful=False, params=params)
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="reft", ckpt_dir=d, sg_size=N,
                              snapshot_every_steps=1,
                              checkpoint_every_steps=4,
                              bucket_bytes=1 << 20, resume=False)
        sup = Supervisor(spec, make_state(100 + seed, nbytes_approx=NBYTES),
                         lambda st, s: update_state(st, s),
                         scenarios=[scen])
        out = sup.run(STEPS)
    ev = out["events"][0] if out["events"] else {}
    g = out["goodput"]
    return {
        "kind": kind + (f"-elastic-{N}to{new_sg}" if new_sg else ""),
        "recovered": bool(ev.get("recovered", False)),
        "bit_exact": ev.get("bit_exact"),
        "tier": ev.get("tier"),
        "perf_only": bool(ev.get("perf_only", False)),
        "detect_s": ev.get("detect_s"),
        "restore_s": ev.get("restore_s"),
        "rolled_back": ev.get("rolled_back"),
        "unrecovered": out["unrecovered"],
        "goodput_frac": g["goodput_frac"],
        "wall_s": g["wall_seconds"],
        "accounting_error": g["accounting_error"],
    }


def survival_rows() -> list:
    """Fig. 8 safe horizons (analytic): REFT vs checkpoint-only on a
    3072-GPU system (768 4-GPU nodes, SGs of 6), Weibull shape swept."""
    rows = []
    k = (3072 // 4 // 6) * 6
    n, lam = 6, 1e-4
    for c in (1.0, 1.3, 1.5, 2.0):
        t_re = policy.safe_horizon(
            lambda t: policy.reft_survival(k, n, t, lam_hw=lam, c=c))
        t_ck = policy.safe_horizon(
            lambda t: policy.ckpt_survival(k, t, lam_hw=lam, lam_sw=lam,
                                           c=c))
        rows.append({"shape_c": c, "reft_horizon_s": t_re,
                     "ckpt_horizon_s": t_ck,
                     "ratio": t_re / max(t_ck, 1e-9)})
    return rows


def run(episodes_per_kind: int = 1) -> dict:
    rows = []
    for rep in range(episodes_per_kind):
        for kind in KINDS:
            rows.append(episode(kind, seed=rep))
        rows.append(episode("preempt", seed=rep, new_sg=N // 2))
    failures = [r for r in rows if not r["perf_only"]]
    return {
        "rows": rows,
        "survival_fig8": survival_rows(),
        "aggregate": {
            "episodes": len(rows),
            "unrecovered": sum(r["unrecovered"] for r in rows),
            "bit_exact": sum(1 for r in failures if r["bit_exact"]),
            "bit_exact_of": len(failures),
            "mean_goodput_frac": (sum(r["goodput_frac"] for r in rows)
                                  / max(len(rows), 1)),
            "max_accounting_error": max(r["accounting_error"]
                                        for r in rows),
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes-per-kind", type=int, default=1)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    out = run(args.episodes_per_kind)
    print("bench,kind,recovered,bit_exact,tier,detect_s,restore_s,goodput")
    for r in out["rows"]:
        det = "" if r["detect_s"] is None else f"{r['detect_s']:.3f}"
        res = "" if r["restore_s"] is None else f"{r['restore_s']:.3f}"
        print(f"sweep,{r['kind']},{r['recovered']},{r['bit_exact']},"
              f"{r['tier']},{det},{res},{r['goodput_frac']:.3f}")
    for s in out["survival_fig8"]:
        print(f"fig8_safe_horizon,c={s['shape_c']},"
              f"{s['reft_horizon_s']:.2f},{s['ckpt_horizon_s']:.2f},"
              f"{s['ratio']:.1f}x")
    agg = out["aggregate"]
    print(f"aggregate,episodes={agg['episodes']},"
          f"unrecovered={agg['unrecovered']},"
          f"bitexact={agg['bit_exact']}/{agg['bit_exact_of']},"
          f"goodput={agg['mean_goodput_frac']:.3f},"
          f"acct_err={agg['max_accounting_error']:.4f}")
    assert agg["unrecovered"] == 0, "sweep left unrecovered failures"
    assert agg["bit_exact"] == agg["bit_exact_of"], \
        "a recovery was not bit-exact"
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=2, default=str)
        print(f"[json] wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
