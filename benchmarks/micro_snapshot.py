"""Figure 9: single-node micro-benchmark.

Four simulated GPUs snapshot a synthetic parameter set; we measure (per
method) the phase speeds actually achievable on this host:
  d2h        — device->host copy (jax array -> numpy)
  sha-mem    — staging-ring write + SMP copy (REFT-Sn's extra hop)
  serialize  — byte-stream framing (CheckFreq/TorchSnapshot phase 2)
  persist    — disk write
and the end-to-end 'perf' GB/s of REFT-Sn / REFT-Ckpt / CheckFreq /
TorchSnapshot, reproducing the figure's ordering.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import make_param_state, tree_bytes
from repro.ckpt import CheckFreqCheckpointer, TorchSnapshotCheckpointer
from repro.core.snapshot import ReftConfig, SnapshotEngine

SIZE = 256 << 20          # 256 MB synthetic state (paper used 20 GB/4 GPUs)


def run(size: int = SIZE) -> list:
    state = make_param_state(size)
    nbytes = tree_bytes(state)
    gb = nbytes / 2 ** 30
    rows = []

    # --- REFT-Sn: async sharded snapshot to SMP shared memory
    eng = SnapshotEngine(0, 1, state, ReftConfig(bucket_bytes=16 << 20))
    try:
        eng.snapshot_sync(state, 1)                     # warm
        t0 = time.perf_counter()
        eng.snapshot_sync(state, 2)
        t_sn = time.perf_counter() - t0
        rows.append(("fig9_reft_sn", t_sn, gb / t_sn))

        # --- REFT-Ckpt: SMP persists its clean buffer (no trainer time)
        with tempfile.NamedTemporaryFile(suffix=".reft") as f:
            t0 = time.perf_counter()
            eng.persist(f.name)
            t_ck = time.perf_counter() - t0
        rows.append(("fig9_reft_ckpt", t_ck, gb / t_ck))
    finally:
        eng.close()

    # --- CheckFreq (full async ckpt) / TorchSnapshot (sharded async ckpt)
    for cls, kw, name in [
            (CheckFreqCheckpointer, {}, "fig9_checkfreq"),
            (TorchSnapshotCheckpointer, {"n_ranks": 4},
             "fig9_torchsnapshot")]:
        with tempfile.TemporaryDirectory() as d:
            ck = cls(d, state, **kw)
            ck.save_sync(state, 1)                      # warm
            t = ck.save_sync(state, 2)
            rows.append((name, t.total, gb / t.total))
            rows.append((name + "_d2h", t.d2h, gb / max(t.d2h, 1e-9)))
            rows.append((name + "_persist", t.persist,
                         gb / max(t.persist, 1e-9)))
    return rows


def main():
    print("bench,seconds,GB_per_s")
    for name, s, gbps in run():
        print(f"{name},{s:.4f},{gbps:.2f}")


if __name__ == "__main__":
    main()
