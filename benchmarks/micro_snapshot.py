"""Figure 9: single-node micro-benchmark, driven through the facade.

Every backend is timed through the SAME `Checkpointer` calls, so the
comparison is apples-to-apples by construction:
  reft        — HASC async pipeline snapshot to SMP shared memory
                (REFT-Sn), plus the SMP-side persist (REFT-Ckpt, no
                trainer time); reported with the per-level decomposition
                (L1 device reads / L2 ring staging / L3 SMP signal+ack)
  sync_disk   — blocking full-state disk save
  async_disk  — CheckFreq-style overlapped full save; with shard=True the
                TorchSnapshot-style 1/m-per-rank variant (parallel I/O)
Phase rows (d2h / persist) reproduce the figure's decomposition for the
disk paths.  `fig9_reft_sn_encode_{host,device}` time the same snapshot
through the host encode path and the device-side fused Pallas
gather+XOR+CRC path (interpret-mode on CPU), with a byte-identity check
between the two (`encode_*` rows / the JSON `encode` field).

`fig_persist_overlap_*` rows compare blocking vs async REFT-Ckpt
persistence against a simulated slow durable tier: the trainer-side
stall of an inline persist vs the fire cost + step-time delta of
`persist(wait=False)` while the SMPs stream shards in the background
(`persist_overlap` in the JSON artifact, with an `async_nonblocking`
check).

The run ends with a training-interference probe: median step time of a
small jitted compute loop with snapshotting off, then with a snapshot
permanently in flight — once against the pre-refactor serial thread
(`pipeline=False`) and once against the HASC pipeline.  The pipelined
engine's step-time delta must be no worse than the serial thread's.

    PYTHONPATH=src python benchmarks/micro_snapshot.py [--smoke] \\
        [--json BENCH_micro_snapshot.json]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import tempfile
import time

if __package__ in (None, ""):                    # `python benchmarks/x.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import make_param_state, tree_bytes
from repro.api import CheckpointSpec

SIZE = 256 << 20          # 256 MB synthetic state (paper used 20 GB/4 GPUs)
SMOKE_SIZE = 8 << 20

VARIANTS = [
    ("reft_sn", "reft", {}),
    ("sync_disk", "sync_disk", {}),
    ("checkfreq", "async_disk", {}),
    ("torchsnapshot", "async_disk", {"shard": True}),
]

LEVELS = ("l1", "l1_stall", "l2", "l3")


def _time_snapshot(ck, state) -> float:
    ck.snapshot(state, 1, wait=True)                    # warm
    t0 = time.perf_counter()
    ck.snapshot(state, 2, wait=True)
    return time.perf_counter() - t0


def run(size: int = SIZE) -> list:
    state = make_param_state(size)
    gb = tree_bytes(state) / 2 ** 30
    rows = []
    for label, backend, opts in VARIANTS:
        with tempfile.TemporaryDirectory() as d:
            spec = CheckpointSpec(backend=backend, ckpt_dir=d, sg_size=4,
                                  resume=False, options=opts)
            with spec.build(state) as ck:
                if backend == "reft":
                    ck.snapshot(state, 1, wait=True)    # warm outside delta
                    lv0 = ck.stats()
                    t0 = time.perf_counter()
                    ck.snapshot(state, 2, wait=True)
                    t = time.perf_counter() - t0
                else:
                    t = _time_snapshot(ck, state)
                rows.append((f"fig9_{label}", t, gb / t))

                if backend == "reft":
                    # HASC per-level decomposition of the timed snapshot
                    lv1 = ck.stats()
                    for k in LEVELS:
                        key = f"engine_{k}_seconds"
                        dt = lv1.get(key, 0.0) - lv0.get(key, 0.0)
                        rows.append((f"fig9_reft_sn_{k}", dt,
                                     gb / dt if dt > 1e-6 else 0.0))
                    # REFT-Ckpt: persist runs inside the SMP — the trainer
                    # only pays the RPC round trip
                    t0 = time.perf_counter()
                    ck.persist()
                    t_ck = time.perf_counter() - t0
                    rows.append(("fig9_reft_ckpt", t_ck, gb / t_ck))
                else:
                    pt = ck.writer.last_times
                    rows.append((f"fig9_{label}_d2h", pt.d2h,
                                 gb / max(pt.d2h, 1e-9)))
                    rows.append((f"fig9_{label}_persist", pt.persist,
                                 gb / max(pt.persist, 1e-9)))
    return rows


def encode_paths(size: int):
    """Device-vs-host snapshot encode on the same state (sg_size=4, so
    parity stripes are exercised): one timed snapshot per path
    (`fig9_reft_sn_encode_{host,device}`) plus a byte-identity check —
    the device path (fused Pallas gather+XOR+CRC, interpret-mode on CPU
    CI) must publish bit-identical own bytes, parity bytes, and
    own-region CRC, or the rows are meaningless."""
    import pickle

    from repro.core.smp import ReadOnlyNode

    state = make_param_state(size)
    gb = tree_bytes(state) / 2 ** 30
    rows, probes = [], {}
    for mode in ("host", "device"):
        opts = {"device_encode": "off" if mode == "host" else "on"}
        with tempfile.TemporaryDirectory() as d:
            spec = CheckpointSpec(backend="reft", ckpt_dir=d, sg_size=4,
                                  resume=False, options=opts)
            with spec.build(state) as ck:
                ck.snapshot(state, 1, wait=True)            # warm/compile
                t0 = time.perf_counter()
                ck.snapshot(state, 2, wait=True)
                t = time.perf_counter() - t0
                rows.append((f"fig9_reft_sn_encode_{mode}", t, gb / t))
                e0 = ck.group.engines[0]
                view = ReadOnlyNode(e0.run, 0, 4, e0.spec.total_bytes)
                try:
                    probes[mode] = {
                        "own": view.read_own(2).tobytes(),
                        "parity": view.read_parity(2).tobytes(),
                        "crc": pickle.loads(view.meta(2)).get("crc_own"),
                    }
                finally:
                    view.close()
    checks = {
        "own_identical": probes["host"]["own"] == probes["device"]["own"],
        "parity_identical":
            probes["host"]["parity"] == probes["device"]["parity"],
        "crc_identical": probes["host"]["crc"] is not None
            and probes["host"]["crc"] == probes["device"]["crc"],
    }
    if not all(checks.values()):
        raise RuntimeError(f"device/host encode mismatch: {checks}")
    return rows, checks


def moe_state(size: int, experts: int = 8):
    """Synthetic 8-expert MoE-shaped state: two expert-stacked weight
    leaves dominate the bytes, plus a small dense router (dirty every
    step, like real routers/norms)."""
    import jax.numpy as jnp
    import numpy as np

    d = max(int((size / (2 * experts * 4)) ** 0.5), 16)
    rng = np.random.RandomState(0)

    def mk(*sh):
        return jnp.asarray(rng.rand(*sh), jnp.float32)

    return {"router": mk(256, experts),
            "wi_gate": mk(experts, d, d), "wo": mk(experts, d, d)}


def delta_snapshot(size: int, dirty_experts: int = 2) -> tuple:
    """Dirty-delta snapshot cost vs the full path (ISSUE 7 acceptance).

    Same MoE-shaped state, same bucket geometry, two backends: delta ON
    with the router reporting `dirty_experts`/8 experts touched
    (provider = `expert_dirty_ranges`), and plain full snapshots.  The
    timed delta flight's d2h bytes and engine L1 seconds must come in at
    <= 0.5x the full flight's (`delta_le_half` in the JSON artifact /
    the `--delta-smoke` gate)."""
    from repro.core.delta import expert_dirty_ranges

    E = 8
    state = moe_state(size, E)
    gb = tree_bytes(state) / 2 ** 30
    touched = [i < dirty_experts for i in range(E)]

    def mutate(st):
        out = dict(st)
        for k in ("wi_gate", "wo"):
            out[k] = st[k].at[:dirty_experts].add(1.0)
        return out

    probes = {}
    # identical FIXED probe geometry for both modes: buckets fine enough
    # that the provider's skip granularity tracks the expert stride
    # (coarse buckets smear one dirty expert across many clean parity
    # sources), and sg_size=2 so only two SMP processes contend with the
    # timed trainer thread on small CI runners
    bb = 128 << 10
    reps = 7
    for mode, opts in (
            ("full", {}),
            ("delta", {"delta": True, "delta_keyframe": 10 ** 6,
                       "delta_dirty_threshold": 0.9})):
        with tempfile.TemporaryDirectory() as d:
            spec = CheckpointSpec(backend="reft", ckpt_dir=d, sg_size=2,
                                  bucket_bytes=bb, resume=False,
                                  options=opts)
            with spec.build(state) as ck:
                if mode == "delta":
                    fspec = ck.group.engines[0].spec
                    ck.set_dirty_provider(
                        lambda: expert_dirty_ranges(fspec, touched))
                ck.snapshot(state, 1, wait=True)    # warm (delta: keyframe)
                st2, walls, bts, l1s = state, [], [], []
                for r in range(reps):
                    st2 = mutate(st2)
                    s0 = ck.stats()
                    t0 = time.perf_counter()
                    ck.snapshot(st2, 2 + r, wait=True)
                    walls.append(time.perf_counter() - t0)
                    s1 = ck.stats()
                    bts.append(s1["engine_bytes_sent"]
                               - s0["engine_bytes_sent"])
                    l1s.append(s1["engine_l1_seconds"]
                               - s0["engine_l1_seconds"])
                # bytes are deterministic (median = any rep); timings use
                # the min over reps — the cost floor — because single-core
                # CI boxes overlay scheduler noise that medians still carry
                probes[mode] = {
                    "seconds": min(walls),
                    "bytes": statistics.median(bts),
                    "l1_seconds": min(l1s),
                    "skipped_buckets": s1.get("skipped_buckets", 0),
                    "delta_flights": s1.get("delta_flights", 0),
                }
        if mode == "delta" and probes[mode]["delta_flights"] < reps:
            raise RuntimeError("delta probe invalid: not every timed "
                               "flight was a delta flight")
    dirty_frac = (dirty_experts / E)
    byr = probes["delta"]["bytes"] / max(probes["full"]["bytes"], 1)
    l1r = probes["delta"]["l1_seconds"] \
        / max(probes["full"]["l1_seconds"], 1e-9)
    rows = [
        ("fig_delta_full_seconds", probes["full"]["seconds"],
         gb / probes["full"]["seconds"]),
        ("fig_delta_seconds", probes["delta"]["seconds"],
         gb / probes["delta"]["seconds"]),
        ("fig_delta_full_bytes", float(probes["full"]["bytes"]), 0.0),
        ("fig_delta_bytes", float(probes["delta"]["bytes"]), byr),
        ("fig_delta_dirty_frac", dirty_frac, 0.0),
    ]
    checks = {
        "dirty_experts": dirty_experts,
        "delta_bytes_ratio": byr,
        "delta_l1_ratio": l1r,
        "skipped_buckets": probes["delta"]["skipped_buckets"],
        # acceptance: <=2/8 dirty experts must at least halve both the
        # d2h+send bytes and the trainer-side L1 time of a flight
        "delta_le_half": byr <= 0.5 and l1r <= 0.5,
    }
    return rows, checks


def persist_overlap(size: int, steps: int = 40,
                    delay_s: float = 0.35) -> tuple:
    """Blocking vs async REFT-Ckpt persist interference on step time.

    One reft backend, sg_size=4, with a simulated slow durable tier
    (`persist_delay_s` — real CI disks are too fast to show the stall).
    The BLOCKING row is the trainer-side stall of an inline persist; the
    ASYNC rows are the fire cost of `persist(wait=False)` plus the
    median step-time delta while the SMPs stream shards in the
    background.  Returns (rows, checks-dict for the JSON artifact)."""
    import statistics
    import tempfile
    import time as _t

    import jax
    import jax.numpy as jnp

    state = make_param_state(size)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.float32)
    f = jax.jit(lambda m: m @ m)
    f(w).block_until_ready()

    def run_steps(n):
        ts = []
        for _ in range(n):
            t0 = _t.perf_counter()
            f(w).block_until_ready()
            ts.append(_t.perf_counter() - t0)
        return statistics.median(ts)

    rows = []
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="reft", ckpt_dir=d, sg_size=4,
                              resume=False,
                              options={"persist_delay_s": delay_s})
        with spec.build(state) as ck:
            ck.snapshot(state, 1, wait=True)
            base = run_steps(steps)

            t0 = _t.perf_counter()
            assert ck.persist(wait=True) == 1
            blocking = _t.perf_counter() - t0       # trainer-side stall

            ck.snapshot(state, 2, wait=True)
            t0 = _t.perf_counter()
            assert ck.persist(wait=False) == 2
            fire = _t.perf_counter() - t0           # ticket cost only
            during = run_steps(steps)               # SMPs writing under us
            t0 = _t.perf_counter()
            ck.wait()                               # drain + collect
            join = _t.perf_counter() - t0
            st = ck.stats()
    delta = during - base
    rows.append(("fig_persist_overlap_blocking_stall_s", blocking, 0.0))
    rows.append(("fig_persist_overlap_async_fire_s", fire, 0.0))
    rows.append(("fig_persist_overlap_async_step_delta_s", delta, 0.0))
    rows.append(("fig_persist_overlap_async_join_s", join, 0.0))
    checks = {
        "baseline_step_s": base,
        "blocking_stall_s": blocking,
        "async_fire_s": fire,
        "async_step_delta_s": delta,
        "async_join_s": join,
        "persist_overlap_seconds": st.get("persist_overlap_seconds", 0.0),
        # the async fire must not pay the durable write: well under the
        # blocking stall (which holds the simulated-fsync delay)
        "async_nonblocking": fire < max(0.25 * blocking, 0.05),
    }
    return rows, checks


def trace_overhead(size: int, reps: int = 5) -> tuple:
    """Runtime protocol-validator overhead on the saving path
    (ReftConfig.trace_protocol): min-over-reps snapshot_sync latency
    with tracing off vs on, identical engine geometry.  Small buckets
    maximize the per-message validator work, so this is the worst case.
    Min-over-reps (not mean) for CI noise immunity; a tiny absolute
    floor absorbs scheduler jitter at smoke sizes."""
    from repro.core import ReftConfig
    from repro.core.snapshot import SnapshotEngine
    state = make_param_state(size)

    def best(trace: bool) -> float:
        cfg = ReftConfig(bucket_bytes=256 << 10, trace_protocol=trace)
        eng = SnapshotEngine(0, 1, state, cfg)
        try:
            eng.snapshot_sync(state, 1)                     # warm
            ts = []
            for i in range(reps):
                t0 = time.perf_counter()
                eng.snapshot_sync(state, 2 + i)
                ts.append(time.perf_counter() - t0)
            return min(ts)
        finally:
            eng.close()

    base = best(False)
    traced = best(True)
    frac = traced / base - 1.0
    ok = frac < 0.05 or (traced - base) < 0.002
    rows = [("save_trace_off", base, size / 2 ** 30 / base),
            ("save_trace_on", traced, size / 2 ** 30 / traced)]
    checks = {"trace_base_s": base, "trace_on_s": traced,
              "trace_overhead_frac": frac, "trace_overhead_ok": ok}
    return rows, checks


def interference(size: int, steps: int = 50, rounds: int = 3) -> dict:
    """Training-interference probe: step-time delta with a snapshot
    permanently in flight, serial thread vs HASC pipeline on the same
    state and bucket geometry.  Rounds interleave baseline/serial/
    pipelined so machine drift cancels; deltas are medians over rounds."""
    import jax
    import jax.numpy as jnp

    from repro.core.pipeline import step_boundary
    from repro.core.snapshot import ReftConfig, SnapshotEngine

    state = make_param_state(size)
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 512), jnp.float32)
    f = jax.jit(lambda m: m @ m)
    f(w).block_until_ready()                              # compile

    def median_step(engine=None) -> float:
        times = []
        snap_step = 10
        for _ in range(steps):
            if engine is not None and not engine.in_flight():
                engine.snapshot_async(state, snap_step)
                snap_step += 1
            t0 = time.perf_counter()
            f(w).block_until_ready()
            step_boundary()                               # the yield hook
            times.append(time.perf_counter() - t0)
        return statistics.median(times)

    # small buckets keep a snapshot in flight across many steps, so the
    # probe measures contention, not the idle tail
    bb = max(64 << 10, size // 256)
    engines = {}
    deltas = {"serial": [], "pipelined": []}
    bases = []
    try:
        for mode, pipelined in (("serial", False), ("pipelined", True)):
            engines[mode] = SnapshotEngine(
                0, 1, state, ReftConfig(pipeline=pipelined, bucket_bytes=bb))
            engines[mode].snapshot_sync(state, 1)         # warm
        order = list(engines.items())
        for r in range(rounds):
            base = median_step(None)
            bases.append(base)
            # alternate measurement order so monotone machine drift (CI
            # warm-up, turbo decay) does not systematically favor the
            # mode measured closer to its round's baseline
            for mode, eng in (order if r % 2 == 0 else order[::-1]):
                n0 = eng.stats["snapshots"]
                deltas[mode].append(median_step(eng) - base)
                eng.wait()
                # a degraded/idle engine would measure baseline-vs-baseline
                # and report vacuous ~zero interference into the artifact
                if eng.degraded or eng.stats["snapshots"] == n0:
                    raise RuntimeError(
                        f"interference probe invalid: {mode} engine made "
                        f"no snapshot progress (degraded={eng.degraded})")
    finally:
        for eng in engines.values():
            eng.close()
    out = {"baseline_s": statistics.median(bases)}
    for mode in ("serial", "pipelined"):
        out[f"{mode}_delta_s"] = statistics.median(deltas[mode])
        out[f"{mode}_s"] = out["baseline_s"] + out[f"{mode}_delta_s"]
    out["pipeline_no_worse"] = (
        out["pipelined_delta_s"] <= max(out["serial_delta_s"], 0.0)
        + 0.25 * out["baseline_s"])       # noise guard band
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small state for CI (seconds, not minutes)")
    ap.add_argument("--size", type=int, default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + interference as JSON "
                         "(CI uploads this as the perf-trajectory artifact)")
    ap.add_argument("--no-interference", action="store_true")
    ap.add_argument("--delta-smoke", action="store_true",
                    help="run ONLY the dirty-delta probe and exit "
                         "non-zero unless a 2/8-dirty-expert delta "
                         "flight costs <= 0.5x the full flight in d2h "
                         "bytes AND engine L1 seconds")
    ap.add_argument("--trace-smoke", action="store_true",
                    help="run ONLY the trace_protocol overhead probe and "
                         "exit non-zero unless the runtime protocol "
                         "validator costs < 5%% on the saving path")
    ap.add_argument("--enforce-interference", action="store_true",
                    help="exit non-zero when the pipelined engine's "
                         "interference exceeds the serial baseline's "
                         "(plus the noise guard band)")
    args = ap.parse_args(argv)
    size = args.size or (SMOKE_SIZE if args.smoke else SIZE)
    if args.trace_smoke:
        t_rows, t_checks = trace_overhead(size)
        print("bench,seconds,GB_per_s")
        for name, sec, g in t_rows:
            print(f"{name},{sec:.6f},{g:.4f}")
        print(f"trace_overhead_frac,{t_checks['trace_overhead_frac']:.4f},")
        print(f"trace_overhead_ok,{int(t_checks['trace_overhead_ok'])},")
        if args.json:
            payload = {"bench": "trace_overhead", "size_bytes": size,
                       "rows": [{"name": n, "seconds": sec, "derived": g}
                                for n, sec, g in t_rows],
                       "trace": t_checks}
            with open(args.json, "w") as fh:
                json.dump(payload, fh, indent=2)
            print(f"[json] wrote {args.json}", file=sys.stderr)
        if not t_checks["trace_overhead_ok"]:
            print("[fail] protocol validator overhead >= 5% on the "
                  "saving path", file=sys.stderr)
            return 2
        return 0
    if args.delta_smoke:
        d_rows, d_checks = delta_snapshot(size)
        print("bench,seconds,derived")
        for name, s, g in d_rows:
            print(f"{name},{s:.6f},{g:.4f}")
        for k in ("delta_bytes_ratio", "delta_l1_ratio"):
            print(f"delta_{k},{d_checks[k]:.4f},")
        print(f"delta_le_half,{int(d_checks['delta_le_half'])},")
        if args.json:
            payload = {"bench": "delta_snapshot", "size_bytes": size,
                       "rows": [{"name": n, "seconds": s, "derived": g}
                                for n, s, g in d_rows],
                       "delta": d_checks}
            with open(args.json, "w") as fh:
                json.dump(payload, fh, indent=2)
            print(f"[json] wrote {args.json}", file=sys.stderr)
        if not d_checks["delta_le_half"]:
            print("[fail] delta flight cost above 0.5x the full flight",
                  file=sys.stderr)
            return 2
        return 0
    rows = run(size)
    d_rows, d_checks = delta_snapshot(size)
    rows += d_rows
    enc_rows, enc_checks = encode_paths(size)
    rows += enc_rows
    po_rows, po = persist_overlap(size)
    rows += po_rows
    print("bench,seconds,GB_per_s")
    for name, s, gbps in rows:
        print(f"{name},{s:.4f},{gbps:.2f}")
    for k, v in enc_checks.items():
        print(f"encode_{k},{int(v)},")
    print(f"persist_overlap_async_nonblocking,"
          f"{int(po['async_nonblocking'])},")
    print(f"delta_le_half,{int(d_checks['delta_le_half'])},")
    inter = None
    if not args.no_interference:
        inter = interference(size)
        print(f"interference_baseline_step_s,{inter['baseline_s']:.5f},")
        for mode in ("serial", "pipelined"):
            print(f"interference_{mode}_delta_s,"
                  f"{inter[f'{mode}_delta_s']:.5f},")
        print(f"interference_pipeline_no_worse,"
              f"{int(inter['pipeline_no_worse'])},")
    if args.json:
        payload = {
            "bench": "micro_snapshot",
            "size_bytes": size,
            "rows": [{"name": n, "seconds": s, "gb_per_s": g}
                     for n, s, g in rows],
            "encode": enc_checks,
            "persist_overlap": po,
            "delta": d_checks,
            "interference": inter,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"[json] wrote {args.json}", file=sys.stderr)
    if args.enforce_interference and inter is not None \
            and not inter["pipeline_no_worse"]:
        print("[fail] pipelined interference exceeds the serial baseline",
              file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
