"""Figure 9: single-node micro-benchmark, driven through the facade.

Every backend is timed through the SAME `Checkpointer` calls, so the
comparison is apples-to-apples by construction:
  reft        — async sharded snapshot to SMP shared memory (REFT-Sn),
                plus the SMP-side persist (REFT-Ckpt, no trainer time)
  sync_disk   — blocking full-state disk save
  async_disk  — CheckFreq-style overlapped full save; with shard=True the
                TorchSnapshot-style 1/m-per-rank variant (parallel I/O)
Phase rows (d2h / persist) reproduce the figure's decomposition for the
disk paths.

    PYTHONPATH=src python benchmarks/micro_snapshot.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

if __package__ in (None, ""):                    # `python benchmarks/x.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import make_param_state, tree_bytes
from repro.api import CheckpointSpec

SIZE = 256 << 20          # 256 MB synthetic state (paper used 20 GB/4 GPUs)
SMOKE_SIZE = 8 << 20

VARIANTS = [
    ("reft_sn", "reft", {}),
    ("sync_disk", "sync_disk", {}),
    ("checkfreq", "async_disk", {}),
    ("torchsnapshot", "async_disk", {"shard": True}),
]


def _time_snapshot(ck, state) -> float:
    ck.snapshot(state, 1, wait=True)                    # warm
    t0 = time.perf_counter()
    ck.snapshot(state, 2, wait=True)
    return time.perf_counter() - t0


def run(size: int = SIZE) -> list:
    state = make_param_state(size)
    gb = tree_bytes(state) / 2 ** 30
    rows = []
    for label, backend, opts in VARIANTS:
        with tempfile.TemporaryDirectory() as d:
            spec = CheckpointSpec(backend=backend, ckpt_dir=d, sg_size=4,
                                  resume=False, options=opts)
            with spec.build(state) as ck:
                t = _time_snapshot(ck, state)
                rows.append((f"fig9_{label}", t, gb / t))

                if backend == "reft":
                    # REFT-Ckpt: persist runs inside the SMP — the trainer
                    # only pays the RPC round trip
                    t0 = time.perf_counter()
                    ck.persist()
                    t_ck = time.perf_counter() - t0
                    rows.append(("fig9_reft_ckpt", t_ck, gb / t_ck))
                else:
                    pt = ck.writer.last_times
                    rows.append((f"fig9_{label}_d2h", pt.d2h,
                                 gb / max(pt.d2h, 1e-9)))
                    rows.append((f"fig9_{label}_persist", pt.persist,
                                 gb / max(pt.persist, 1e-9)))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small state for CI (seconds, not minutes)")
    ap.add_argument("--size", type=int, default=None)
    args = ap.parse_args(argv)
    size = args.size or (SMOKE_SIZE if args.smoke else SIZE)
    print("bench,seconds,GB_per_s")
    for name, s, gbps in run(size):
        print(f"{name},{s:.4f},{gbps:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
