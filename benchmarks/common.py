"""Shared benchmark utilities."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def make_param_state(nbytes: int, seed: int = 0) -> dict:
    """Synthetic 'model + Adam moments' pytree of ~nbytes total."""
    n = max(1024, nbytes // 12)            # bf16 params + 2x fp32 moments
    k = jax.random.PRNGKey(seed)
    return {
        "params": jax.random.normal(k, (n,), jnp.bfloat16),
        "mu": jnp.zeros((n,), jnp.float32),
        "nu": jnp.zeros((n,), jnp.float32),
        "step": jnp.int32(0),
    }


def timeit(fn, *args, repeat: int = 3, warmup: int = 1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def row(name: str, seconds: float, derived: str = "") -> str:
    us = seconds * 1e6
    return f"{name},{us:.1f},{derived}"


def tree_bytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))
