"""§6.2 restarting & recomputation overhead + distributed-loader figures.

Part 1 (the paper's trade): a 4-node lockstep cluster with a fixed
per-step compute time is killed mid-run; we measure (a) in-memory/RAIM5
recovery wall time, (b) checkpoint load wall time, and derive the
recomputation each would pay given the snapshot vs checkpoint intervals —
the '58 s load vs 10 min saved recompute' trade.

Part 2 (facade sweep): every registered backend saves the same state and
is timed through the SAME `Checkpointer.restore()` call, so restore-path
costs are directly comparable across REFT and the disk baselines.

Part 3 (loader figures): the monolithic pre-refactor restore shape
(whole-region reads + full-shard decode on one caller) vs the ranged
`LoadPlan` executors (parallel scatter-gather reads, range-limited RAIM5
decode), full and partial (single-leaf) plans — with bytes_read /
decoded_bytes per row.

    PYTHONPATH=src python benchmarks/recovery.py [--backend B ...]
        [--json BENCH_recovery.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

if __package__ in (None, ""):                    # `python benchmarks/x.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.api import CheckpointSpec
from repro.core.cluster import LocalCluster

STEP_TIME = 0.05
SNAP_EVERY = 1
CKPT_AT = 4          # checkpoint taken at this step
KILL_AT = 12

SWEEP_BYTES = 8 << 20
SWEEP_BACKENDS = ("reft", "sync_disk", "async_disk")
LOADER_BYTES = 32 << 20

LAGGARD_NODE = 2
LAGGARD_FAST_BW = 64 << 20       # healthy member bandwidth (bytes/s)
LAGGARD_SLOW_FACTOR = 8          # laggard runs at fast / this


def row(name: str, seconds: float, detail: str = "", **extra) -> dict:
    out = {"name": name, "seconds": seconds, "detail": detail}
    out.update(extra)
    return out


def _stats_extra(ld) -> dict:
    if ld is None:
        return {}
    out = {"tier": ld.tier, "bytes_read": ld.bytes_read,
           "decoded_bytes": ld.decoded_bytes,
           "read_seconds": ld.read_seconds,
           "decode_seconds": ld.decode_seconds,
           "h2d_seconds": ld.h2d_seconds,
           "resharded": ld.resharded}
    if getattr(ld, "sched", ""):
        out.update(sched=ld.sched,
                   overlap_seconds=ld.overlap_seconds,
                   stolen_chunks=ld.stolen_chunks,
                   parity_rerouted_bytes=ld.parity_rerouted_bytes,
                   rerouted_members=list(ld.rerouted_members),
                   hedged_reads=ld.hedged_reads,
                   hedged_wins=ld.hedged_wins)
    return out


def run_cluster_trade() -> list:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="reft", ckpt_dir=d,
                              snapshot_every_steps=SNAP_EVERY,
                              bucket_bytes=1 << 20)
        c = LocalCluster(4, seed=3, nbytes=8 << 20, step_time=STEP_TIME,
                         spec=spec)
        try:
            c.run_rounds(CKPT_AT)
            c.checkpoint()
            c.run_rounds(KILL_AT - CKPT_AT)

            # node failure -> RAIM5 in-memory recovery
            c.kill_node(2)
            t0 = time.perf_counter()
            state, step, tier = c.recover()
            t_rec = time.perf_counter() - t0
            assert tier == "raim5"
            lost_steps_reft = KILL_AT - step
            rows.append(row("recover_raim5_load", t_rec,
                            f"steps_lost={lost_steps_reft}",
                            **_stats_extra(c.last_load_stats)))
            rows.append(row("recover_raim5_recompute",
                            lost_steps_reft * STEP_TIME, f"tier={tier}"))

            # counterfactual: checkpoint-only restart pays load + recompute
            from repro.core.loader import LoadStats
            from repro.core.recovery import restore_from_checkpoint
            ck_stats = LoadStats()
            t0 = time.perf_counter()
            _, ck_step, _ = restore_from_checkpoint(d, 4, c.template,
                                                    stats=ck_stats)
            t_load = time.perf_counter() - t0
            ck_stats.tier = "checkpoint"
            lost_steps_ck = KILL_AT - ck_step
            rows.append(row("recover_ckpt_load", t_load,
                            f"steps_lost={lost_steps_ck}",
                            **_stats_extra(ck_stats)))
            rows.append(row("recover_ckpt_recompute",
                            lost_steps_ck * STEP_TIME, "tier=checkpoint"))
            saved = (lost_steps_ck - lost_steps_reft) * STEP_TIME \
                - (t_rec - t_load)
            rows.append(row("recover_net_saving", max(saved, 0.0),
                            "reft_vs_ckpt"))
        finally:
            c.close()
    return rows


def run_backend_sweep(backends=SWEEP_BACKENDS, nbytes=SWEEP_BYTES) -> list:
    from benchmarks.common import make_param_state
    rows = []
    state = make_param_state(nbytes)
    for backend in backends:
        with tempfile.TemporaryDirectory() as d:
            spec = CheckpointSpec(backend=backend, ckpt_dir=d, sg_size=4,
                                  resume=False)
            with spec.build(state) as ck:
                ck.snapshot(state, 1, wait=True)
                ck.persist()
                t0 = time.perf_counter()
                res = ck.restore()
                t = time.perf_counter() - t0
                rows.append(row(f"recover_{backend}_restore", t,
                                f"tier={res.tier}",
                                **_stats_extra(res.load)))
    return rows


def run_loader_compare(nbytes=LOADER_BYTES) -> list:
    """Monolithic (pre-refactor whole-region) vs ranged LoadPlan restore,
    healthy and after a single-member loss, plus a partial (single-leaf)
    plan with range-limited decode."""
    from benchmarks.common import make_param_state
    from repro.core import raim5
    from repro.core.coordinator import ReftGroup
    from repro.core.loader import (
        LoadStats, ShmSource, build_plan, load_bytes, need_for_leaves,
    )
    from repro.core.recovery import attach_survivors
    from repro.core.snapshot import ReftConfig
    from repro.core.treebytes import make_flat_spec

    def monolithic(views, n, total, step, failed=None):
        def read_block(node, stripe, index):
            return views[node].read_block(step, stripe, index)
        recovered = None
        if failed is not None:
            recovered = raim5.decode_node(
                failed, n, total, read_block=read_block,
                read_parity=lambda s: views[s].read_parity(step))
        return raim5.reassemble(n, total, read_block, recovered)

    rows = []
    state = make_param_state(nbytes)
    spec = make_flat_spec(state)
    with tempfile.TemporaryDirectory() as d:
        g = ReftGroup(4, state, ReftConfig(ckpt_dir=d,
                                           checkpoint_every_snapshots=10**9))
        try:
            g.snapshot(state, 1)
            total = g.total_bytes

            def compare(failed, alive, tag):
                views = attach_survivors(g.run, alive, 4, total)
                try:
                    t0 = time.perf_counter()
                    monolithic(views, 4, total, 1, failed)
                    t_mono = time.perf_counter() - t0
                    rows.append(row(f"loader_monolithic_{tag}", t_mono,
                                    f"bytes={total}"))
                    st = LoadStats()
                    plan = build_plan(4, total, failed=failed)
                    t0 = time.perf_counter()
                    load_bytes(plan, ShmSource(views, 1), verify=False,
                               stats=st)
                    rows.append(row(f"loader_ranged_{tag}",
                                    time.perf_counter() - t0,
                                    f"readers={st.parallel_readers}",
                                    **_stats_extra(st)))
                    # partial: one leaf's ranges only (range-limited decode)
                    need = need_for_leaves(spec, ("mu",))
                    st2 = LoadStats()
                    plan2 = build_plan(4, total, need=need, failed=failed)
                    t0 = time.perf_counter()
                    load_bytes(plan2, ShmSource(views, 1), verify=False,
                               stats=st2)
                    rows.append(row(f"loader_ranged_partial_{tag}",
                                    time.perf_counter() - t0,
                                    f"needed={st2.bytes_needed}",
                                    **_stats_extra(st2)))
                finally:
                    for v in views.values():
                        v.close()

            compare(None, [0, 1, 2, 3], "full")
            g.inject_node_failure(2)
            compare(2, [0, 1, 3], "raim5")
        finally:
            g.close()
    return rows


def run_objstore(nbytes=LOADER_BYTES) -> list:
    """Tier-4 rows: ranged restore straight from a remote family (full /
    single-member decode / partial) vs the local tier-3 `FileSource`
    equivalent over the SAME persisted family."""
    from benchmarks.common import make_param_state
    from repro.core.coordinator import ReftGroup
    from repro.core.loader import (
        FileSource, LoadStats, ObjectSource, build_plan, load_bytes,
        need_for_leaves,
    )
    from repro.core.snapshot import ReftConfig
    from repro.core.treebytes import make_flat_spec
    from repro.store import (
        LocalObjectStore, build_manifest, load_manifest, put_manifest,
    )

    rows = []
    state = make_param_state(nbytes)
    spec = make_flat_spec(state)
    with tempfile.TemporaryDirectory() as d:
        g = ReftGroup(4, state, ReftConfig(ckpt_dir=d,
                                           checkpoint_every_snapshots=10**9))
        try:
            g.snapshot(state, 1)
            g.wait()
            total = g.total_bytes
            store = LocalObjectStore(os.path.join(d, "objstore"))
            step = g.checkpoint_async(remote={"store": store.config,
                                              "prefix": "families"})
            rounds = g.drain_persists()
            rnd = next(r for r in rounds if r["step"] == step)
            assert rnd["ok"], rnd["errors"]
            put_manifest(store, "families",
                         build_manifest(g.run, step, 4, total,
                                        rnd["uploads"]))
            man = load_manifest(store, "families", step)

            def src_obj():
                return ObjectSource(store, man)

            def src_file():
                return FileSource({nd: os.path.join(
                    d, f"step-{step}-node-{nd}.reft") for nd in range(4)})

            def timed(tag, mk_src, need=None, failed=None):
                st = LoadStats()
                plan = build_plan(4, total, need=need, failed=failed)
                src = mk_src()
                try:
                    t0 = time.perf_counter()
                    load_bytes(plan, src, verify=False, stats=st)
                    rows.append(row(tag, time.perf_counter() - t0,
                                    f"bytes={total}", **_stats_extra(st)))
                finally:
                    src.close()

            timed("objstore_remote_full", src_obj)
            timed("objstore_remote_decode", src_obj, failed=2)
            timed("objstore_remote_partial", src_obj,
                  need=need_for_leaves(spec, ("mu",)))
            timed("objstore_local_tier3_full", src_file)
        finally:
            g.close()
    return rows


def run_delta(nbytes=SWEEP_BYTES) -> list:
    """Delta-family rows: restoring the keyframe step (one `.reft` set)
    vs restoring the newest step of the same family through its
    keyframe + delta chain (`.reftd` links), with bytes_read per row —
    the read cost a delta chain adds to recovery."""
    from benchmarks.common import make_param_state
    from repro.core.coordinator import ReftGroup
    from repro.core.loader import LoadStats
    from repro.core.recovery import (
        latest_checkpoint_step, restore_from_checkpoint,
    )
    from repro.core.snapshot import ReftConfig

    rows = []
    chain = 3                     # delta links on top of the keyframe
    state = make_param_state(nbytes)
    with tempfile.TemporaryDirectory() as d:
        cfg = ReftConfig(ckpt_dir=d, bucket_bytes=256 << 10, delta=True,
                         delta_keyframe=100, delta_dirty_threshold=0.9,
                         checkpoint_every_snapshots=10 ** 9)
        g = ReftGroup(4, state, cfg)
        kinds = []
        st = state
        try:
            leaf = sorted(state)[0]
            for step in range(chain + 1):
                if step:                     # sparse mutation -> delta
                    st = dict(st)
                    st[leaf] = st[leaf].at[(0,) * st[leaf].ndim].add(1.0)
                assert g.snapshot(st, step, wait=True)
                assert g.checkpoint_async(
                    delta_base=latest_checkpoint_step(d, 4)) is not None
                rnd = g.drain_persists()[-1]
                assert rnd["ok"], rnd.get("errors")
                kinds.append(rnd["kind"])
        finally:
            g.close()
        assert kinds == ["full"] + ["delta"] * chain, kinds

        st_kf = LoadStats()
        t0 = time.perf_counter()
        _, at, _ = restore_from_checkpoint(d, 4, state, step=0,
                                           stats=st_kf)
        t_kf = time.perf_counter() - t0
        assert at == 0
        rows.append(row("delta_restore_keyframe", t_kf, "chain_depth=0",
                        **_stats_extra(st_kf)))

        st_ch = LoadStats()
        t0 = time.perf_counter()
        _, at, _ = restore_from_checkpoint(d, 4, state, step=chain,
                                           stats=st_ch)
        t_ch = time.perf_counter() - t0
        assert at == chain
        rows.append(row("delta_restore_chain", t_ch,
                        f"chain_depth={chain}", **_stats_extra(st_ch)))
        # `bytes_read` counts logical plan bytes, identical for both
        # restores (chain spans resolve from `.reftd` payloads instead of
        # the keyframe) — the chain's real surcharge is wall time plus
        # the on-disk delta footprint
        import glob
        kf_bytes = sum(os.path.getsize(p) for p in
                       glob.glob(os.path.join(d, "step-0-node-*.reft")))
        reftd_bytes = sum(os.path.getsize(p) for p in
                          glob.glob(os.path.join(d, "*.reftd")))
        rows.append(row("delta_restore_chain_overhead",
                        max(t_ch - t_kf, 0.0),
                        f"reftd_bytes={reftd_bytes}"
                        f";keyframe_bytes={kf_bytes}"))
    return rows


def run_laggard(nbytes=LOADER_BYTES) -> list:
    """Straggler rows: one survivor at 1/8 bandwidth, FCFS vs chunked
    work-stealing vs stealing + parity-alternative routing, over the SAME
    snapshot — every row's buffer is checked byte-identical, and the
    smoke gates assert (a) adaptive beats FCFS by >= 1.5x under the
    laggard and (b) adaptive costs nothing without one."""
    import numpy as np

    from benchmarks.common import make_param_state
    from repro.core.coordinator import ReftGroup
    from repro.core.loader import LoadStats, ShmSource, build_plan, \
        load_bytes
    from repro.core.readsched import SchedConfig, ThrottledSource
    from repro.core.recovery import attach_survivors
    from repro.core.snapshot import ReftConfig

    fast = float(LAGGARD_FAST_BW)
    slow = fast / LAGGARD_SLOW_FACTOR
    cfgs = {"fcfs": SchedConfig(mode="fcfs"),
            "steal": SchedConfig(mode="steal", chunk_bytes=1 << 20),
            "adaptive": SchedConfig(mode="adaptive", chunk_bytes=1 << 20)}

    rows = []
    state = make_param_state(nbytes)
    with tempfile.TemporaryDirectory() as d:
        g = ReftGroup(4, state, ReftConfig(ckpt_dir=d,
                                           checkpoint_every_snapshots=10**9))
        try:
            g.snapshot(state, 1)
            total = g.total_bytes
            views = attach_survivors(g.run, [0, 1, 2, 3], 4, total)
            try:
                def timed(tag, bws, cfg):
                    src = ThrottledSource(ShmSource(views, 1), bws)
                    st = LoadStats()
                    plan = build_plan(4, total)
                    t0 = time.perf_counter()
                    buf, _ = load_bytes(plan, src, verify=False,
                                        stats=st, sched=cfg)
                    dt = time.perf_counter() - t0
                    rows.append(row(tag, dt, f"sched={cfg.mode}",
                                    **_stats_extra(st)))
                    return dt, buf

                uniform = {i: fast for i in range(4)}
                lagged = dict(uniform)
                lagged[LAGGARD_NODE] = slow
                wall, oracle = {}, None
                for name, cfg in cfgs.items():
                    wall[name], buf = timed(f"laggard_restore_{name}",
                                            lagged, cfg)
                    if oracle is None:
                        oracle = buf
                    elif not np.array_equal(buf, oracle):
                        raise SystemExit(
                            f"laggard_restore_{name}: NOT byte-identical "
                            f"to the FCFS oracle")
                t_uf, buf_uf = timed("uniform_restore_fcfs", uniform,
                                     cfgs["fcfs"])
                t_ua, buf_ua = timed("uniform_restore_adaptive", uniform,
                                     cfgs["adaptive"])
                if not np.array_equal(buf_ua, buf_uf):
                    raise SystemExit(
                        "uniform_restore_adaptive: NOT byte-identical")
                speedup = wall["fcfs"] / max(wall["adaptive"], 1e-9)
                ratio = t_ua / max(t_uf, 1e-9)
                rows.append(row("laggard_adaptive_speedup", speedup,
                                f"gate>=1.5;slow_factor="
                                f"{LAGGARD_SLOW_FACTOR}"))
                rows.append(row("uniform_adaptive_ratio", ratio,
                                "gate<=1.15"))
                if wall["adaptive"] > 0.67 * wall["fcfs"]:
                    raise SystemExit(
                        f"laggard gate FAILED: adaptive "
                        f"{wall['adaptive']:.3f}s > 0.67 x fcfs "
                        f"{wall['fcfs']:.3f}s (speedup {speedup:.2f}x)")
                if t_ua > 1.15 * t_uf + 0.05:
                    raise SystemExit(
                        f"uniform gate FAILED: adaptive {t_ua:.3f}s vs "
                        f"fcfs {t_uf:.3f}s (ratio {ratio:.2f})")
            finally:
                for v in views.values():
                    v.close()
        finally:
            g.close()
    return rows


def run(backends=SWEEP_BACKENDS, objstore=False, delta=False,
        laggard=False) -> list:
    return (run_cluster_trade() + run_backend_sweep(backends)
            + run_loader_compare()
            + (run_objstore() if objstore else [])
            + (run_delta() if delta else [])
            + (run_laggard() if laggard else []))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", action="append", default=None,
                    help="restrict the facade sweep (repeatable)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write structured rows as JSON (CI uploads "
                         "this as a perf-trajectory artifact)")
    ap.add_argument("--objstore", action="store_true",
                    help="add tier-4 rows (remote ranged full / decode / "
                         "partial restore vs local tier-3)")
    ap.add_argument("--delta", action="store_true",
                    help="add delta-family rows (keyframe-only vs "
                         "keyframe+delta-chain restore)")
    ap.add_argument("--laggard", action="store_true",
                    help="add straggler rows (one survivor at 1/8 "
                         "bandwidth: fcfs vs steal vs adaptive) with "
                         "speedup smoke gates")
    args = ap.parse_args(argv)
    rows = run(tuple(args.backend) if args.backend else SWEEP_BACKENDS,
               objstore=args.objstore, delta=args.delta,
               laggard=args.laggard)
    print("bench,seconds,derived")
    for r in rows:
        extra = ""
        if "bytes_read" in r:
            extra = (f";read={r['bytes_read']}"
                     f";decoded={r['decoded_bytes']}")
        print(f"{r['name']},{r['seconds']:.4f},{r['detail']}{extra}")
    if args.json:
        payload = {"bench": "recovery", "rows": rows}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"[json] wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
