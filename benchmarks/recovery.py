"""§6.2 restarting & recomputation overhead.

Part 1 (the paper's trade): a 4-node lockstep cluster with a fixed
per-step compute time is killed mid-run; we measure (a) in-memory/RAIM5
recovery wall time, (b) checkpoint load wall time, and derive the
recomputation each would pay given the snapshot vs checkpoint intervals —
the '58 s load vs 10 min saved recompute' trade.

Part 2 (facade sweep): every registered backend saves the same state and
is timed through the SAME `Checkpointer.restore()` call, so restore-path
costs are directly comparable across REFT and the disk baselines.

    PYTHONPATH=src python benchmarks/recovery.py [--backend B ...]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

if __package__ in (None, ""):                    # `python benchmarks/x.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.api import CheckpointSpec
from repro.core.cluster import LocalCluster

STEP_TIME = 0.05
SNAP_EVERY = 1
CKPT_AT = 4          # checkpoint taken at this step
KILL_AT = 12

SWEEP_BYTES = 8 << 20
SWEEP_BACKENDS = ("reft", "sync_disk", "async_disk")


def run_cluster_trade() -> list:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        spec = CheckpointSpec(backend="reft", ckpt_dir=d,
                              snapshot_every_steps=SNAP_EVERY,
                              bucket_bytes=1 << 20)
        c = LocalCluster(4, seed=3, nbytes=8 << 20, step_time=STEP_TIME,
                         spec=spec)
        try:
            c.run_rounds(CKPT_AT)
            c.checkpoint()
            c.run_rounds(KILL_AT - CKPT_AT)

            # node failure -> RAIM5 in-memory recovery
            c.kill_node(2)
            t0 = time.perf_counter()
            state, step, tier = c.recover()
            t_rec = time.perf_counter() - t0
            assert tier == "raim5"
            lost_steps_reft = KILL_AT - step
            rows.append(("recover_raim5_load", t_rec,
                         f"steps_lost={lost_steps_reft}"))
            rows.append(("recover_raim5_recompute",
                         lost_steps_reft * STEP_TIME, f"tier={tier}"))

            # counterfactual: checkpoint-only restart pays load + recompute
            from repro.core.recovery import restore_from_checkpoint
            t0 = time.perf_counter()
            _, ck_step, _ = restore_from_checkpoint(d, 4, c.template)
            t_load = time.perf_counter() - t0
            lost_steps_ck = KILL_AT - ck_step
            rows.append(("recover_ckpt_load", t_load,
                         f"steps_lost={lost_steps_ck}"))
            rows.append(("recover_ckpt_recompute",
                         lost_steps_ck * STEP_TIME, "tier=checkpoint"))
            saved = (lost_steps_ck - lost_steps_reft) * STEP_TIME \
                - (t_rec - t_load)
            rows.append(("recover_net_saving", max(saved, 0.0),
                         "reft_vs_ckpt"))
        finally:
            c.close()
    return rows


def run_backend_sweep(backends=SWEEP_BACKENDS, nbytes=SWEEP_BYTES) -> list:
    from benchmarks.common import make_param_state
    rows = []
    state = make_param_state(nbytes)
    for backend in backends:
        with tempfile.TemporaryDirectory() as d:
            spec = CheckpointSpec(backend=backend, ckpt_dir=d, sg_size=4,
                                  resume=False)
            with spec.build(state) as ck:
                ck.snapshot(state, 1, wait=True)
                ck.persist()
                t0 = time.perf_counter()
                res = ck.restore()
                t = time.perf_counter() - t0
                rows.append((f"recover_{backend}_restore", t,
                             f"tier={res.tier}"))
    return rows


def run() -> list:
    return run_cluster_trade() + run_backend_sweep()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", action="append", default=None,
                    help="restrict the facade sweep (repeatable)")
    args = ap.parse_args(argv)
    rows = run_cluster_trade()
    rows += run_backend_sweep(tuple(args.backend) if args.backend
                              else SWEEP_BACKENDS)
    print("bench,seconds,derived")
    for name, s, d in rows:
        print(f"{name},{s:.4f},{d}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
