"""§6.2 restarting & recomputation overhead.

A 4-node lockstep cluster with a fixed per-step compute time is killed
mid-run; we measure (a) in-memory/RAIM5 recovery wall time, (b) checkpoint
load wall time, and derive the recomputation each would pay given the
snapshot vs checkpoint intervals — the paper's '58 s load vs 10 min saved
recompute' trade.
"""
from __future__ import annotations

import tempfile
import time

from repro.core.cluster import LocalCluster

STEP_TIME = 0.05
SNAP_EVERY = 1
CKPT_AT = 4          # checkpoint taken at this step
KILL_AT = 12


def run() -> list:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        c = LocalCluster(4, seed=3, nbytes=8 << 20, snapshot_every=SNAP_EVERY,
                         step_time=STEP_TIME, ckpt_dir=d)
        try:
            c.run_rounds(CKPT_AT)
            c.checkpoint()
            c.run_rounds(KILL_AT - CKPT_AT)

            # node failure -> RAIM5 in-memory recovery
            c.kill_node(2)
            t0 = time.perf_counter()
            state, step, tier = c.recover()
            t_rec = time.perf_counter() - t0
            assert tier == "raim5"
            lost_steps_reft = KILL_AT - step
            rows.append(("recover_raim5_load", t_rec,
                         f"steps_lost={lost_steps_reft}"))
            rows.append(("recover_raim5_recompute",
                         lost_steps_reft * STEP_TIME, f"tier={tier}"))

            # counterfactual: checkpoint-only restart pays load + recompute
            from repro.core.recovery import restore_from_checkpoint
            t0 = time.perf_counter()
            _, ck_step, _ = restore_from_checkpoint(d, 4, c.template)
            t_load = time.perf_counter() - t0
            lost_steps_ck = KILL_AT - ck_step
            rows.append(("recover_ckpt_load", t_load,
                         f"steps_lost={lost_steps_ck}"))
            rows.append(("recover_ckpt_recompute",
                         lost_steps_ck * STEP_TIME, "tier=checkpoint"))
            saved = (lost_steps_ck - lost_steps_reft) * STEP_TIME \
                - (t_rec - t_load)
            rows.append(("recover_net_saving", max(saved, 0.0),
                         "reft_vs_ckpt"))
        finally:
            c.close()
    return rows


def main():
    print("bench,seconds,derived")
    for name, s, d in run():
        print(f"{name},{s:.4f},{d}")


if __name__ == "__main__":
    main()
