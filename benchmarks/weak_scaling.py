"""§6.2a weak scaling: saving speed vs number of DP paths.

The state is replicated across m DP nodes; REFT shards it so each node
moves ~2W/m bytes (own shard + parity stripe), all nodes in parallel.
We run the m engines' snapshots concurrently (each a real SMP process) and
report the aggregate GB/s, against CheckFreq (every node writes the full
state) and TorchSnapshot (each node writes W/m to disk in parallel).
"""
from __future__ import annotations

import tempfile
import time

from benchmarks.common import make_param_state, tree_bytes
from repro.ckpt import CheckFreqCheckpointer, TorchSnapshotCheckpointer
from repro.core.coordinator import ReftGroup
from repro.core.snapshot import ReftConfig

SIZE = 96 << 20
PATHS = (1, 2, 4, 6, 8, 12)      # paper scales to DP-24 on 6 nodes; this
                                 # 24-core host sustains 12 parallel paths


def run(size: int = SIZE, paths=PATHS) -> list:
    rows = []
    state = make_param_state(size)
    gb = tree_bytes(state) / 2 ** 30
    for m in paths:
        g = ReftGroup(m, state, ReftConfig(
            bucket_bytes=16 << 20, ckpt_dir=tempfile.mkdtemp(),
            checkpoint_every_snapshots=10 ** 9))
        try:
            g.snapshot(state, 1)                        # warm
            t0 = time.perf_counter()
            g.snapshot(state, 2)
            t = time.perf_counter() - t0
            rows.append((f"weak_reft_sn_dp{m}", t, gb / t))
        finally:
            g.close()

        with tempfile.TemporaryDirectory() as d:
            ck = TorchSnapshotCheckpointer(d, state, n_ranks=m)
            ck.save_sync(state, 1)
            t = ck.save_sync(state, 2).total
            rows.append((f"weak_torchsnapshot_dp{m}", t, gb / t))
        with tempfile.TemporaryDirectory() as d:
            ck = CheckFreqCheckpointer(d, state)
            ck.save_sync(state, 1)
            t = ck.save_sync(state, 2).total
            rows.append((f"weak_checkfreq_dp{m}", t, gb / t))
    return rows


def main():
    print("bench,seconds,GB_per_s")
    for name, s, gbps in run():
        print(f"{name},{s:.4f},{gbps:.2f}")


if __name__ == "__main__":
    main()
