"""Kernel micro-benches: interpret-mode Pallas vs oracle wall time (CPU
sanity only — TPU perf comes from the roofline analysis) plus the host
numpy XOR path used by the SMP (the production encode on this box).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core.raim5 import xor_blocks
from repro.kernels.ops import ssd_scan, swa_attention, xor_parity_encode
from repro.kernels.ref import ssd_scan_ref, swa_attention_ref, xor_reduce_ref


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    # xor parity: host numpy (production path) vs kernel oracle
    blocks = rng.integers(0, 256, size=(3, 64 << 20), dtype=np.uint8)
    t_np = timeit(lambda: xor_blocks(list(blocks)), repeat=3)
    gb = blocks.nbytes / 2 ** 30
    rows.append(("xor_host_numpy_64MBx3", t_np, f"{gb/t_np:.1f}GB/s"))
    blk_small = jnp.asarray(blocks[:, :1 << 20])
    t_k = timeit(lambda: jax.block_until_ready(
        xor_parity_encode(blk_small)), repeat=3)
    rows.append(("xor_pallas_interp_1MBx3", t_k, "interpret-mode"))

    # ssd: chunked kernel vs naive recurrence (both jitted, CPU)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    B, S, H, P, N = 2, 1024, 4, 64, 128
    u = jax.random.normal(ks[0], (B, S, H, P))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    ref = jax.jit(ssd_scan_ref)
    t_r = timeit(lambda: jax.block_until_ready(ref(u, a, Bm, Cm)))
    rows.append(("ssd_naive_scan_1k", t_r, "jit"))
    t_c = timeit(lambda: jax.block_until_ready(
        ssd_scan(u, a, Bm, Cm, chunk=128)))
    rows.append(("ssd_pallas_interp_1k", t_c, f"vs_naive={t_r/t_c:.2f}x"))

    # swa flash kernel vs dense reference
    q = jax.random.normal(ks[0], (1, 1024, 2, 4, 64))
    k = jax.random.normal(ks[1], (1, 1024, 2, 64))
    v = jax.random.normal(ks[2], (1, 1024, 2, 64))
    refa = jax.jit(lambda q, k, v: swa_attention_ref(q, k, v, window=128))
    t_d = timeit(lambda: jax.block_until_ready(refa(q, k, v)))
    rows.append(("swa_dense_ref_1k_w128", t_d, "jit"))
    t_f = timeit(lambda: jax.block_until_ready(
        swa_attention(q, k, v, window=128)))
    rows.append(("swa_pallas_interp_1k_w128", t_f, f"vs_dense={t_d/t_f:.2f}x"))
    return rows


def main():
    print("bench,seconds,derived")
    for name, s, d in run():
        print(f"{name},{s:.4f},{d}")


if __name__ == "__main__":
    main()
