"""Roofline table: formats results/*.jsonl from the dry-run campaigns.

Reads (in order of preference) the roofline (extrapolated-unrolled) records
and merges per-pair memory stats from the scanned proof records.
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return {}
    out = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            out[(r["arch"], r["shape"])] = r
    return out


def run() -> list:
    roof = load("roofline_baseline.jsonl")
    proof = load("dryrun_single_pod.jsonl")
    rows = []
    for key in sorted(set(roof) | set(proof)):
        r = roof.get(key, proof.get(key))
        if "skipped" in r:
            rows.append((*key, "skip", r["skipped"], "", "", "", "", ""))
            continue
        if "error" in r:
            rows.append((*key, "ERROR", r["error"], "", "", "", "", ""))
            continue
        mem = (proof.get(key) or {}).get("memory", {})
        args_gib = mem.get("argument_bytes", 0) / 2 ** 30
        rows.append((*key, r["dominant"],
                     f"{r['t_compute_s']:.3e}",
                     f"{r['t_memory_s']:.3e}",
                     f"{r['t_collective_s']:.3e}",
                     f"{(r.get('useful_compute_ratio') or 0):.3f}",
                     f"{args_gib:.2f}"))
    return rows


def main():
    print("arch,shape,dominant,t_compute_s,t_memory_s,t_collective_s,"
          "useful_ratio,args_GiB_per_chip")
    for r in run():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
