"""Appendix A: optimal snapshot/checkpoint intervals and total overhead.

Feeds *measured* saving overheads (from the micro benchmark sizes) into
Eqs. 4-11 for a hypothetical week-long pretraining at several failure
rates, and reports REFT's total fault-tolerance overhead vs
checkpoint-only.
"""
from __future__ import annotations

from repro.core import policy


def run(t_snapshot: float = 0.4, t_checkpoint: float = 4.0,
        t_comp: float = 1.0, n: int = 6) -> list:
    rows = []
    t_total = 7 * 24 * 3600.0
    for mttf_h in (2.0, 8.0, 24.0):
        lam = 1.0 / (mttf_h * 3600.0)
        plan = policy.plan_frequencies(
            t_snapshot=t_snapshot, t_checkpoint=t_checkpoint,
            t_comp=t_comp, lam_node=lam, n=n)
        # REFT: snapshots hide behind compute (Eq. 8), restart pays the
        # snapshot interval; checkpoints only for the rare Eq. 7 event.
        snap_int = max(plan.snapshot_interval, t_comp)
        o_reft = policy.total_overhead(
            t_total, snap_int, plan.o_snapshot, lam, t_sch=30, t_load=5) + \
            policy.total_overhead(
                t_total, max(plan.checkpoint_interval, 60.0), 0.0,
                plan.lam_unrecoverable, t_sch=30, t_load=30)
        # checkpoint-only baseline
        o_ck_save = policy.effective_save_overhead(t_checkpoint, t_comp)
        t_ck = policy.optimal_interval(o_ck_save, lam)
        o_ckpt = policy.total_overhead(t_total, max(t_ck, t_comp),
                                       o_ck_save, lam, t_sch=30, t_load=30)
        rows.append((f"intervals_mttf{mttf_h}h", snap_int,
                     plan.checkpoint_interval, t_ck, o_reft, o_ckpt,
                     o_ckpt / max(o_reft, 1e-9)))
    return rows


def main():
    print("bench,snap_interval_s,reft_ckpt_interval_s,baseline_ckpt_interval_s,"
          "reft_total_overhead_s,ckpt_total_overhead_s,reduction")
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]:.1f},{r[3]:.1f},{r[4]:.0f},"
              f"{r[5]:.0f},{r[6]:.1f}x")


if __name__ == "__main__":
    main()
