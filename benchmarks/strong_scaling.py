"""Figures 10-11 strong scaling: saving speed/overhead vs PP stages.

Paper setting: DP=1, TP=4, PP in {1,2,4,6}; each PP stage is one SG of one
node, so REFT's parallelism comes from per-stage engines saving their stage
slice concurrently.  CheckFreq writes the whole model from one node.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

import jax

from benchmarks.common import make_param_state, tree_bytes
from repro.ckpt import CheckFreqCheckpointer
from repro.core.snapshot import ReftConfig, SnapshotEngine

SIZE = 96 << 20
PP = (1, 2, 4, 6)


def _stage_slice(state, i, n):
    def cut(x):
        if x.ndim == 0:
            return x
        per = -(-x.shape[0] // n)
        return x[i * per:(i + 1) * per]
    return jax.tree.map(cut, state)


def run(size: int = SIZE, pps=PP) -> list:
    rows = []
    state = make_param_state(size)
    gb = tree_bytes(state) / 2 ** 30
    for pp in pps:
        stages = [_stage_slice(state, i, pp) for i in range(pp)]
        engines = [SnapshotEngine(0, 1, st, ReftConfig(
            bucket_bytes=16 << 20, run_id=f"ss{pp}-{i}"))
            for i, st in enumerate(stages)]
        try:
            for e, st in zip(engines, stages):
                e.snapshot_sync(st, 1)                  # warm
            t0 = time.perf_counter()
            for e, st in zip(engines, stages):          # async, parallel
                assert e.snapshot_async(st, 2)
            for e in engines:
                e.wait()
            t = time.perf_counter() - t0
            rows.append((f"strong_reft_sn_pp{pp}", t, gb / t))
        finally:
            for e in engines:
                e.close()

        with tempfile.TemporaryDirectory() as d:
            ck = CheckFreqCheckpointer(d, state)
            ck.save_sync(state, 1)
            t = ck.save_sync(state, 2).total
            rows.append((f"strong_checkfreq_pp{pp}", t, gb / t))
    return rows


def main():
    print("bench,seconds,GB_per_s")
    for name, s, gbps in run():
        print(f"{name},{s:.4f},{gbps:.2f}")


if __name__ == "__main__":
    main()
