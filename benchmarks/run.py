"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` style CSV per section.

  PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


SECTIONS = [
    ("micro snapshot (Fig. 9)", "benchmarks.micro_snapshot"),
    ("weak scaling (§6.2a)", "benchmarks.weak_scaling"),
    ("strong scaling (Figs. 10-11)", "benchmarks.strong_scaling"),
    ("restart/recompute (§6.2)", "benchmarks.recovery"),
    ("optimal intervals (Appx. A)", "benchmarks.intervals"),
    ("failure-scenario sweep + survival (Fig. 8)",
     "benchmarks.failure_sweep"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline (dry-run)", "benchmarks.roofline"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    failures = 0
    for title, mod_name in SECTIONS:
        if args.only and args.only not in mod_name:
            continue
        print(f"\n=== {title} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            import inspect
            if inspect.signature(mod.main).parameters:
                mod.main([])          # don't leak our argv into theirs
            else:
                mod.main()
            print(f"--- ok ({time.time()-t0:.1f}s)", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"--- FAILED ({time.time()-t0:.1f}s)", flush=True)
    print(f"\nbenchmarks done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
