"""GQA attention with RoPE, optional qk-norm and sliding windows.

The window width is a *traced per-layer value* (scanned array), so local and
global layers share one scan body: global layers carry the FULL_WINDOW
sentinel.  Decode attends one query against a pre-allocated KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.flash import flash_attention
from repro.models.layers import (
    FULL_WINDOW, apply_rope, dense_init, init_rms, pdtype_of, rms_norm,
    rope_angles,
)

NEG_INF = -1e30
# Above this sequence length the online-softmax path is used so the
# (S, S) score matrix is never materialized.
FLASH_THRESHOLD = 2048


def init_attn(key, cfg):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), pd),
        "wk": dense_init(ks[1], (D, KV * hd), pd),
        "wv": dense_init(ks[2], (D, KV * hd), pd),
        "wo": dense_init(ks[3], (H * hd, D), pd),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(hd, pd)
        p["k_norm"] = init_rms(hd, pd)
    return p


def _project_qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _gqa_scores(q, k, cfg):
    """q: (B,Sq,H,hd), k: (B,Sk,KV,hd) -> (B,KV,G,Sq,Sk) fp32."""
    B, Sq, H, hd = q.shape
    KV = cfg.num_kv_heads
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    return s * (hd ** -0.5)


def _mix(scores, v, cfg):
    """scores: (B,KV,G,Sq,Sk) fp32, v: (B,Sk,KV,hd) -> (B,Sq,H*hd)."""
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    B, Sq = o.shape[0], o.shape[1]
    return o.reshape(B, Sq, cfg.num_heads * cfg.head_dim)


def attention(p, cfg, x, *, window, positions, band=None, unroll=False):
    """Full-sequence attention (training / prefill).

    window: traced int32 scalar (FULL_WINDOW for global layers).
    positions: (S,) int32 (assumed contiguous from 0 for the flash path).
    band: static int window for exact banded attention (§Perf hillclimb).
    Returns (out, (k, v)) so prefill can populate the cache.
    """
    q, k, v = _project_qkv(p, cfg, x, positions)
    B, S = x.shape[0], x.shape[1]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if S >= FLASH_THRESHOLD or band is not None:
        qg = q.reshape(B, S, KV, H // KV, hd)
        # Tiles grow with S so the block grid stays <=16x16 — keeps the
        # unrolled dry-run compile tractable without changing totals.
        bq = max(512, S // 16)
        bk = max(1024, S // 16)
        o = flash_attention(qg, k, v, window=window, causal=cfg.causal,
                            band=band, unroll=unroll, block_q=bq,
                            block_k=bk)
        out = o.reshape(B, S, H * hd) @ p["wo"]
        return out, (k, v)
    qpos = positions[:, None]
    kpos = positions[None, :]
    ok = kpos - qpos < 1 if cfg.causal else jnp.ones((S, S), bool)
    ok = ok & (qpos - kpos < window) & (kpos - qpos < window)
    scores = _gqa_scores(q, k, cfg)
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    out = _mix(scores, v, cfg) @ p["wo"]
    return out, (k, v)


def attention_decode(p, cfg, x, cache_k, cache_v, *, window, index):
    """One-token decode. x: (B,1,D); cache_k/v: (B,Smax,KV,hd); index: scalar.

    Writes the new k/v at `index` and attends over positions <= index within
    the sliding window. Returns (out, new_k, new_v).
    """
    pos = jnp.full((1,), index, jnp.int32)
    q, k1, v1 = _project_qkv(p, cfg, x, pos)
    Smax = cache_k.shape[1]
    # Ring-buffer write: slot = index % Smax. When Smax covers the full
    # sequence this is a plain positional write; when the cache is
    # window-sized (window_kv_cache) old entries are overwritten.
    slot = jax.lax.rem(index, Smax)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k1.astype(cache_k.dtype),
                                             slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v1.astype(cache_v.dtype),
                                             slot, axis=1)
    j = jnp.arange(Smax, dtype=jnp.int32)
    kpos = index - jax.lax.rem(index - j, Smax)           # true position of slot j
    ok = (kpos >= 0) & (kpos <= index) & (index - kpos < window)
    scores = _gqa_scores(q, ck, cfg)                   # (B,KV,G,1,Smax)
    scores = jnp.where(ok[None, None, None, None], scores, NEG_INF)
    out = _mix(scores, cv, cfg) @ p["wo"]
    return out, ck, cv
