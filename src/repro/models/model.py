"""Model assembly: init / forward / prefill / decode for every family.

Layer stacks are *scanned* (stacked params, `lax.scan`) so the HLO stays
compact for 95-layer / trillion-parameter configs.  Heterogeneous hybrids
(Jamba) scan over *periods* whose body unrolls the static per-position layer
kinds.  Local-vs-global attention is data, not structure: the per-layer
window width is a scanned int32 (FULL_WINDOW sentinel for global layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN, ModelConfig
from repro.dist.api import shard
from repro.models.attention import attention, attention_decode, init_attn
from repro.models.layers import (
    FULL_WINDOW, chunked_cross_entropy, cross_entropy, dense_init, dtype_of,
    init_mlp, init_rms, mlp, pdtype_of, rms_norm,
)
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import init_ssm, ssm_block, ssm_decode


# ===================================================================== init
def window_array(cfg: ModelConfig, count=None, offset=0):
    vals = [cfg.layer_window(offset + i) or FULL_WINDOW
            for i in range(count or cfg.num_layers)]
    return jnp.asarray(vals, jnp.int32)


def _init_layer(cfg: ModelConfig, key, idx: int):
    """One layer's params; `idx` decides kind/moe via the static pattern."""
    pd = pdtype_of(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 2)
    p = {"ln1": init_rms(D, pd)}
    if cfg.layer_kind(idx) == ATTN:
        p["mix"] = init_attn(ks[0], cfg)
    else:
        p["mix"] = init_ssm(ks[0], cfg)
    if cfg.d_ff:
        p["ln2"] = init_rms(D, pd)
        p["ffn"] = (init_moe(ks[1], cfg) if cfg.layer_is_moe(idx)
                    else init_mlp(ks[1], cfg))
    return p


def _stack_period(cfg: ModelConfig):
    """(period, n_periods) for the scan structure."""
    if cfg.family == "hybrid" and cfg.attn_period:
        period = cfg.attn_period
        if cfg.num_experts:
            # the scan body must see a pattern that repeats exactly
            import math
            period = math.lcm(period, cfg.moe_every)
        assert cfg.num_layers % period == 0, (cfg.name, period)
        return period, cfg.num_layers // period
    return 1, cfg.num_layers


def init_params(cfg: ModelConfig, key):
    pd = pdtype_of(cfg)
    D, V = cfg.d_model, cfg.vocab_size
    k_embed, k_blocks, k_head, k_proj = jax.random.split(key, 4)
    params = {}
    if cfg.embed_inputs:
        params["embed"] = dense_init(k_embed, (V, D), pd, scale=0.02)
    if not cfg.embed_inputs or cfg.num_patches:
        params["proj_in"] = dense_init(k_proj, (D, D), pd)
    period, n_periods = _stack_period(cfg)
    keys = jax.random.split(k_blocks, n_periods)

    def init_period(k):
        pks = jax.random.split(k, period)
        return {f"pos{i}": _init_layer(cfg, pks[i], i) for i in range(period)}

    params["blocks"] = jax.vmap(init_period)(keys)
    params["final_norm"] = init_rms(D, pd)
    if cfg.is_encoder or not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (D, V), pd)
    return params


# ===================================================================== fwd
def _ffn_apply(cfg, p, idx, h):
    """Returns (out, aux)."""
    if not cfg.d_ff:
        return jnp.zeros_like(h), jnp.zeros((), jnp.float32)
    h_in = rms_norm(h, p["ln2"])
    if cfg.layer_is_moe(idx):
        out, aux = moe_ffn(p["ffn"], cfg, h_in)
        return out, aux
    return mlp(p["ffn"], h_in), jnp.zeros((), jnp.float32)


def _layer_full(cfg, p, idx, h, w, positions, collect_cache,
                static_idx=None, unroll=False):
    """One layer on the full sequence. Returns (h, aux, cache_entry).

    static_idx: the *global* layer index when it is statically known
    (unrolled dry-run) — enables exact banded attention per layer.
    """
    h = shard(h, P(("pod", "data"), None, None))
    if cfg.layer_kind(idx) == ATTN:
        band = None
        if cfg.banded_attention and cfg.sliding_window is not None:
            if static_idx is not None:
                band = cfg.layer_window(static_idx)   # None on global layers
            elif not cfg.global_every:
                band = cfg.sliding_window             # homogeneous SWA
        a, (k, v) = attention(p["mix"], cfg, rms_norm(h, p["ln1"]),
                              window=w, positions=positions, band=band,
                              unroll=unroll)
        entry = ({"k": k, "v": v} if collect_cache else
                 {})
    else:
        a, (conv_state, h_final) = ssm_block(p["mix"], cfg,
                                             rms_norm(h, p["ln1"]),
                                             chunk=cfg.ssd_chunk)
        entry = ({"conv": conv_state, "h": h_final} if collect_cache else {})
    h = h + a
    f, aux = _ffn_apply(cfg, p, idx, h)
    h = h + f
    return h, aux, entry


def _layer_decode(cfg, p, idx, h, w, index, entry):
    """One-token step against this layer's cache slice."""
    if cfg.layer_kind(idx) == ATTN:
        a, ck, cv = attention_decode(p["mix"], cfg, rms_norm(h, p["ln1"]),
                                     entry["k"], entry["v"],
                                     window=w, index=index)
        new_entry = {"k": ck, "v": cv}
    else:
        a, conv_state, hs = ssm_decode(p["mix"], cfg, rms_norm(h, p["ln1"]),
                                       entry["conv"], entry["h"])
        new_entry = {"conv": conv_state, "h": hs}
    h = h + a
    f, _ = _ffn_apply(cfg, p, idx, h)
    return h + f, new_entry


def _scan_blocks(cfg, params, h, positions, *, collect_cache=False,
                 remat=False, unroll=False):
    period, n_periods = _stack_period(cfg)
    win = window_array(cfg).reshape(n_periods, period)

    def make_body(period_idx=None):
        def body(carry, xs):
            h, aux = carry
            p_period, w_period = xs
            entries = {}
            for i in range(period):
                sidx = (None if period_idx is None
                        else period_idx * period + i)
                h, a, e = _layer_full(cfg, p_period[f"pos{i}"], i, h,
                                      w_period[i], positions, collect_cache,
                                      static_idx=sidx, unroll=unroll)
                aux = aux + a
                if collect_cache:
                    entries[f"pos{i}"] = e
            return (h, aux), entries
        if remat:
            policy = (jax.checkpoint_policies.dots_saveable
                      if cfg.remat_policy == "dots" else None)
            return jax.checkpoint(body, policy=policy)
        return body

    carry0 = (h, jnp.zeros((), jnp.float32))
    if unroll:
        # Dry-run mode: XLA's cost analysis counts a while-loop body once,
        # so roofline FLOPs are extracted from the unrolled program.  The
        # static layer index also enables exact per-layer banded attention.
        carry = carry0
        entries_list = []
        for i in range(n_periods):
            xs_i = (jax.tree.map(lambda a: a[i], params["blocks"]), win[i])
            carry, entries = make_body(i)(carry, xs_i)
            entries_list.append(entries)
        h, aux = carry
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *entries_list)
                  if collect_cache else {})
        return h, aux, caches
    (h, aux), caches = jax.lax.scan(make_body(), carry0,
                                    (params["blocks"], win))
    return h, aux, caches


def embed_batch(cfg: ModelConfig, params, batch):
    """-> (x (B,S,D), labels, loss_mask)."""
    dt = dtype_of(cfg)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(dt) @ params["proj_in"]
        tok = params["embed"][batch["tokens"]].astype(dt)
        x = jnp.concatenate([patches, tok], axis=1)
        labels = batch["labels"]
        Bp = patches.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((x.shape[0], Bp), bool),
             jnp.ones((x.shape[0], x.shape[1] - Bp), bool)], axis=1)
        return x, labels, mask
    if not cfg.embed_inputs:                    # audio frames
        x = batch["frames"].astype(dt) @ params["proj_in"]
        return x, batch["labels"], batch.get("mask")
    x = params["embed"][batch["tokens"]].astype(dt)
    return x, batch["labels"], None


def _lm_head_w(cfg, params):
    if "lm_head" in params:
        return params["lm_head"]
    return params["embed"].T


def forward(cfg: ModelConfig, params, batch, *, collect_cache=False,
            remat=None, unroll=False):
    """Full-sequence forward. Returns (loss, aux_dict)."""
    x, labels, mask = embed_batch(cfg, params, batch)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    remat = cfg.remat if remat is None else remat
    h, aux, caches = _scan_blocks(cfg, params, x, positions,
                                  collect_cache=collect_cache, remat=remat,
                                  unroll=unroll)
    h = rms_norm(h, params["final_norm"])
    w_out = _lm_head_w(cfg, params)
    if cfg.chunked_ce:
        loss = chunked_cross_entropy(h, w_out, labels, cfg.chunked_ce, mask,
                                     unroll=unroll)
    else:
        logits = h @ w_out
        logits = shard(logits, P(("pod", "data"), None, "model"))
        loss = cross_entropy(logits, labels, mask)
    loss = loss + 0.01 * aux
    out = {"loss": loss, "aux": aux}
    if collect_cache:
        out["cache"] = caches
    return loss, out


def logits_fn(cfg: ModelConfig, params, batch, *, unroll=False):
    """Last-position logits (used by prefill and tests)."""
    x, _, _ = embed_batch(cfg, params, batch)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    h, _, caches = _scan_blocks(cfg, params, x, positions, collect_cache=True,
                                remat=False, unroll=unroll)
    h = rms_norm(h, params["final_norm"])
    logits = h[:, -1:, :] @ _lm_head_w(cfg, params)
    return logits, caches


# ===================================================================== cache
def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int):
    """Zeroed decode cache pytree, stacked over scan periods."""
    period, n_periods = _stack_period(cfg)
    dt = dtype_of(cfg)
    entries = {}
    for i in range(period):
        if cfg.layer_kind(i) == ATTN:
            S = max_seq
            if cfg.window_kv_cache and cfg.layer_window(i) is not None:
                S = min(max_seq, cfg.layer_window(i))
            shape = (n_periods, batch_size, S, cfg.num_kv_heads, cfg.head_dim)
            entries[f"pos{i}"] = {"k": jnp.zeros(shape, dt),
                                  "v": jnp.zeros(shape, dt)}
        else:
            ch = cfg.d_inner + 2 * cfg.ssm_state
            entries[f"pos{i}"] = {
                "conv": jnp.zeros((n_periods, batch_size,
                                   cfg.ssm_conv_width - 1, ch), dt),
                "h": jnp.zeros((n_periods, batch_size, cfg.ssm_heads,
                                cfg.ssm_head_dim, cfg.ssm_state),
                               jnp.float32),
            }
    return {"entries": entries, "index": jnp.zeros((), jnp.int32)}


def decode_step(cfg: ModelConfig, params, cache, tokens, *, unroll=False):
    """One decode step. tokens: (B, 1) int32 -> (logits (B,1,V), new cache)."""
    period, n_periods = _stack_period(cfg)
    index = cache["index"]
    x = params["embed"][tokens].astype(dtype_of(cfg))
    win = window_array(cfg).reshape(n_periods, period)

    def body(h, xs):
        p_period, w_period, entries = xs
        new_entries = {}
        for i in range(period):
            h, ne = _layer_decode(cfg, p_period[f"pos{i}"], i, h,
                                  w_period[i], index, entries[f"pos{i}"])
            new_entries[f"pos{i}"] = ne
        return h, new_entries

    if unroll:
        h = x
        ne_list = []
        for i in range(n_periods):
            xs_i = (jax.tree.map(lambda a: a[i], params["blocks"]), win[i],
                    jax.tree.map(lambda a: a[i], cache["entries"]))
            h, ne = body(h, xs_i)
            ne_list.append(ne)
        new_entries = jax.tree.map(lambda *xs: jnp.stack(xs), *ne_list)
    else:
        h, new_entries = jax.lax.scan(
            body, x, (params["blocks"], win, cache["entries"]))
    h = rms_norm(h, params["final_norm"])
    logits = h @ _lm_head_w(cfg, params)
    return logits, {"entries": new_entries, "index": index + 1}
