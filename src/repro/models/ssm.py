"""Mamba2 (SSD — state-space duality) block, pure JAX.

Training/prefill uses the *chunked* SSD algorithm (matmul-dominated, TPU
MXU-friendly — this is also the oracle for the Pallas `ssd_scan` kernel);
decode uses the O(1)-state recurrent step.  All decays are exp of
non-positive cumulative sums, so no rescaling tricks are needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, pdtype_of, rms_norm, init_rms

DEFAULT_CHUNK = 256


def init_ssm(key, cfg):
    D, di, N, H, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv_width)
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 6)
    ch = di + 2 * N
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * N + H), pd),
        "conv_w": dense_init(ks[1], (W, ch), pd, scale=W ** -0.5),
        "conv_b": jnp.zeros((ch,), pd),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "dt_bias": jnp.full((H,), 0.5, jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "gate_norm": init_rms(di, pd),
        "out_proj": dense_init(ks[5], (di, D), pd),
    }


def _split_proj(p, cfg, x):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xbc_dt = jnp.split(x @ p["in_proj"], [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * N], axis=-1)
    return z, xbc, dt                                    # dt: (..., H)


def _conv_full(p, xbc):
    """Causal depthwise conv over the sequence. xbc: (B, S, ch)."""
    W = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * p["conv_w"][i] for i in range(W))
    return jax.nn.silu(out + p["conv_b"])


def _conv_step(p, xbc1, conv_state):
    """xbc1: (B, ch) current input; conv_state: (B, W-1, ch)."""
    W = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_state, xbc1[:, None, :]], axis=1)  # (B,W,ch)
    out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    new_state = window[:, 1:, :]
    return jax.nn.silu(out), new_state


def _gates(p, cfg, dt, xs):
    """dt (B,S,H) raw -> (a, u): log-decay and scaled input."""
    A = -jnp.exp(p["A_log"])                             # (H,) negative
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = dtp * A                                          # (B,S,H) <= 0
    u = xs * dtp[..., None].astype(xs.dtype)             # (B,S,H,P)
    return a, u


# ------------------------------------------------------------- SSD cores
def ssd_chunked(u, a, Bm, Cm, h0=None, chunk=DEFAULT_CHUNK):
    """Chunked SSD. u: (B,S,H,P) fp32; a: (B,S,H) log-decay (<=0);
    Bm/Cm: (B,S,N). Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    B, S, H, Pd = u.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    uc = u.reshape(B, nc, Q, H, Pd)
    ac = a.reshape(B, nc, Q, H)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)

    cum = jnp.cumsum(ac, axis=2)                          # (B,nc,Q,H)
    # intra-chunk: L[t,s] = exp(cum[t]-cum[s]) for s<=t
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bntm,bnsm->bnts", Cc, Bc,
                        preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("bnts,bntsh,bnshp->bnthp", scores, L, uc)

    # chunk states: S_n = sum_s exp(cum[-1]-cum[s]) B[s] (x) u[s]
    dec = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,nc,Q,H)
    states = jnp.einsum("bnsh,bnsm,bnshp->bnhpm", dec, Bc, uc)

    # inter-chunk recurrence over nc
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), u.dtype)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H)

    def body(h, xs):
        s_n, d_n = xs                                     # (B,H,P,N), (B,H)
        h_out = h                                         # state BEFORE chunk
        h_new = h * d_n[:, :, None, None] + s_n
        return h_new, h_out

    hs = jnp.moveaxis(states, 1, 0)                       # (nc,B,H,P,N)
    ds = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,B,H)
    h_final, h_prevs = jax.lax.scan(body, h0, (hs, ds))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,nc,H,P,N)

    y_inter = jnp.einsum("bntm,bnhpm->bnthp", Cc, h_prevs) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y, h_final


def ssd_scan_ref(u, a, Bm, Cm, h0=None):
    """Naive per-step recurrence (oracle for ssd_chunked and the kernel)."""
    B, S, H, Pd = u.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), u.dtype)

    def body(h, xs):
        u_t, a_t, b_t, c_t = xs
        h = h * jnp.exp(a_t)[:, :, None, None] \
            + jnp.einsum("bhp,bm->bhpm", u_t, b_t)
        y_t = jnp.einsum("bhpm,bm->bhp", h, c_t)
        return h, y_t

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h_final, ys = jax.lax.scan(body, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


# ------------------------------------------------------------- block api
def ssm_block(p, cfg, x, h0=None, chunk=DEFAULT_CHUNK, use_kernel=False):
    """Full-sequence mamba2 block. x: (B,S,D) -> (y, (conv_state, h_final))."""
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B, S, D = x.shape
    z, xbc, dt = _split_proj(p, cfg, x)
    conv_state = xbc[:, -(cfg.ssm_conv_width - 1):, :]    # for decode handoff
    xbc = _conv_full(p, xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xs = xs.reshape(B, S, H, Pd)
    a, u = _gates(p, cfg, dt, xs)
    if use_kernel:
        from repro.kernels.ops import ssd_scan as _k
        y, h_final = _k(u.astype(jnp.float32), a,
                        Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                        h0=h0, chunk=chunk)
    else:
        y, h_final = ssd_chunked(u.astype(jnp.float32), a,
                                 Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), h0=h0, chunk=chunk)
    y = y + p["D_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["out_proj"]
    return out, (conv_state.astype(x.dtype), h_final)


def ssm_decode(p, cfg, x, conv_state, h):
    """One-token step. x: (B,1,D); conv_state: (B,W-1,ch); h: (B,H,P,N)."""
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B = x.shape[0]
    z, xbc, dt = _split_proj(p, cfg, x[:, 0, :])
    xbc, conv_state = _conv_step(p, xbc, conv_state)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xs = xs.reshape(B, H, Pd)
    A = -jnp.exp(p["A_log"])
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    decay = jnp.exp(dtp * A)                                       # (B,H)
    u = xs.astype(jnp.float32) * dtp[..., None]
    h = h * decay[:, :, None, None] + jnp.einsum(
        "bhp,bm->bhpm", u, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpm,bm->bhp", h, Cm.astype(jnp.float32))
    y = y + p["D_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, conv_state, h
