"""Shared neural-net primitives (pure JAX, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Sentinel window width meaning "full attention" (fits int32, > any seq len).
FULL_WINDOW = 1 << 30


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg):
    return jnp.dtype(cfg.param_dtype)


def rms_norm(x, gain, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gain.astype(jnp.float32))).astype(dt)


def init_rms(d, dtype):
    return jnp.zeros((d,), dtype)          # gain stored as (1 + g)


def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- RoPE
def rope_angles(positions, head_dim, theta):
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (S, hd//2) or (B, S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:                       # (S, half) -> broadcast over B, H
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                                   # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP
def init_mlp(key, cfg):
    D, F = cfg.d_model, cfg.d_ff
    pd = pdtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (D, F), pd),
        "wi_up": dense_init(k2, (D, F), pd),
        "wo": dense_init(k3, (F, D), pd),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    return h @ p["wo"]


def cross_entropy(logits, labels, mask=None):
    """Mean CE in fp32. logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(h, w_out, labels, chunk, mask=None, unroll=False):
    """CE over sequence chunks without materializing (B, S, V).

    h: (B, S, D) final hidden states; w_out: (D, V); labels: (B, S).
    """
    B, S, D = h.shape
    n = max(1, S // chunk)
    while S % n:
        n -= 1
    hc = h.reshape(B, n, S // n, D).swapaxes(0, 1)          # (n, B, c, D)
    lc = labels.reshape(B, n, S // n).swapaxes(0, 1)
    mc = (mask.reshape(B, n, S // n).swapaxes(0, 1).astype(jnp.float32)
          if mask is not None else jnp.ones_like(lc, jnp.float32))

    def body(carry, xs):
        hh, ll, mm = xs
        logits = (hh @ w_out).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (logz - tgt) * mm
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(mm)), None

    carry = (jnp.zeros(()), jnp.zeros(()))
    if unroll:                                   # dry-run FLOP accounting
        for i in range(n):
            carry, _ = body(carry, (hc[i], lc[i], mc[i]))
        tot, cnt = carry
    else:
        (tot, cnt), _ = jax.lax.scan(body, carry, (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
