"""Top-k mixture-of-experts with capacity-based gather dispatch.

Tokens are sorted by routed expert (stable), ranked within each expert group,
and gathered into an (E, C+1, D) buffer (slot C absorbs capacity overflow;
dropped tokens contribute zero via a masked combine weight).  The expert
einsums carry sharding constraints so the E axis maps onto the "model"
(expert-parallel) mesh axis and the capacity axis onto "data" — GSPMD then
materializes the dispatch as all-to-all-style collectives rather than a full
replication.  Correctness is checked against a per-expert python-loop oracle
in tests (including the drop rule).
"""
from __future__ import annotations

import math
import threading

import numpy as np

import jax
import jax.numpy as jnp

from repro.analyze.lockgraph import named_lock
from repro.dist.api import shard
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, pdtype_of


def init_moe(key, cfg):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "wi_gate": dense_init(ks[1], (E, D, F), pd),
        "wi_up": dense_init(ks[2], (E, D, F), pd),
        "wo": dense_init(ks[3], (E, F, D), pd),
    }


def _capacity(T, k, E, factor):
    return max(1, int(math.ceil(T * k / E * factor)))


def _dispatch_compute(xf, probs, w, sel, wi_gate, wi_up, wo, C):
    """Capacity-gather dispatch + expert einsums + weighted combine.

    xf: (T, D); w/sel: (T, k) routing weights / expert ids (ids may exceed
    the local expert count E_loc = wi_gate.shape[0] — those pairs are
    masked out, which is how the expert-parallel path drops non-local
    pairs).  Returns (T, D).
    """
    T, D = xf.shape
    E_loc = wi_gate.shape[0]
    k = sel.shape[1]
    Tk = T * k

    eids = sel.reshape(Tk)
    local = eids < E_loc
    eids = jnp.where(local, eids, E_loc)                 # trash expert
    order = jnp.argsort(eids, stable=True)
    sorted_eids = eids[order]
    group_start = jnp.searchsorted(sorted_eids,
                                   jnp.arange(E_loc, dtype=eids.dtype))
    rank = jnp.arange(Tk, dtype=jnp.int32) - group_start[
        jnp.minimum(sorted_eids, E_loc - 1)]
    keep = (rank < C) & (sorted_eids < E_loc)
    slot = jnp.where(keep, rank, C).astype(jnp.int32)
    eid_safe = jnp.minimum(sorted_eids, E_loc - 1).astype(jnp.int32)
    tok = (order // k).astype(jnp.int32)

    disp = jnp.full((E_loc, C + 1), T, jnp.int32)
    disp = disp.at[eid_safe, slot].set(jnp.where(keep, tok, T))
    xpad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = xpad[disp]                                      # (E_loc, C+1, D)
    xe = shard(xe, P("model", "data", None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wi_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, wi_up)
    h = shard(h, P("model", "data", None))
    ye = jnp.einsum("ecf,efd->ecd", h, wo)               # (E_loc, C+1, D)
    ye = shard(ye, P("model", "data", None))

    rows = ye[eid_safe, slot]                            # (Tk, D)
    wsorted = (w.reshape(Tk)[order] * keep).astype(rows.dtype)
    return jax.ops.segment_sum(rows * wsorted[:, None], tok, num_segments=T)


class ExpertTouchTracker:
    """Aggregates which experts the router selected since the last
    snapshot flight (the dirty-delta saving path's provider signal).

    Disabled by default (zero overhead: the debug callback is only
    staged into the jaxpr when `enable()` ran before tracing).  The
    router feeds every `sel` through `record`; the snapshot driver calls
    `consume()` at flight time for the touched mask and resets it.
    """

    def __init__(self):
        self._lock = named_lock("moe.touched")
        self._mask: np.ndarray = np.zeros(0, bool)
        self.enabled = False

    def enable(self, num_experts: int) -> "ExpertTouchTracker":
        with self._lock:
            self._mask = np.zeros(int(num_experts), bool)
            self.enabled = True
        return self

    def disable(self) -> None:
        with self._lock:
            self.enabled = False
            self._mask = np.zeros(0, bool)

    def record(self, sel) -> None:
        """Fold a (T, k) routed-expert id array into the mask (host
        side; also the target of the in-jit debug callback)."""
        with self._lock:
            if not self.enabled:
                return
            ids = np.asarray(sel).reshape(-1)
            ids = ids[(ids >= 0) & (ids < self._mask.size)]
            self._mask[np.unique(ids)] = True

    def consume(self) -> np.ndarray:
        """Return-and-reset the aggregated touched mask."""
        with self._lock:
            m = self._mask.copy()
            self._mask[:] = False
            return m

    def peek(self) -> np.ndarray:
        with self._lock:
            return self._mask.copy()


# module-level singleton: the router is pure-functional, so dirtiness
# aggregation has to live beside it rather than in model state
TOUCHED = ExpertTouchTracker()


def _route(p, cfg, xf):
    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(probs, cfg.experts_per_token)     # (T, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    if TOUCHED.enabled:
        jax.debug.callback(TOUCHED.record, sel)
    return probs, w, sel


def _aux_loss(cfg, probs, sel):
    """Switch-style load-balance auxiliary loss."""
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce_frac = jnp.mean(
        (jax.nn.one_hot(sel, E, dtype=jnp.float32)).sum(1), axis=0)
    return E * jnp.sum(me * ce_frac) / cfg.experts_per_token


def moe_ffn_gspmd(p, cfg, x):
    """GSPMD-inferred dispatch (baseline). x: (B,S,D) -> (y, aux)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(T, k, E, cfg.capacity_factor)
    if cfg.moe_pad_capacity:
        # keep the (C+1)-slot dispatch buffer divisible by the data axis so
        # GSPMD can shard the capacity dim (otherwise expert compute is
        # only expert-parallel -> 16x undersharded on a 16x16 mesh)
        m = cfg.moe_pad_capacity
        C = -(-(C + 1) // m) * m - 1
    xf = x.reshape(T, D)
    probs, w, sel = _route(p, cfg, xf)
    y = _dispatch_compute(xf, probs, w, sel, p["wi_gate"], p["wi_up"],
                          p["wo"], C)
    y = shard(y.reshape(B, S, D), P(("data",), None, None))
    return y.astype(x.dtype), _aux_loss(cfg, probs, sel)


def moe_ffn_ep(p, cfg, x):
    """Explicit expert-parallel MoE (§Perf, beyond paper).

    shard_map over the full mesh: tokens stay sharded over (pod, data);
    expert weights are sharded over "model" (FSDP shards over "data" are
    all-gathered locally, textbook FSDP); each device runs the *local*
    capacity-gather dispatch for its E/model_parallel experts on its own
    token shard, and partial outputs are psum'd over "model".  Collective
    traffic per layer is one all-gather of local expert weights plus one
    (T_local, D) psum — versus the TB-scale all-reduces GSPMD infers for
    the data-dependent gathers of the baseline.
    """
    from repro.dist.api import _active_mesh, adapt_spec
    mesh = _active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_ffn_gspmd(p, cfg, x)

    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    ep = sizes["model"] if E % sizes["model"] == 0 else 1
    if ep == 1:
        return moe_ffn_gspmd(p, cfg, x)
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = math.prod(sizes[a] for a in dp_axes) if dp_axes else 1
    if (B * S) % dp:
        return moe_ffn_gspmd(p, cfg, x)
    T_loc = B * S // dp
    C_loc = _capacity(T_loc, k, E, cfg.capacity_factor)
    fsdp = tuple(a for a in ("pod", "data") if a in sizes) if cfg.fsdp \
        else ()

    def local_fn(xl, router, wg, wu, wo):
        # xl: (B_loc, S, D); wg/wu/wo: local expert shards
        if fsdp:
            wg_f = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu_f = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wo_f = jax.lax.all_gather(wo, fsdp, axis=1, tiled=True)
        else:
            wg_f, wu_f, wo_f = wg, wu, wo
        E_loc = wg_f.shape[0]
        bl, sl, _ = xl.shape
        xf = xl.reshape(bl * sl, D)
        probs, w, sel = _route({"router": router}, cfg, xf)
        m_idx = jax.lax.axis_index("model")
        sel_loc = jnp.where(sel // E_loc == m_idx, sel % E_loc, E_loc)
        y = _dispatch_compute(xf, probs, w, sel_loc, wg_f, wu_f, wo_f,
                              C_loc)
        y = jax.lax.psum(y, "model")
        aux = _aux_loss(cfg, probs, sel)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(bl, sl, D).astype(xl.dtype), aux

    x_spec = P(dp_axes if dp_axes else None, None, None)
    w_spec = P("model", fsdp if fsdp else None, None)
    if hasattr(jax, "shard_map"):                      # modern jax
        smap = jax.shard_map
        kw = {"check_vma": False}
    else:                                              # 0.4.x spelling
        from jax.experimental.shard_map import shard_map as smap
        kw = {"check_rep": False}
    y, aux = smap(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        **kw,
    )(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    return y, aux


def moe_ffn(p, cfg, x):
    """x: (B, S, D) -> (B, S, D), plus router aux loss."""
    if cfg.moe_ep:
        return moe_ffn_ep(p, cfg, x)
    return moe_ffn_gspmd(p, cfg, x)
