"""Flash-style (online-softmax) attention in pure JAX.

Used automatically for long sequences so prefill/train never materializes
the (Sq, Sk) score matrix — live memory per step is one (bq, bk) tile.
Supports causal masking, sliding windows (traced width), GQA, and an
optional *banded* mode (static window) that skips out-of-window KV blocks
entirely, turning O(S^2) FLOPs into O(S*W) — the §Perf hillclimb for SWA
architectures.

Also the reference semantics for the `swa_attention` Pallas kernel (whose
oracle is kernels/ref.py's naive masked softmax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pick_block(n, target):
    b = min(target, n)
    while n % b:
        b -= 1
    return b


def flash_attention(q, k, v, *, window, causal=True, q_offset=0,
                    block_q=512, block_k=1024, band=None, unroll=False):
    """q: (B,Sq,KV,G,hd), k/v: (B,Sk,KV,hd); window: traced int32 scalar.

    band: optional *static* int window; KV blocks fully outside the band of
    each query block are skipped (exact banded attention).
    unroll: python loops instead of lax.scan (dry-run FLOP accounting).
    Returns (B,Sq,KV,G,hd) in q.dtype.
    """
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    nq, nk = Sq // bq, Sk // bk
    scale = hd ** -0.5

    qb = q.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)   # (nq,B,bq,KV,G,hd)
    kb = k.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
    vb = v.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
    kpos_all = jnp.arange(Sk, dtype=jnp.int32).reshape(nk, bk)

    def q_block(iq, q_i, kv_idxs):
        """iq: scalar (traced or static); kv_idxs: 1-D block index array."""
        qpos = q_offset + iq * bq + jnp.arange(bq, dtype=jnp.int32)
        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)

        def kv_step(carry, ik):
            m, l, acc = carry
            k_i, v_i, kpos = kb[ik], vb[ik], kpos_all[ik]
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_i,
                           preferred_element_type=jnp.float32) * scale
            ok = jnp.ones((bq, bk), bool)
            if causal:
                ok = ok & (kpos[None, :] <= qpos[:, None])
            ok = ok & (qpos[:, None] - kpos[None, :] < window)
            ok = ok & (kpos[None, :] - qpos[:, None] < window)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_i.dtype), v_i)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc), None

        if isinstance(kv_idxs, (range, list, tuple)):
            carry = (m0, l0, a0)
            for ik in kv_idxs:
                carry, _ = kv_step(carry, ik)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kv_idxs)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)                        # (B,KV,G,bq,hd)

    if band is None and unroll:
        o = jnp.stack([q_block(iq, qb[iq], range(nk))
                       for iq in range(nq)], axis=0)
    elif band is None:
        def q_step(_, xs):
            iq, q_i = xs
            return None, q_block(iq, q_i, jnp.arange(nk))
        _, o = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    else:
        outs = []
        for iq in range(nq):
            q_lo = iq * bq + q_offset
            q_hi = q_lo + bq - 1
            k_lo_blk = max(0, (q_lo - band + 1) // bk)
            if causal:
                k_hi_blk = min(nk - 1, q_hi // bk)
            else:
                k_hi_blk = min(nk - 1, (q_hi + band - 1) // bk)
            idxs = (range(k_lo_blk, k_hi_blk + 1) if unroll
                    else jnp.arange(k_lo_blk, k_hi_blk + 1))
            outs.append(q_block(iq, qb[iq], idxs))
        o = jnp.stack(outs, axis=0)

    # o: (nq, B, KV, G, bq, hd) -> (B, Sq, KV, G, hd)
    o = jnp.moveaxis(o, 0, 1)                              # (B,nq,KV,G,bq,hd)
    o = jnp.transpose(o, (0, 1, 4, 2, 3, 5))               # (B,nq,bq,KV,G,hd)
    return o.reshape(B, Sq, KV, G, hd)
