"""Pure-jnp oracles for every Pallas kernel (shape/dtype-swept in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def xor_reduce_ref(blocks: jax.Array) -> jax.Array:
    """blocks: (k, n) uint32 -> (n,) uint32."""
    out = blocks[0]
    for i in range(1, blocks.shape[0]):
        out = jnp.bitwise_xor(out, blocks[i])
    return out


def encode_bucket_ref(blocks, nbytes: int):
    """Host oracle for kernels.stage.encode_bucket: numpy XOR fold +
    zlib CRC over the first `nbytes` bytes.  Returns (lanes, crc)."""
    import zlib

    import numpy as np
    acc = np.asarray(blocks[0]).copy()
    for i in range(1, len(blocks)):
        acc ^= np.asarray(blocks[i])
    crc = zlib.crc32(acc.view(np.uint8)[:nbytes]) & 0xFFFFFFFF
    return acc, crc


def ssd_scan_ref(u, a, Bm, Cm, h0=None):
    """Naive SSD recurrence (same semantics as models.ssm.ssd_scan_ref).

    u: (B,S,H,P) fp32; a: (B,S,H) log-decay; Bm/Cm: (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    from repro.models.ssm import ssd_scan_ref as _r
    return _r(u, a, Bm, Cm, h0=h0)


def swa_attention_ref(q, k, v, *, window, causal=True):
    """Naive masked softmax attention.

    q: (B,Sq,KV,G,hd), k/v: (B,Sk,KV,hd); window: python int or FULL.
    """
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    ok = ok & (qpos - kpos < window) & (kpos - qpos < window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
