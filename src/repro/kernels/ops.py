"""Public jit'd wrappers around the Pallas kernels.

These are the entry points used by the rest of the system; on this CPU
container they run in interpret mode (kernel body executed in Python),
on TPU they compile to Mosaic.  Each has a pure-jnp oracle in ref.py.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan as _ssd_scan_kernel
from repro.kernels.stage import encode_bucket as _encode_bucket_kernel
from repro.kernels.swa_attention import swa_flash as _swa_flash_kernel
from repro.kernels.xor_parity import xor_reduce as _xor_reduce_kernel


def xor_parity_encode(blocks, *, interpret: bool = None):
    """XOR parity of k byte blocks. blocks: (k, nbytes) uint8 -> (nbytes,).

    Pads to 4-byte lanes (uint32) for the TPU kernel.  `interpret=None`
    selects interpret mode from the JAX backend (CPU -> interpreted).
    """
    blocks = jnp.asarray(blocks)
    assert blocks.dtype == jnp.uint8 and blocks.ndim == 2
    k, n = blocks.shape
    pad = (-n) % 512                       # 128 lanes x 4 bytes
    if pad:
        blocks = jnp.pad(blocks, ((0, 0), (0, pad)))
    lanes = jax.lax.bitcast_convert_type(
        blocks.reshape(k, -1, 4), jnp.uint32).reshape(k, -1)
    out = _xor_reduce_kernel(lanes, interpret=interpret)
    out8 = jax.lax.bitcast_convert_type(
        out.reshape(-1, 1), jnp.uint8).reshape(-1)
    return out8[:n]


def xor_parity_decode(survivors, parity, *, interpret: bool = None):
    """Reconstruct the missing block: XOR(survivors..., parity)."""
    stack = jnp.concatenate(
        [jnp.asarray(parity)[None], jnp.asarray(survivors)], axis=0)
    return xor_parity_encode(stack, interpret=interpret)


def encode_bucket(blocks, *, nbytes: int, want_crc: bool = True,
                  interpret: bool = None, crc_impl: str = "pallas",
                  tile_lanes: int = None):
    """Fused snapshot-bucket encode (XOR parity fold + CRC32) on device —
    see `repro.kernels.stage`.  blocks: (k, n_lanes) uint32.  Buckets
    beyond `stage.MAX_CELL_LANES` tile over a grid and return per-tile
    digests (fold with `stage.bucket_crc`)."""
    return _encode_bucket_kernel(blocks, nbytes=nbytes, want_crc=want_crc,
                                 interpret=interpret, crc_impl=crc_impl,
                                 tile_lanes=tile_lanes)


def ssd_scan(u, a, Bm, Cm, h0=None, *, chunk: int = 128,
             interpret: bool = True):
    """Chunked SSD (Mamba2). Same contract as models.ssm.ssd_chunked."""
    return _ssd_scan_kernel(u, a, Bm, Cm, h0, chunk=chunk,
                            interpret=interpret)


def swa_attention(q, k, v, *, window=None, causal: bool = True,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = True):
    """Banded flash attention; window is a *static* int (None = full)."""
    return _swa_flash_kernel(q, k, v, window=window, causal=causal,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
