"""Pallas TPU kernels for the perf-critical hot spots:

* xor_parity    — RAIM5 parity encode/decode (the paper's EC hot loop,
                  moved on-accelerator as a beyond-paper option)
* stage         — fused snapshot-bucket encode (XOR parity fold + CRC32
                  before the d2h copy; the REFT-Sn device encode path)
* ssd_scan      — Mamba2 chunked state-space-duality scan
* swa_attention — banded (sliding-window) flash attention

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), a jit'd wrapper
in ops.py, and a pure-jnp oracle in ref.py, swept in tests/.
"""
from repro.kernels.ops import (
    encode_bucket, ssd_scan, swa_attention, xor_parity_decode,
    xor_parity_encode,
)
from repro.kernels.stage import bucket_crc

__all__ = ["bucket_crc", "encode_bucket", "ssd_scan", "swa_attention",
           "xor_parity_decode", "xor_parity_encode"]
