"""Device-side snapshot bucket encode: fused XOR-parity + CRC32 (Pallas).

The save hot path's per-bucket host work (gather -> XOR parity -> zlib
CRC) moves onto the accelerator: the L1 pump gathers a bucket's scattered
leaf byte-ranges into one contiguous uint32 lane buffer on device
(`repro.core.pipeline.DeviceEncoder`), and this kernel finishes the
encode *before* the d2h copy —

  * XOR-folds the k stacked stripe blocks of a parity bucket (k == 1 for
    own-data buckets, a pass-through), and
  * computes the bucket's CRC32 with slice-by-4 table lookups (the
    (4, 256) uint32 table lives in VMEM; one uint32 lane is consumed per
    loop step with four lookups).

so the host receives ready-to-publish shard + parity + checksum in one
`copy_to_host_async` stream and the SMP's byte-wise XOR / zlib pass
drops to a plain write.  Per-bucket CRCs are recombined into the
contiguous own-region digest with `repro.core.crcutil.crc32_combine`.

The kernel runs as a single grid cell per bucket (CRC is sequential), so
`bucket_bytes` x k must fit VMEM on real TPUs (the default 4 MiB bucket
does for small k; shrink `ReftConfig.bucket_bytes` for large SGs).  On
CPU backends it runs in interpret mode; `crc_impl="jnp"` keeps a
pure-jnp CRC fallback for backends where in-kernel table gathers lower
poorly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.crcutil import CRC_TABLES

LANE_BYTES = 512              # pad buckets to 128 uint32 lanes x 4 bytes

_MASK = 0xFF                  # plain ints: jnp constants created at module
_INIT = 0xFFFFFFFF            # scope would be captured consts in the kernel


def default_interpret() -> bool:
    """Interpret mode iff there is no real accelerator to compile for."""
    return jax.default_backend() == "cpu"


def pack_lanes(u8: jax.Array) -> jax.Array:
    """uint8 bytes (length % 4 == 0) -> little-endian uint32 lanes."""
    return jax.lax.bitcast_convert_type(
        u8.reshape(-1, 4), jnp.uint32).reshape(-1)


def _crc_words(tab, lanes, nbytes: int):
    """Slice-by-4 CRC32 over the first `nbytes` bytes of the lane
    vector (final value, i.e. init/final XOR included)."""
    nw, rem = nbytes // 4, nbytes % 4
    mask = jnp.uint32(_MASK)

    def body(i, c):
        x = c ^ lanes[i]
        return (tab[3, (x & mask).astype(jnp.int32)]
                ^ tab[2, ((x >> 8) & mask).astype(jnp.int32)]
                ^ tab[1, ((x >> 16) & mask).astype(jnp.int32)]
                ^ tab[0, ((x >> 24) & mask).astype(jnp.int32)])

    crc = jax.lax.fori_loop(0, nw, body, jnp.uint32(_INIT))
    if rem:                                  # 1-3 tail bytes, unrolled
        w = lanes[nw]
        for j in range(rem):
            byte = (w >> (8 * j)) & mask
            crc = (crc >> 8) ^ tab[0, ((crc ^ byte) & mask)
                                   .astype(jnp.int32)]
    return crc ^ jnp.uint32(_INIT)


def _encode_kernel(blocks_ref, tab_ref, out_ref, crc_ref, *,
                   nbytes: int, want_crc: bool):
    k = blocks_ref.shape[0]
    acc = blocks_ref[0]
    for i in range(1, k):                    # k is static and small (SG-1)
        acc = jax.lax.bitwise_xor(acc, blocks_ref[i])
    out_ref[...] = acc
    if want_crc:
        crc_ref[0] = _crc_words(tab_ref[...], acc, nbytes)
    else:
        crc_ref[0] = jnp.uint32(0)


@functools.partial(jax.jit, static_argnames=("nbytes", "want_crc",
                                             "interpret", "crc_impl"))
def encode_bucket(blocks: jax.Array, *, nbytes: int, want_crc: bool = True,
                  interpret: bool = None, crc_impl: str = "pallas"):
    """Fused bucket encode.  blocks: (k, n_lanes) uint32 (n_lanes % 128
    == 0; bytes past `nbytes` are zero padding).  Returns
    (encoded (n_lanes,) uint32, crc (1,) uint32).

    k == 1: own-data bucket — pass-through + CRC.
    k  > 1: parity bucket — XOR fold of the stripe blocks (+ CRC if
    asked; parity regions carry no checksum, so callers pass False).
    """
    if interpret is None:
        interpret = default_interpret()
    k, n = blocks.shape
    assert blocks.dtype == jnp.uint32 and 0 < nbytes <= 4 * n
    if crc_impl == "jnp":
        acc = blocks[0]
        for i in range(1, k):
            acc = jax.lax.bitwise_xor(acc, blocks[i])
        crc = crc32_lanes_jnp(acc, nbytes) if want_crc \
            else jnp.zeros((1,), jnp.uint32)
        return acc, crc
    kern = functools.partial(_encode_kernel, nbytes=nbytes,
                             want_crc=want_crc)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.uint32),
                   jax.ShapeDtypeStruct((1,), jnp.uint32)),
        interpret=interpret,
    )(blocks, jnp.asarray(CRC_TABLES))


@functools.partial(jax.jit, static_argnames=("nbytes",))
def crc32_lanes_jnp(lanes: jax.Array, nbytes: int) -> jax.Array:
    """Pure-jnp slice-by-4 CRC32 over uint32 lanes (no Pallas): the
    fallback for backends where in-kernel VMEM table gathers are not
    available.  Byte-identical to `zlib.crc32`."""
    return _crc_words(jnp.asarray(CRC_TABLES), lanes, nbytes).reshape(1)
