"""Device-side snapshot bucket encode: fused XOR-parity + CRC32 (Pallas).

The save hot path's per-bucket host work (gather -> XOR parity -> zlib
CRC) moves onto the accelerator: the L1 pump gathers a bucket's scattered
leaf byte-ranges into one contiguous uint32 lane buffer on device
(`repro.core.pipeline.DeviceEncoder`), and this kernel finishes the
encode *before* the d2h copy —

  * XOR-folds the k stacked stripe blocks of a parity bucket (k == 1 for
    own-data buckets, a pass-through), and
  * computes the bucket's CRC32 with slice-by-4 table lookups (the
    (4, 256) uint32 table lives in VMEM; one uint32 lane is consumed per
    loop step with four lookups).

so the host receives ready-to-publish shard + parity + checksum in one
`copy_to_host_async` stream and the SMP's byte-wise XOR / zlib pass
drops to a plain write.  Per-bucket CRCs are recombined into the
contiguous own-region digest with `repro.core.crcutil.crc32_combine`.

Small buckets run as a single grid cell (CRC is sequential).  Buckets
larger than `MAX_CELL_LANES` are TILED: the kernel runs over a
`grid=(T,)` of `TILE_LANES`-lane cells — each cell XOR-folds and
checksums only its slice (so VMEM holds one tile, not the whole bucket)
and emits a per-tile digest; the host recombines the digests into the
bucket's zlib-compatible CRC with `repro.core.crcutil.crc32_combine`
(`bucket_crc`).  On CPU backends the kernel runs in interpret mode;
`crc_impl="jnp"` keeps a pure-jnp CRC fallback (single-pass — the VMEM
tiling rationale does not apply to it) for backends where in-kernel
table gathers lower poorly.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.crcutil import CRC_TABLES, crc32_concat

LANE_BYTES = 512              # pad buckets to 128 uint32 lanes x 4 bytes
MAX_CELL_LANES = 1 << 16      # 256 KiB: biggest single-grid-cell bucket
TILE_LANES = 1 << 15          # 128 KiB grid cells beyond that

_MASK = 0xFF                  # plain ints: jnp constants created at module
_INIT = 0xFFFFFFFF            # scope would be captured consts in the kernel


def default_interpret() -> bool:
    """Interpret mode iff there is no real accelerator to compile for."""
    return jax.default_backend() == "cpu"


def pack_lanes(u8: jax.Array) -> jax.Array:
    """uint8 bytes (length % 4 == 0) -> little-endian uint32 lanes."""
    return jax.lax.bitcast_convert_type(
        u8.reshape(-1, 4), jnp.uint32).reshape(-1)


def _crc_words(tab, lanes, nbytes: int):
    """Slice-by-4 CRC32 over the first `nbytes` bytes of the lane
    vector (final value, i.e. init/final XOR included)."""
    nw, rem = nbytes // 4, nbytes % 4
    mask = jnp.uint32(_MASK)

    def body(i, c):
        x = c ^ lanes[i]
        return (tab[3, (x & mask).astype(jnp.int32)]
                ^ tab[2, ((x >> 8) & mask).astype(jnp.int32)]
                ^ tab[1, ((x >> 16) & mask).astype(jnp.int32)]
                ^ tab[0, ((x >> 24) & mask).astype(jnp.int32)])

    crc = jax.lax.fori_loop(0, nw, body, jnp.uint32(_INIT))
    if rem:                                  # 1-3 tail bytes, unrolled
        w = lanes[nw]
        for j in range(rem):
            byte = (w >> (8 * j)) & mask
            crc = (crc >> 8) ^ tab[0, ((crc ^ byte) & mask)
                                   .astype(jnp.int32)]
    return crc ^ jnp.uint32(_INIT)


def _crc_words_dyn(tab, lanes, nbytes):
    """Slice-by-4 CRC32 over the first `nbytes` bytes where `nbytes` is a
    TRACED value (the per-tile byte count of the tiled kernel): the word
    loop bound is dynamic and the 0-3 tail bytes are a masked unroll."""
    mask = jnp.uint32(_MASK)
    nbytes = jnp.asarray(nbytes, jnp.int32)
    nw = nbytes // 4

    def body(i, c):
        x = c ^ lanes[i]
        return (tab[3, (x & mask).astype(jnp.int32)]
                ^ tab[2, ((x >> 8) & mask).astype(jnp.int32)]
                ^ tab[1, ((x >> 16) & mask).astype(jnp.int32)]
                ^ tab[0, ((x >> 24) & mask).astype(jnp.int32)])

    crc = jax.lax.fori_loop(0, nw, body, jnp.uint32(_INIT))
    rem = nbytes - nw * 4
    w = lanes[jnp.minimum(nw, lanes.shape[0] - 1)]   # clamp: unused if rem=0

    def tail(j, c):
        byte = (w >> (8 * j).astype(jnp.uint32)) & mask
        nc = (c >> 8) ^ tab[0, ((c ^ byte) & mask).astype(jnp.int32)]
        return jnp.where(j < rem, nc, c)

    crc = jax.lax.fori_loop(0, 3, tail, crc)
    return crc ^ jnp.uint32(_INIT)


def _encode_kernel(blocks_ref, tab_ref, out_ref, crc_ref, *,
                   nbytes: int, want_crc: bool):
    k = blocks_ref.shape[0]
    acc = blocks_ref[0]
    for i in range(1, k):                    # k is static and small (SG-1)
        acc = jax.lax.bitwise_xor(acc, blocks_ref[i])
    out_ref[...] = acc
    if want_crc:
        crc_ref[0] = _crc_words(tab_ref[...], acc, nbytes)
    else:
        crc_ref[0] = jnp.uint32(0)


def _encode_tiled_kernel(blocks_ref, tab_ref, out_ref, crc_ref, *,
                         nbytes: int, tile_lanes: int, want_crc: bool):
    """One grid cell per `tile_lanes`-lane slice of the bucket: XOR-fold
    the slice and checksum only the slice's live bytes.  The per-tile
    digests are plain zlib CRC32s of consecutive chunks, recombined on
    the host (`bucket_crc`)."""
    t = pl.program_id(0)
    k = blocks_ref.shape[0]
    acc = blocks_ref[0]
    for i in range(1, k):
        acc = jax.lax.bitwise_xor(acc, blocks_ref[i])
    out_ref[...] = acc
    if want_crc:
        tile_bytes = 4 * tile_lanes
        nb_t = jnp.clip(jnp.int32(nbytes) - t * tile_bytes, 0, tile_bytes)
        crc_ref[0] = _crc_words_dyn(tab_ref[...], acc, nb_t)
    else:
        crc_ref[0] = jnp.uint32(0)


def resolve_tile_lanes(n_lanes: int,
                       tile_lanes: Optional[int] = None) -> Optional[int]:
    """CRC tiling decision for an `n_lanes`-lane bucket: None = single
    grid cell (small bucket), else the tile width in lanes."""
    if tile_lanes is not None:
        return tile_lanes if n_lanes > tile_lanes else None
    return TILE_LANES if n_lanes > MAX_CELL_LANES else None


@functools.partial(jax.jit, static_argnames=("nbytes", "want_crc",
                                             "interpret", "crc_impl",
                                             "tile_lanes"))
def encode_bucket(blocks: jax.Array, *, nbytes: int, want_crc: bool = True,
                  interpret: bool = None, crc_impl: str = "pallas",
                  tile_lanes: Optional[int] = None):
    """Fused bucket encode.  blocks: (k, n_lanes) uint32 (n_lanes % 128
    == 0; bytes past `nbytes` are zero padding).  Returns
    (encoded (n_lanes,) uint32, crc uint32 array) — crc has shape (1,)
    for single-cell buckets or (T,) per-tile digests when the bucket is
    larger than `MAX_CELL_LANES` (fold with `bucket_crc`).

    k == 1: own-data bucket — pass-through + CRC.
    k  > 1: parity bucket — XOR fold of the stripe blocks (+ CRC if
    asked; parity regions carry no checksum, so callers pass False).
    """
    if interpret is None:
        interpret = default_interpret()
    k, n = blocks.shape
    assert blocks.dtype == jnp.uint32 and 0 < nbytes <= 4 * n
    if crc_impl == "jnp":
        acc = blocks[0]
        for i in range(1, k):
            acc = jax.lax.bitwise_xor(acc, blocks[i])
        crc = crc32_lanes_jnp(acc, nbytes) if want_crc \
            else jnp.zeros((1,), jnp.uint32)
        return acc, crc
    tl = resolve_tile_lanes(n, tile_lanes)
    if tl is None:
        kern = functools.partial(_encode_kernel, nbytes=nbytes,
                                 want_crc=want_crc)
        return pl.pallas_call(
            kern,
            out_shape=(jax.ShapeDtypeStruct((n,), jnp.uint32),
                       jax.ShapeDtypeStruct((1,), jnp.uint32)),
            interpret=interpret,
        )(blocks, jnp.asarray(CRC_TABLES))
    nt = -(-n // tl)
    n_pad = nt * tl
    if n_pad != n:
        blocks = jnp.pad(blocks, ((0, 0), (0, n_pad - n)))
    kern = functools.partial(_encode_tiled_kernel, nbytes=nbytes,
                             tile_lanes=tl, want_crc=want_crc)
    out, crc = pl.pallas_call(
        kern,
        grid=(nt,),
        in_specs=[pl.BlockSpec((k, tl), lambda t: (0, t)),
                  pl.BlockSpec((4, 256), lambda t: (0, 0))],
        out_specs=(pl.BlockSpec((tl,), lambda t: (t,)),
                   pl.BlockSpec((1,), lambda t: (t,))),
        out_shape=(jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
                   jax.ShapeDtypeStruct((nt,), jnp.uint32)),
        interpret=interpret,
    )(blocks, jnp.asarray(CRC_TABLES))
    return out[:n], crc


def bucket_crc(crc, nbytes: int, tile_lanes: Optional[int] = None) -> int:
    """`encode_bucket` digest(s) -> the bucket's final CRC32: identity for
    the single-cell (1,) shape, a `crc32_combine` fold of consecutive
    per-tile digests for the tiled (T,) shape."""
    arr = np.asarray(crc).reshape(-1)
    if arr.size <= 1:
        return int(arr[0]) if arr.size else 0
    words = -(-nbytes // 4)
    if tile_lanes is None:
        # recover the auto tiling: lane counts are padded to LANE_BYTES.
        # The recovered tile count must match EXACTLY — an encode made
        # with an explicit tile_lanes combined at the wrong granularity
        # would fold wrong per-part lengths into a silently bad CRC.
        n_lanes = -(-nbytes // LANE_BYTES) * (LANE_BYTES // 4)
        tile_lanes = resolve_tile_lanes(n_lanes) or n_lanes
        assert -(-n_lanes // tile_lanes) == arr.size, \
            f"{arr.size} tile digests do not match the auto tiling " \
            f"({tile_lanes} lanes/tile over {n_lanes} lanes) — pass the " \
            f"tile_lanes used at encode time"
    else:
        # explicit tiling: extra all-padding tiles digest 0 bytes and
        # combine as identity, but too FEW tiles cannot cover the data
        assert -(-words // tile_lanes) <= arr.size, \
            f"{arr.size} tile digests cannot cover {nbytes} bytes " \
            f"at {tile_lanes} lanes/tile"
    tile_bytes = 4 * tile_lanes
    parts = []
    left = nbytes
    for i in range(arr.size):
        nb = max(0, min(tile_bytes, left))
        parts.append((int(arr[i]), nb))
        left -= tile_bytes
    return crc32_concat(parts)


@functools.partial(jax.jit, static_argnames=("nbytes",))
def crc32_lanes_jnp(lanes: jax.Array, nbytes: int) -> jax.Array:
    """Pure-jnp slice-by-4 CRC32 over uint32 lanes (no Pallas): the
    fallback for backends where in-kernel VMEM table gathers are not
    available.  Byte-identical to `zlib.crc32`."""
    return _crc_words(jnp.asarray(CRC_TABLES), lanes, nbytes).reshape(1)
