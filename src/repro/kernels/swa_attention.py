"""Sliding-window flash attention as a Pallas TPU kernel.

Grid (heads, q_blocks, kv_blocks), kv innermost & sequential.  The output
block's index map ignores the kv index, so the (bq, hd) accumulator stays
resident in VMEM across the kv sweep; running max / normalizer live in two
small side outputs with the same trick.  Out-of-band (window / causal)
blocks are skipped with @pl.when — on TPU this saves the MXU work for all
blocks outside the band, which is the point of SWA: O(S*W) not O(S^2).

GQA layout: q heads are flattened to (B*KV*G); the kv index map divides by
G so grouped queries share one KV block fetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  bq, bk, window, causal, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    q_lo = iq * bq
    k_lo = ik * bk

    @pl.when(ik == 0)
    def _init():
        m_ref[0] = jnp.full((bq,), NEG_INF, jnp.float32)
        l_ref[0] = jnp.zeros((bq,), jnp.float32)
        o_ref[0] = jnp.zeros(o_ref.shape[1:], jnp.float32)

    # band test: does this kv block intersect the allowed region?
    needed = jnp.bool_(True)
    if causal:
        needed = needed & (k_lo <= q_lo + bq - 1)
    if window is not None:
        needed = needed & (k_lo + bk - 1 >= q_lo - window + 1)
        if not causal:
            needed = needed & (k_lo <= q_lo + bq - 1 + window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)              # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = ok & (kpos <= qpos)
        if window is not None:
            ok = ok & (qpos - kpos < window) & (kpos - qpos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[0]
        l_prev = l_ref[0]
        o_prev = o_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[0] = m_new
        l_ref[0] = l_prev * corr + jnp.sum(p, axis=1)
        o_ref[0] = o_prev * corr[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=(
    "window", "causal", "block_q", "block_k", "interpret"))
def swa_flash(q, k, v, *, window=None, causal=True, block_q=128,
              block_k=128, interpret=True):
    """q: (B,Sq,KV,G,hd); k/v: (B,Sk,KV,hd); window: static int or None.
    Returns (B,Sq,KV,G,hd) fp32-accumulated, cast back to q.dtype."""
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(block_k, Sk)
    while Sk % bk:
        bk //= 2
    nq, nk = Sq // bq, Sk // bk
    BH = B * KV * G
    BKV = B * KV

    qf = q.reshape(B, Sq, KV * G, hd).transpose(0, 2, 1, 3) \
        .reshape(BH, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(BKV, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(BKV, Sk, hd)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, window=window,
                               causal=causal, scale=hd ** -0.5)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, nq * bq), jnp.float32),
            jax.ShapeDtypeStruct((BH, nq * bq), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    o = o.reshape(B, KV * G, Sq, hd).transpose(0, 2, 1, 3) \
        .reshape(B, Sq, KV, G, hd)
    return o.astype(q.dtype)
