"""RAIM5 XOR parity as a Pallas TPU kernel.

The paper computes parity "byte-wise on the CPU"; the beyond-paper variant
encodes parity *on the accelerator before the d2h copy*, so the host
receives shard + parity in one stream and the XOR rides the idle MXU-free
VPU cycles.  Lanes are uint32 (TPU-native integer width); tiles are
(8, 128)-aligned VMEM blocks.

encode: parity[t] = XOR_i blocks[i, t]      blocks: (k, n) uint32
decode: missing   = XOR(survivors, parity)  == encode on (k, n) stacked
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_kernel(blocks_ref, out_ref):
    k = blocks_ref.shape[0]
    acc = blocks_ref[0]
    for i in range(1, k):                    # k is static and small (SG size)
        acc = jax.lax.bitwise_xor(acc, blocks_ref[i])
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def xor_reduce(blocks: jax.Array, *, block_elems: int = 64 * 1024,
               interpret: bool = True) -> jax.Array:
    """XOR-reduce along axis 0. blocks: (k, n) uint32 -> (n,) uint32.

    n must be a multiple of 128 lanes; the wrapper in ops.py pads.
    """
    k, n = blocks.shape
    assert blocks.dtype == jnp.uint32
    be = min(block_elems, n)
    while n % be:
        be //= 2
    be = max(be, 1)
    grid = (n // be,)
    return pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, be), lambda i: (0, i))],
        out_specs=pl.BlockSpec((be,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(blocks)
