"""RAIM5 XOR parity as a Pallas TPU kernel.

The paper computes parity "byte-wise on the CPU"; the beyond-paper variant
encodes parity *on the accelerator before the d2h copy*, so the host
receives shard + parity in one stream and the XOR rides the idle MXU-free
VPU cycles.  Lanes are uint32 (TPU-native integer width); tiles are
(8, 128)-aligned VMEM blocks.

encode: parity[t] = XOR_i blocks[i, t]      blocks: (k, n) uint32
decode: missing   = XOR(survivors, parity)  == encode on (k, n) stacked

`interpret=None` (the default) selects interpret mode from the JAX
backend: compiled on a real accelerator, interpreted on CPU (CI).  A
lane count that does not divide into whole tiles is zero-padded up to a
128-lane multiple (XOR identity), never ground down to one-element grid
cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_kernel(blocks_ref, out_ref):
    k = blocks_ref.shape[0]
    acc = blocks_ref[0]
    for i in range(1, k):                    # k is static and small (SG size)
        acc = jax.lax.bitwise_xor(acc, blocks_ref[i])
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def xor_reduce(blocks: jax.Array, *, block_elems: int = 64 * 1024,
               interpret: bool = None) -> jax.Array:
    """XOR-reduce along axis 0. blocks: (k, n) uint32 -> (n,) uint32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    k, n = blocks.shape
    assert blocks.dtype == jnp.uint32
    # tile size: a whole number of 128-lane groups, never below one tile
    be = max(128, min(block_elems // 128 * 128, -(-n // 128) * 128))
    n_pad = -(-n // be) * be                 # pad up (zeros = XOR identity)
    if n_pad != n:
        blocks = jnp.pad(blocks, ((0, 0), (0, n_pad - n)))
    grid = (n_pad // be,)
    out = pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, be), lambda i: (0, i))],
        out_specs=pl.BlockSpec((be,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(blocks)
    return out[:n] if n_pad != n else out
