"""Chunked Mamba2 SSD scan as a Pallas TPU kernel.

Grid is (B, H, num_chunks) with the chunk axis innermost and *sequential*;
the inter-chunk recurrent state lives in the `h_out` block (whose index map
ignores the chunk index, so Pallas keeps it resident in VMEM across the
whole scan and flushes it once per (batch, head)).  Within a chunk the
computation is three (Q,Q)/(Q,N)/(N,P) matmuls — MXU work — exactly the
state-space-duality trade the paper family targets.

Tile choices: Q (chunk) = 128 rows, P (head dim) and N (state) are already
TPU-lane-sized (64/128); everything fp32 in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(u_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, h_ref):
    nc = pl.num_programs(2)
    ci = pl.program_id(2)

    u = u_ref[0, 0, 0]                    # (Q, P)
    a = a_ref[0, 0, 0]                    # (Q,)
    Bm = b_ref[0, 0]                      # (Q, N) — shared across heads
    Cm = c_ref[0, 0]                      # (Q, N)
    Q = u.shape[0]

    @pl.when(ci == 0)
    def _init():
        h_ref[0, 0] = h0_ref[0, 0]        # (N, P)

    h = h_ref[0, 0]                       # (N, P) carried state

    cum = jnp.cumsum(a)                   # (Q,)
    rel = cum[:, None] - cum[None, :]     # (Q, Q) <= 0 on the lower triangle
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(rows >= cols, jnp.exp(rel), 0.0)

    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)   # (Q,Q)
    y_intra = jnp.dot(scores * L, u, preferred_element_type=jnp.float32)

    y_inter = jnp.dot(Cm, h, preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]                                      # (Q,P)

    dec = jnp.exp(cum[-1] - cum)          # (Q,)
    state = jnp.dot((Bm * dec[:, None]).T, u,
                    preferred_element_type=jnp.float32)              # (N,P)
    h_ref[0, 0] = h * jnp.exp(cum[-1]) + state

    y_ref[0, 0, 0] = y_intra + y_inter


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan(u, a, Bm, Cm, h0=None, *, chunk: int = 128,
             interpret: bool = True):
    """u: (B,S,H,P) fp32; a: (B,S,H); Bm/Cm: (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N)) — same contract as
    models.ssm.ssd_chunked."""
    B, S, H, P = u.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    u_c = u.astype(jnp.float32).transpose(0, 2, 1, 3) \
        .reshape(B, H, nc, Q, P)
    a_c = a.astype(jnp.float32).transpose(0, 2, 1).reshape(B, H, nc, Q)
    b_c = Bm.astype(jnp.float32).reshape(B, nc, Q, N)
    c_c = Cm.astype(jnp.float32).reshape(B, nc, Q, N)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    else:
        h0 = jnp.swapaxes(h0, -1, -2).astype(jnp.float32)   # (B,H,N,P)

    y, h = pl.pallas_call(
        _ssd_kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(u_c, a_c, b_c, c_c, h0)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, jnp.swapaxes(h, -1, -2)                        # (B,H,P,N)
