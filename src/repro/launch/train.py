"""End-to-end training driver with REFT fault tolerance.

Trains a real model (JAX CPU here; the same code path jit-lowers onto the
production mesh) while an SG of SMP processes snapshots the train state
asynchronously.  Optional fault injection exercises the three recovery
tiers mid-run and verifies training resumes from the recovered state.

  PYTHONPATH=src python -m repro.launch.train --arch opt-125m --steps 50 \\
      --batch 2 --seq 256 --sg-size 4 --snapshot-every 2 \\
      --inject 20:software --inject 35:node
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--sg-size", type=int, default=4)
    ap.add_argument("--snapshot-every", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/reft-train-ckpt")
    ap.add_argument("--inject", action="append", default=[],
                    help="step:kind  (kind: software|node)")
    ap.add_argument("--no-reft", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.core import ReftConfig, ReftGroup
    from repro.data.pipeline import SyntheticDataset
    from repro.train.steps import init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = InputShape("cli", args.seq, args.batch, "train")
    injections = dict(tuple(x.split(":")) for x in args.inject)
    injections = {int(k): v for k, v in injections.items()}

    print(f"[train] arch={cfg.name} params={cfg.param_count():,} "
          f"batch={args.batch}x{args.seq}")
    state = init_train_state(cfg, 0).tree()
    ds = SyntheticDataset(cfg, shape, seed=0)
    step_fn = jax.jit(make_train_step(cfg))

    group = None
    if not args.no_reft:
        rcfg = ReftConfig(ckpt_dir=args.ckpt_dir,
                          checkpoint_every_snapshots=max(
                              1, args.ckpt_every // args.snapshot_every))
        group = ReftGroup(args.sg_size, state, rcfg)

    losses = []
    t0 = time.time()
    step = int(state["step"])
    try:
        while step < args.steps:
            batch = next(ds)
            state, metrics = step_fn(state, batch)
            step = int(state["step"])
            losses.append(float(metrics["loss"]))
            if group and step % args.snapshot_every == 0:
                group.snapshot(state, step, extra_meta=ds.state(),
                               wait=False)

            if step in injections and group is not None:
                kind = injections.pop(step)
                group.wait()
                print(f"[inject] {kind} failure at step {step}")
                if kind == "software":
                    group.inject_software_failure(0)
                else:
                    group.inject_node_failure(1)
                rec, rstep, extra, tier = group.recover()
                print(f"[recover] tier={tier} step={rstep}")
                state = jax.tree.map(jnp.asarray, rec)
                ds.restore(extra)
                step = rstep
                for i in range(args.sg_size):
                    group.heal(i)

            if step % 10 == 0 or step == args.steps:
                print(f"  step {step:5d} loss {losses[-1]:.4f} "
                      f"({(time.time()-t0)/max(step,1):.2f}s/step)",
                      flush=True)
        if group:
            group.wait()
            group.checkpoint()
            st = group.engines[0].stats
            print(f"[reft] snapshots={st['snapshots']} "
                  f"bytes={st['bytes_sent']:,} "
                  f"avg_snapshot_s={st['seconds']/max(st['snapshots'],1):.3f}")
    finally:
        if group:
            group.close()
    print(f"[done] steps={step} final_loss={losses[-1]:.4f} "
          f"first_loss={losses[0]:.4f} wall={time.time()-t0:.1f}s")
    assert np.isfinite(losses).all(), "loss diverged"
    if args.steps >= 100:                 # short smoke runs are too noisy
        assert np.mean(losses[-10:]) < np.mean(losses[:10]), \
            "loss did not decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
