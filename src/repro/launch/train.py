"""End-to-end training driver with pluggable fault tolerance.

Trains a real model (JAX CPU here; the same code path jit-lowers onto the
production mesh) under any registered `Checkpointer` backend — the paper's
REFT stack or a disk baseline — selected by one flag, so overhead and
recovery comparisons are apples-to-apples.  Optional fault injection
exercises the recovery ladder mid-run and verifies training resumes from
the recovered state.

  PYTHONPATH=src python -m repro.launch.train --arch opt-125m --steps 50 \\
      --batch 2 --seq 256 --backend reft --sg-size 4 --snapshot-every 2 \\
      --inject 20:software --inject 35:node

Elastic restart (reshard-on-restore): `--resume` works with a DIFFERENT
`--sg-size` than the run that wrote the checkpoint — the distributed
loader rediscovers the saved layout from the REFT-Ckpt family heads and
ranges its reads accordingly, so an n-node run restores onto m nodes.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp


def _load_stats_str(ld) -> str:
    """One-line per-phase load decomposition for resume/recover prints."""
    if ld is None:
        return ""
    out = (f" read={ld.bytes_read / 1e6:.1f}MB"
           f" decoded={ld.decoded_bytes / 1e6:.1f}MB"
           f" read_s={ld.read_seconds:.3f}")
    if ld.h2d_seconds:
        out += f" h2d_s={ld.h2d_seconds:.3f}"
    if ld.resharded:
        out += f" resharded={ld.saved_n}->{ld.target_n}"
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--backend", default="reft",
                    choices=["reft", "objstore", "sync_disk", "async_disk",
                             "null"])
    ap.add_argument("--sg-size", type=int, default=4)
    ap.add_argument("--snapshot-every", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/reft-train-ckpt")
    ap.add_argument("--resume", action="store_true",
                    help="restore-on-entry from ckpt-dir if possible")
    ap.add_argument("--auto-tune", action="store_true",
                    help="Appendix-A adaptive snapshot cadence")
    ap.add_argument("--blocking-persist", action="store_true",
                    help="run cadence persists inline (the pre-overlap "
                         "behavior) instead of fire-and-poll")
    ap.add_argument("--delta", action="store_true",
                    help="dirty-delta snapshotting: for MoE archs the "
                         "router's touched-expert mask feeds the dirty "
                         "provider; dense archs fall back to the "
                         "per-bucket digest compare")
    ap.add_argument("--inject", action="append", default=[],
                    help="STEP:KIND[:NODE]  (kind: software|node|smp|"
                         "laggard|corrupt-stripe|slow-persist|preempt)")
    ap.add_argument("--graceful-inject", action="store_true",
                    help="drain in-flight saves before each injection "
                         "(default: mid-flight, like a real failure)")
    ap.add_argument("--no-reft", action="store_true",
                    help="legacy alias for --backend null")
    args = ap.parse_args(argv)
    if args.no_reft:
        args.backend = "null"

    from repro.api import CheckpointSession, CheckpointSpec
    from repro.core.recovery import RecoveryError
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import SyntheticDataset
    from repro.train.steps import init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = InputShape("cli", args.seq, args.batch, "train")
    from repro.supervise.inject import parse_scenario
    injections = {}
    for item in args.inject:
        try:
            sc = parse_scenario(item, default_node=-1)
        except ValueError as e:
            ap.error(str(e))
        injections[sc.step] = sc
    if injections and args.backend == "null":
        ap.error("--inject needs a backend that can restore (not null)")
    if args.delta and args.backend not in ("reft", "objstore"):
        ap.error("--delta needs the reft backend family")

    print(f"[train] arch={cfg.name} params={cfg.param_count():,} "
          f"batch={args.batch}x{args.seq} backend={args.backend}"
          + (" delta" if args.delta else ""))
    if args.delta and cfg.num_experts:
        # enable BEFORE the step function traces, so the router's
        # touched-expert debug callback is staged into the jaxpr
        from repro.models.moe import TOUCHED
        TOUCHED.enable(cfg.num_experts)
    state = init_train_state(cfg, 0).tree()
    ds = SyntheticDataset(cfg, shape, seed=0)
    # no with_step_boundary wrapper here: sess.after_step runs every step
    # and already ticks the HASC gate (one boundary signal per step)
    step_fn = jax.jit(make_train_step(cfg))

    spec = CheckpointSpec(
        backend=args.backend,
        ckpt_dir=args.ckpt_dir,
        sg_size=args.sg_size,
        snapshot_every_steps=args.snapshot_every,
        checkpoint_every_steps=args.ckpt_every,
        resume=args.resume,
        auto_tune=args.auto_tune,
        options=dict(
            **({"persist_blocking": True} if args.blocking_persist else {}),
            **({"delta": True} if args.delta else {}),
        ),
    )

    losses = []
    t0 = time.time()
    step = int(state["step"])
    with CheckpointSession(spec, state) as sess:
        if args.delta and cfg.num_experts \
                and hasattr(sess.checkpointer, "set_dirty_provider"):
            from repro.core.delta import expert_dirty_ranges
            from repro.models.moe import TOUCHED
            fspec = sess.checkpointer.group.engines[0].spec
            sess.checkpointer.set_dirty_provider(
                lambda: expert_dirty_ranges(fspec, TOUCHED.consume()))
        if sess.restored is not None:
            res = sess.restored
            print(f"[resume] tier={res.tier} step={res.step}"
                  + _load_stats_str(res.load))
            state = jax.tree.map(jnp.asarray, res.state)
            ds.restore(res.extra_meta)
            step = res.step
        while step < args.steps:
            batch = next(ds)
            state, metrics = step_fn(state, batch)
            step = int(state["step"])
            losses.append(float(metrics["loss"]))
            sess.after_step(state, step, extra_meta=ds.state())

            if step in injections:
                sc = injections.pop(step)
                kind = sc.kind
                node = sc.node if sc.node >= 0 \
                    else (0 if kind == "software" else 1)
                print(f"[inject] {kind} failure at step {step} "
                      f"(node {node}"
                      + ("" if args.graceful_inject else ", mid-flight")
                      + ")")
                sess.inject(kind, node=node,
                            graceful=args.graceful_inject,
                            **sc.merged_params())
                if kind in ("laggard", "slow-persist"):
                    continue           # perf faults: nothing to restore
                if kind == "preempt":
                    # ride out the grace window; health() ticks the
                    # deadline and hard-fails the node when it expires
                    deadline = time.monotonic() + 5.0
                    while node not in sess.health().get("preempted",
                                                        [node]):
                        if time.monotonic() > deadline:
                            ap.error("preempt grace window never expired")
                        # deadline-bounded grace-window poll in the CLI
                        # harness (the sim has no event to wait on)
                        # analyze: ok ANZ007
                        time.sleep(0.05)
                try:
                    res = sess.restore()
                except RecoveryError as e:
                    ap.error(f"injected {kind} failure at step {step} is "
                             f"unrecoverable: {e} (no completed save yet — "
                             f"lower --snapshot-every or inject later)")
                print(f"[recover] tier={res.tier} step={res.step}"
                      + _load_stats_str(res.load))
                state = jax.tree.map(jnp.asarray, res.state)
                ds.restore(res.extra_meta)
                step = res.step

            if step % 10 == 0 or step == args.steps:
                print(f"  step {step:5d} loss {losses[-1]:.4f} "
                      f"({(time.time()-t0)/max(step,1):.2f}s/step)",
                      flush=True)
        sess.drain()               # join async persists + collect events
        st = sess.stats()
        # engine-side timing when the backend exposes it (async launches
        # make the trainer-side snapshot_seconds near-zero by design)
        snaps = st.get("engine_snapshots") or st.get("snapshot", 0)
        secs = st.get("engine_seconds", st.get("snapshot_seconds", 0.0))
        print(f"[{args.backend}] snapshots={snaps} "
              f"persists={st.get('persist', 0)} "
              f"persist_inflight={st.get('persist_inflight', 0)} "
              f"persist_overlap_s={st.get('persist_overlap_seconds', 0.0):.3f} "
              f"restores={st.get('restore', 0)} "
              f"avg_snapshot_s={secs/max(snaps, 1):.3f} "
              f"degraded={sess.degraded}")
        if st.get("persist_upload_bytes"):
            print(f"[{args.backend}] uploads="
                  f"{st['persist_upload_bytes'] / 1e6:.1f}MB "
                  f"upload_s={st.get('persist_upload_seconds', 0.0):.3f} "
                  f"retries={st.get('persist_upload_retries', 0)} "
                  f"throttle_s="
                  f"{st.get('persist_throttle_seconds', 0.0):.3f}")
        if st.get("delta_flights") or st.get("keyframe_flights"):
            print(f"[{args.backend}] "
                  f"delta_flights={st.get('delta_flights', 0)} "
                  f"keyframes={st.get('keyframe_flights', 0)} "
                  f"skipped_buckets={st.get('skipped_buckets', 0)} "
                  f"base_misses={st.get('delta_base_misses', 0)}")
        if st.get("scrub_passes"):
            print(f"[{args.backend}] scrub_passes={st['scrub_passes']} "
                  f"families={st.get('scrub_families', 0)} "
                  f"corrupt={st.get('scrub_corrupt', 0)} "
                  f"repaired={st.get('scrub_repaired', 0)}")
    if not losses:
        print(f"[done] steps={step} (resumed past --steps; nothing to run) "
              f"wall={time.time()-t0:.1f}s")
        return 0
    print(f"[done] steps={step} final_loss={losses[-1]:.4f} "
          f"first_loss={losses[0]:.4f} wall={time.time()-t0:.1f}s")
    assert np.isfinite(losses).all(), "loss diverged"
    if args.steps >= 100:                 # short smoke runs are too noisy
        assert np.mean(losses[-10:]) < np.mean(losses[:10]), \
            "loss did not decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
