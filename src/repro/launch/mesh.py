"""Production mesh construction (TPU v5e pods; CPU placeholders in dry-run).

A function, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # axis_types only exists on newer jax; older versions default to Auto
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        return jax.make_mesh(shape, axes, axis_types=(at.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_smoke_mesh():
    """Whatever this host offers (1 CPU device in the container)."""
    n = len(jax.devices())
    return _mesh((n, 1), ("data", "model"))


# v5e hardware constants for the roofline (DESIGN.md §6)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
