"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
against 512 placeholder CPU devices, and extract the roofline terms.

The os.environ lines below MUST run before ANY other import (jax locks the
device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import math
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config,
                           shape_supported)
from repro.data.pipeline import input_specs
from repro.dist import shardings as SH
from repro.dist.api import use_mesh
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import model as M
from repro.train.steps import init_train_state, make_train_step

_COLL_RE = re.compile(
    r"(\w+)\[([0-9,]*)\][^=]*\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in the HLO."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] = out.get(op, 0) + n * _DTYPE_BYTES[dt]
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on modern jax, a one-element
    list of dicts on 0.4.x — normalize to the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _abstract_state(cfg):
    return jax.eval_shape(lambda: init_train_state(cfg, 0).tree())


def build_lowered(arch: str, shape_name: str, mesh, verbose=False,
                  unroll=False, cfg=None):
    """Returns (lowered, meta) for the (arch, shape) pair on `mesh`.

    unroll=True unrolls the layer scan so cost_analysis counts every layer
    (XLA prices a while body once) — used for the roofline table; the
    scanned variant is used for the (faster) compile-proof runs.
    """
    cfg = cfg or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise SkipPair(why)

    with use_mesh(mesh):
        if shape.kind == "decode":
            params_sh = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            cache_sh = jax.eval_shape(
                lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
            tokens_sh = jax.ShapeDtypeStruct((shape.global_batch, 1),
                                             jnp.int32)
            p_specs = SH.named(SH.param_specs(cfg, params_sh), params_sh,
                               mesh)
            c_specs = SH.named(
                SH.cache_specs(cfg, cache_sh, shape.global_batch, mesh),
                cache_sh, mesh)
            t_spec = SH.named(SH.batch_specs(cfg, {"t": tokens_sh}),
                              {"t": tokens_sh}, mesh)["t"]

            def serve_step(params, cache, tokens):
                return M.decode_step(cfg, params, cache, tokens,
                                     unroll=unroll)

            fn = jax.jit(serve_step,
                         in_shardings=(p_specs, c_specs, t_spec),
                         out_shardings=(None, c_specs))
            lowered = fn.lower(params_sh, cache_sh, tokens_sh)
            tokens_per_step = shape.global_batch
            train = False
        elif shape.kind == "prefill":
            params_sh = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            batch_sh = input_specs(cfg, shape)
            p_specs = SH.named(SH.param_specs(cfg, params_sh), params_sh,
                               mesh)
            b_specs = SH.named(SH.batch_specs(cfg, batch_sh), batch_sh, mesh)

            def prefill_step(params, batch):
                logits, caches = M.logits_fn(cfg, params, batch,
                                             unroll=unroll)
                return logits, caches

            fn = jax.jit(prefill_step, in_shardings=(p_specs, b_specs))
            lowered = fn.lower(params_sh, batch_sh)
            tokens_per_step = shape.global_batch * shape.seq_len
            train = False
        else:
            state_sh = _abstract_state(cfg)
            batch_sh = input_specs(cfg, shape)
            s_specs = SH.named(SH.state_specs(cfg, state_sh), state_sh, mesh)
            b_specs = SH.named(SH.batch_specs(cfg, batch_sh), batch_sh, mesh)
            step = make_train_step(cfg, unroll=unroll)
            fn = jax.jit(step, in_shardings=(s_specs, b_specs),
                         out_shardings=(s_specs, None))
            lowered = fn.lower(state_sh, batch_sh)
            tokens_per_step = shape.global_batch * shape.seq_len
            train = True
    meta = {"arch": arch, "shape": shape_name, "unroll": unroll,
            "tokens_per_step": tokens_per_step, "train": train,
            "chips": math.prod(mesh.axis_sizes),
            "mesh": "x".join(map(str, mesh.axis_sizes))}
    return lowered, meta


class SkipPair(Exception):
    pass


def analyse(lowered, compiled, meta, cfg) -> dict:
    """Roofline terms.  NOTE: compiled artifacts are the *per-device* SPMD
    program, so cost_analysis flops/bytes and HLO operand shapes are already
    per-chip — terms divide by per-chip peaks, not (chips x peak)."""
    chips = meta["chips"]
    cost = cost_dict(compiled)
    flops = float(cost.get("flops", 0.0))          # per chip
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())    # per chip

    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_coll = coll["total"] / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]

    n_active = cfg.active_param_count()
    mult = 6 if meta["train"] else 2
    model_flops = mult * n_active * meta["tokens_per_step"]   # global

    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.peak_memory_in_bytes),
        }
    except Exception:
        mem_d = {}

    return {
        **meta,
        "hlo_flops_per_chip": flops,
        "hlo_flops_global": flops * chips,
        "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_compute_ratio": (model_flops / (flops * chips))
        if flops else None,
        "params_total": cfg.param_count(),
        "params_active": n_active,
        "memory": mem_d,
    }


def run_pair(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, unroll: bool = False, cfg=None) -> dict:
    cfg = cfg or get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, meta = build_lowered(arch, shape_name, mesh, unroll=unroll,
                                  cfg=cfg)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec = analyse(lowered, compiled, meta, cfg)
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)
    if verbose:
        mem = rec.get("memory", {})
        print(f"[ok] {arch} x {shape_name} mesh={rec['mesh']} "
              f"flops/chip={rec['hlo_flops_per_chip']:.3e} "
              f"bytes/chip={rec['hlo_bytes_per_chip']:.3e} "
              f"coll/chip={rec['collective_bytes']['total']:.3e} "
              f"dom={rec['dominant']} "
              f"useful={rec['useful_compute_ratio'] and round(rec['useful_compute_ratio'],3)} "
              f"args/chip={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)",
              flush=True)
    return rec


def extrapolation_period(cfg) -> int:
    """Smallest layer count that tiles the full model exactly (hybrid
    period x local:global interleave)."""
    period, _ = M._stack_period(cfg)
    if cfg.global_every:
        period = math.lcm(period, cfg.global_every)
    return period


_SCALARS = ("hlo_flops_per_chip", "hlo_bytes_per_chip", "t_compute_s",
            "t_memory_s", "t_collective_s")


def run_pair_roofline(arch: str, shape_name: str, *, multi_pod: bool = False,
                      cfg=None, verbose: bool = True) -> dict:
    """Exact roofline terms via layer extrapolation: compile the unrolled
    program at L=P and L=2P layers (P = pattern period) and extrapolate
    linearly — exact because layers are periodic and XLA cost is additive
    in unrolled layers.  Avoids multi-minute full unrolled compiles."""
    import dataclasses
    cfg = cfg or get_config(arch)
    P_ = extrapolation_period(cfg)
    L = cfg.num_layers
    if L <= 2 * P_:
        rec = run_pair(arch, shape_name, multi_pod=multi_pod, unroll=True,
                       cfg=cfg, verbose=verbose)
        rec["extrapolated"] = False
        return rec
    c1 = dataclasses.replace(cfg, name=cfg.name, num_layers=P_)
    c2 = dataclasses.replace(cfg, name=cfg.name, num_layers=2 * P_)
    r1 = run_pair(arch, shape_name, multi_pod=multi_pod, unroll=True,
                  cfg=c1, verbose=False)
    r2 = run_pair(arch, shape_name, multi_pod=multi_pod, unroll=True,
                  cfg=c2, verbose=False)

    def ex(v1, v2):
        return v1 + (v2 - v1) * (L - P_) / P_

    rec = dict(r2)
    for k in _SCALARS:
        rec[k] = ex(r1[k], r2[k])
    coll = {k: ex(r1["collective_bytes"].get(k, 0),
                  r2["collective_bytes"].get(k, 0))
            for k in set(r1["collective_bytes"]) | set(r2["collective_bytes"])}
    rec["collective_bytes"] = coll
    rec["t_collective_s"] = coll["total"] / ICI_BW
    rec["hlo_flops_global"] = rec["hlo_flops_per_chip"] * rec["chips"]
    rec["dominant"] = max(
        (("compute", rec["t_compute_s"]), ("memory", rec["t_memory_s"]),
         ("collective", rec["t_collective_s"])), key=lambda kv: kv[1])[0]
    rec["params_total"] = cfg.param_count()
    rec["params_active"] = cfg.active_param_count()
    mult = 6 if rec["train"] else 2
    rec["model_flops"] = mult * rec["params_active"] * rec["tokens_per_step"]
    rec["useful_compute_ratio"] = (rec["model_flops"]
                                   / rec["hlo_flops_global"])
    rec["extrapolated"] = True
    rec["memory"] = {}            # memory comes from the full scanned proof
    rec["lower_s"] = r1["lower_s"] + r2["lower_s"]
    rec["compile_s"] = r1["compile_s"] + r2["compile_s"]
    if verbose:
        print(f"[ok] {arch} x {shape_name} mesh={rec['mesh']} (extrap {P_}->"
              f"{L}L) flops/chip={rec['hlo_flops_per_chip']:.3e} "
              f"bytes/chip={rec['hlo_bytes_per_chip']:.3e} "
              f"coll/chip={coll['total']:.3e} dom={rec['dominant']} "
              f"useful={round(rec['useful_compute_ratio'], 3)}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None, help="append JSONL records here")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact roofline FLOPs")
    ap.add_argument("--mode", choices=["proof", "roofline"], default="proof",
                    help="roofline = layer-extrapolated unrolled analysis")
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    records = []
    failures = 0
    for a, s in pairs:
        try:
            if args.mode == "roofline":
                rec = run_pair_roofline(a, s, multi_pod=args.multi_pod)
            else:
                rec = run_pair(a, s, multi_pod=args.multi_pod,
                               unroll=args.unroll)
            records.append(rec)
        except SkipPair as e:
            print(f"[skip] {a} x {s}: {e}", flush=True)
            records.append({"arch": a, "shape": s, "skipped": str(e)})
        except Exception as e:
            failures += 1
            print(f"[FAIL] {a} x {s}: {type(e).__name__}: {e}", flush=True)
            records.append({"arch": a, "shape": s, "error": repr(e)})
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(records[-1]) + "\n")
    print(f"done: {len(records)} pairs, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
