"""§Perf hillclimb runner: re-lower a chosen (arch x shape) pair with a
config variant and report the roofline-term deltas vs baseline.

  PYTHONPATH=src python -m repro.launch.hillclimb --exp jamba_pad16
  PYTHONPATH=src python -m repro.launch.hillclimb --list
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import sys

# experiment -> (arch, shape, {config overrides})
EXPERIMENTS = {
    # --- hillclimb A: jamba train_4k (worst useful-compute ratio) ---
    "jamba_base": ("jamba-v0.1-52b", "train_4k", {}),
    "jamba_pad16": ("jamba-v0.1-52b", "train_4k", {"moe_pad_capacity": 16}),
    "jamba_pad16_dots": ("jamba-v0.1-52b", "train_4k",
                         {"moe_pad_capacity": 16, "remat_policy": "dots"}),
    "jamba_pad16_ce": ("jamba-v0.1-52b", "train_4k",
                       {"moe_pad_capacity": 16, "chunked_ce": 512}),

    # --- hillclimb B: kimi train_4k (most collective-bound) ---
    "kimi_base": ("kimi-k2-1t-a32b", "train_4k", {}),
    "kimi_pad16": ("kimi-k2-1t-a32b", "train_4k", {"moe_pad_capacity": 16}),
    "kimi_pad16_dots": ("kimi-k2-1t-a32b", "train_4k",
                        {"moe_pad_capacity": 16, "remat_policy": "dots"}),

    # --- hillclimb C: starcoder2 prefill_32k (paper-representative SWA;
    #     banded attention is the beyond-paper TPU optimization) ---
    "starcoder2_base": ("starcoder2-3b", "prefill_32k", {}),
    "starcoder2_band": ("starcoder2-3b", "prefill_32k",
                        {"banded_attention": True}),
    "starcoder2_band_train": ("starcoder2-3b", "train_4k",
                              {"banded_attention": True}),

    "jamba_ep": ("jamba-v0.1-52b", "train_4k", {"moe_ep": True}),
    "kimi_ep": ("kimi-k2-1t-a32b", "train_4k", {"moe_ep": True}),
    "kimi_ep_dots": ("kimi-k2-1t-a32b", "train_4k",
                     {"moe_ep": True, "remat_policy": "dots"}),
    "jamba_ep_dots": ("jamba-v0.1-52b", "train_4k",
                      {"moe_ep": True, "remat_policy": "dots"}),
    "jamba_ep_q64": ("jamba-v0.1-52b", "train_4k",
                     {"moe_ep": True, "ssd_chunk": 64}),
    "kimi_ep_dots_cf1": ("kimi-k2-1t-a32b", "train_4k",
                         {"moe_ep": True, "remat_policy": "dots",
                          "capacity_factor": 1.0}),
    "jamba_ep_q128": ("jamba-v0.1-52b", "train_4k",
                      {"moe_ep": True, "ssd_chunk": 128}),

    # dense memory-bound pairs: remat dots
    "hubert_dots": ("hubert-xlarge", "train_4k", {"remat_policy": "dots"}),
    "phi3v_dots": ("phi-3-vision-4.2b", "train_4k",
                   {"remat_policy": "dots"}),
    "deepseek_dots": ("deepseek-67b", "train_4k", {"remat_policy": "dots"}),

    # --- extras beyond the three required pairs ---
    "dbrx_ep": ("dbrx-132b", "train_4k", {"moe_ep": True}),
    "kimi_ep_prefill": ("kimi-k2-1t-a32b", "prefill_32k", {"moe_ep": True}),
    "dbrx_pad16": ("dbrx-132b", "train_4k", {"moe_pad_capacity": 16}),
    "gemma3_ringkv": ("gemma3-4b", "long_500k", {"window_kv_cache": True}),
    "gemma3_ringkv32k": ("gemma3-4b", "decode_32k",
                         {"window_kv_cache": True}),
    "starcoder2_ringkv": ("starcoder2-3b", "long_500k",
                          {"window_kv_cache": True}),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", action="append", default=[])
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--json", default="results/hillclimb.jsonl")
    args = ap.parse_args(argv)
    if args.list:
        for k, v in EXPERIMENTS.items():
            print(k, "->", v)
        return 0

    from repro.configs import get_config
    from repro.launch.dryrun import run_pair_roofline

    for name in args.exp:
        arch, shape, over = EXPERIMENTS[name]
        cfg = get_config(arch)
        if over:
            cfg = dataclasses.replace(cfg, **over)
        print(f"=== {name}: {arch} x {shape} overrides={over}", flush=True)
        rec = run_pair_roofline(arch, shape, cfg=cfg)
        rec["experiment"] = name
        rec["overrides"] = over
        if args.json:
            os.makedirs(os.path.dirname(args.json), exist_ok=True)
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
