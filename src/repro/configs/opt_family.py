"""OPT family — the paper's own evaluation models (§6.1) [arXiv:2205.01068].

Used by the REFT benchmarks (weak/strong scaling over OPT-125M..2.7B).
"""
from repro.configs.base import ModelConfig, register

_COMMON = dict(family="dense", vocab_size=50272, rope_theta=1e4,
               source="arXiv:2205.01068 (paper §6.1)")

OPT_125M = register(ModelConfig(
    name="opt-125m", num_layers=12, d_model=768, num_heads=12,
    num_kv_heads=12, d_ff=3072, **_COMMON))

OPT_350M = register(ModelConfig(
    name="opt-350m", num_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=4096, **_COMMON))

OPT_1_3B = register(ModelConfig(
    name="opt-1.3b", num_layers=24, d_model=2048, num_heads=32,
    num_kv_heads=32, d_ff=8192, **_COMMON))

OPT_2_7B = register(ModelConfig(
    name="opt-2.7b", num_layers=32, d_model=2560, num_heads=32,
    num_kv_heads=32, d_ff=10240, **_COMMON))
