"""Architecture configuration system.

Every assigned architecture gets a ``ModelConfig`` (exact paper/model-card
numbers) in ``src/repro/configs/<id>.py``.  ``reduced()`` derives the
family-preserving smoke-test variant (<=2 layers, d_model<=512, <=4 experts)
exercised on CPU; the full configs are only ever lowered via the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kinds
ATTN = "attn"
SSM = "ssm"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention flavour ---
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # sliding window width; None = full attention everywhere
    sliding_window: Optional[int] = None
    # local:global interleave -- every `global_every`-th layer is global
    # (0 = all layers share `sliding_window`); gemma3 uses 6 (5 local : 1 global)
    global_every: int = 0
    causal: bool = True              # False for encoder-only (hubert)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # apply MoE on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # hybrid interleave: layers where idx % attn_period == attn_index are
    # attention, the rest SSM (0 = homogeneous per `family`)
    attn_period: int = 0
    attn_index: int = 0

    # --- modality / head ---
    is_encoder: bool = False         # no decode step (hubert)
    embed_inputs: bool = True        # False: inputs are precomputed embeddings
    num_patches: int = 0             # VLM: image patch embeddings prepended
    tie_embeddings: bool = False

    # --- numerics / partitioning knobs ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    fsdp: bool = False               # additionally shard params over data(+pod)
    remat: bool = True               # activation checkpointing on the scan body
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    # beyond-paper perf knobs (see EXPERIMENTS.md §Perf)
    chunked_ce: int = 0              # >0: sequence-chunked cross-entropy
    window_kv_cache: bool = False    # SWA layers cache only the window
    banded_attention: bool = False   # skip out-of-window KV blocks (SWA)
    # round the MoE dispatch buffer (capacity+1 axis) up to a multiple, so
    # the capacity axis stays shardable over the data axis (§Perf)
    moe_pad_capacity: int = 0
    # explicit expert-parallel MoE via shard_map (local dispatch + psum over
    # the model axis) instead of GSPMD-inferred sharding (§Perf)
    moe_ep: bool = False
    # SSD chunk length Q: the intra-chunk decay matrix is O(S*Q*heads) fp32
    # of HBM traffic, so Q trades compute quadratics vs memory (§Perf)
    ssd_chunk: int = 256

    # provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kind(self, idx: int) -> str:
        if self.family == "ssm":
            return SSM
        if self.family == "hybrid" and self.attn_period:
            return ATTN if idx % self.attn_period == self.attn_index else SSM
        return ATTN

    def layer_is_moe(self, idx: int) -> bool:
        if not self.num_experts:
            return False
        return idx % self.moe_every == self.moe_offset

    def layer_window(self, idx: int) -> Optional[int]:
        """Effective attention window of layer `idx` (None = full)."""
        if self.sliding_window is None:
            return None
        if self.global_every and (idx + 1) % self.global_every == 0:
            return None                      # global layer
        return self.sliding_window

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode (500k) is supported: every layer is
        either SSM or sliding-window attention with a bounded window (global
        interleave layers are decode-linear and allowed)."""
        if self.is_encoder:
            return False
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            # attention layers must be a minority & windowable; SSM carries ctx
            return True
        return self.sliding_window is not None

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        n = 0
        if self.embed_inputs:
            n += V * D
        if not self.is_encoder and not self.tie_embeddings:
            n += D * V
        elif self.is_encoder:
            n += D * V                      # prediction head
        hd = self.head_dim
        for i in range(self.num_layers):
            n += 2 * D                      # two RMSNorm gains
            if self.layer_kind(i) == ATTN:
                n += D * (self.num_heads * hd)            # wq
                n += 2 * D * (self.num_kv_heads * hd)     # wk, wv
                n += (self.num_heads * hd) * D            # wo
                if self.qk_norm:
                    n += 2 * hd
            else:
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                n += D * (2 * di + 2 * N + H)             # in_proj
                n += self.ssm_conv_width * (di + 2 * N)   # conv
                n += 3 * H                                # A, dt_bias, D skip
                n += di * D                               # out_proj
                n += di                                   # gate norm
            if self.layer_is_moe(i):
                E = self.num_experts
                n += D * E                                # router
                n += E * (3 * D * F)                      # gated experts
            else:
                if F:
                    n += 3 * D * F                        # gated MLP
        n += D                                            # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        total = self.param_count()
        n_moe = sum(1 for i in range(self.num_layers) if self.layer_is_moe(i))
        dead = n_moe * (self.num_experts - self.experts_per_token) * (3 * D * F)
        return total - dead

    def reduced(self) -> "ModelConfig":
        """Family-preserving smoke-test variant (2 layers, d<=512, <=4 experts)."""
        changes = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=256,
            d_ff=512 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=64 if self.num_heads else 0,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            # drop-free in smoke tests (C >= T*k); the capacity drop rule is
            # unit-tested separately against the python oracle
            capacity_factor=float(max(self.num_experts, 1)),
            moe_every=min(self.moe_every, 2) if self.num_experts else 1,
            ssm_state=min(self.ssm_state, 64) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            attn_period=2 if self.attn_period else 0,
            attn_index=1 if self.attn_period else 0,
            global_every=2 if self.global_every else 0,
            sliding_window=(64 if self.sliding_window is not None else None),
            num_patches=min(self.num_patches, 4),
            dtype="float32",
            param_dtype="float32",
            remat=False,
            fsdp=False,
        )
        if self.num_experts:
            changes["moe_offset"] = min(self.moe_offset, 1)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ----------------------------------------------------------------------
# registry
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def _load_all():
    # import side-effect registers every config module
    from repro.configs import (  # noqa: F401
        starcoder2_3b, hubert_xlarge, jamba_v01_52b, phi3_vision_4p2b,
        dbrx_132b, kimi_k2_1t, qwen3_8b, mamba2_130m, deepseek_67b,
        gemma3_4b, opt_family,
    )


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is exercised; reason recorded in DESIGN.md."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch without sub-quadratic variant"
    if cfg.is_encoder and shape.name == "long_500k":
        return False, "encoder-only; no long-context decode"
    return True, ""
