"""HuBERT-XLarge — encoder-only audio backbone [arXiv:2106.07447].

Modality carve-out: the conv/mel frontend is a stub — ``input_specs`` provides
precomputed frame embeddings (B, S, d_model); we build the transformer encoder
that consumes them, with a masked-prediction head over the 504-unit codebook.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,                 # k-means codebook units
    is_encoder=True,
    causal=False,
    embed_inputs=False,             # frame embeddings come from the stub frontend
    source="arXiv:2106.07447",
))
