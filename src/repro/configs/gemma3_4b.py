"""Gemma3-4B — dense, 5:1 local:global interleave, 128k context
[hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,                 # 5 local : 1 global
    qk_norm=True,
    head_dim=256,
    rope_theta=1e6,
    chunked_ce=512,                 # 262k vocab
    window_kv_cache=False,          # flipped on in the §Perf hillclimb
    source="hf:google/gemma-3-1b-pt",
))
