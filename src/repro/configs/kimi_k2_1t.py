"""Kimi K2 — trillion-parameter MoE, 384 experts top-8, 32B active
(paper-table numbers) [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                      # per-expert ffn (fine-grained experts)
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    moe_every=1, moe_offset=0,
    rope_theta=5e4,
    fsdp=True,
    chunked_ce=512,                 # 163k vocab: never materialize full logits
    source="arXiv:2501.kimi2",
))
