"""Qwen3-8B — dense, GQA(kv=8), qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1e6,
    chunked_ce=512,
    source="hf:Qwen/Qwen3-8B",
))
