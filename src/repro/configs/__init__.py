from repro.configs.base import (
    ModelConfig, InputShape, INPUT_SHAPES, get_config, list_configs,
    register, shape_supported,
)

ASSIGNED_ARCHS = (
    "starcoder2-3b", "hubert-xlarge", "jamba-v0.1-52b", "phi-3-vision-4.2b",
    "dbrx-132b", "kimi-k2-1t-a32b", "qwen3-8b", "mamba2-130m",
    "deepseek-67b", "gemma3-4b",
)

__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "get_config", "list_configs",
    "register", "shape_supported", "ASSIGNED_ARCHS",
]
