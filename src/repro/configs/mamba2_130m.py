"""Mamba2-130M — attention-free SSM, SSD (state-space duality)
[arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                         # mamba blocks have no separate MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    source="arXiv:2405.21060",
))
