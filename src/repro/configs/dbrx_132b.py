"""DBRX 132B — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    moe_every=1, moe_offset=0,      # every layer is MoE
    rope_theta=5e5,
    fsdp=True,
    source="hf:databricks/dbrx-base",
))
