"""Jamba-v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2, moe_offset=1,      # MoE on every other layer
    attn_period=8, attn_index=4,    # 1 attention : 7 mamba per 8-layer period
    ssm_state=16,                   # jamba uses mamba-1 state 16
    ssm_head_dim=64,
    fsdp=True,
    source="arXiv:2403.19887",
))
