"""Phi-3-vision 4.2B — phi3-mini decoder + CLIP frontend (stubbed)
[hf:microsoft/Phi-3-vision-128k-instruct].

Modality carve-out: ``input_specs`` provides precomputed patch embeddings
(B, num_patches, d_model) prepended to the token sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,                # MHA
    d_ff=8192,
    vocab_size=32064,
    num_patches=576,                # CLIP ViT-L/14 @ 336px
    rope_theta=1e4,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
))
