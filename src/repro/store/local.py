"""Filesystem-backed `ObjectStore` — the tests/CI tier-4 target.

Maps keys to files under one root.  Multipart semantics mirror a real
object store: `put_part` lands `<path>.partNNNNNN` scratch files (each
written tmp-then-rename, so a crashed part never half-exists),
`compose` concatenates them into a tmp file, fsyncs, and `os.replace`s
onto the final path — readers see either the previous object or the
complete new one, never a prefix.  `list` hides parts and scratch, so a
torn upload is invisible exactly like an uncomposed S3 multipart.

`write_range` is a deliberate extra beyond the `ObjectStore` protocol:
the scrubber uses it to patch a repaired stripe in place instead of
re-uploading a whole shard.  Wrappers forward it when the inner store
has one; callers fall back to read-patch-put when absent.
"""
from __future__ import annotations

import os
import tempfile
from typing import List

import numpy as np

from repro.store.base import NotFoundError, ObjectStore, StoreError

_PART_SUFFIX = ".part"


def _is_scratch(name: str) -> bool:
    if ".tmp" in name:
        return True
    stem, sep, tail = name.rpartition(_PART_SUFFIX)
    return bool(stem) and sep == _PART_SUFFIX and tail.isdigit()


class LocalObjectStore(ObjectStore):
    kind = "local"

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    # --------------------------------------------------------- internals
    def _path(self, key: str) -> str:
        if not key or key.startswith("/") or ".." in key.split("/"):
            raise StoreError(f"bad object key {key!r}")
        return os.path.join(self.root, *key.split("/"))

    def _part_path(self, key: str, part: int) -> str:
        return f"{self._path(key)}{_PART_SUFFIX}{part:06d}"

    @staticmethod
    def _write_atomic(path: str, data) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=os.path.basename(path) + ".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            tmp = None
        finally:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    # ------------------------------------------------------------- write
    def put_part(self, key: str, part: int, data) -> None:
        if part < 0:
            raise StoreError(f"bad part index {part}")
        self._write_atomic(self._part_path(key, part), bytes(data))

    def compose(self, key: str, nparts: int) -> int:
        path = self._path(key)
        parts = [self._part_path(key, i) for i in range(nparts)]
        for p in parts:
            if not os.path.exists(p):
                raise StoreError(f"compose {key!r}: missing part {p}")
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=os.path.basename(path) + ".tmp")
        total = 0
        try:
            with os.fdopen(fd, "wb") as f:
                for p in parts:
                    with open(p, "rb") as pf:
                        while True:
                            chunk = pf.read(8 << 20)
                            if not chunk:
                                break
                            f.write(chunk)
                            total += len(chunk)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            tmp = None
        finally:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
        for p in parts:
            try:
                os.unlink(p)
            except OSError:
                pass
        return total

    def put(self, key: str, data) -> None:
        # single fsync'd rename — skip the part shuffle for small blobs
        self._write_atomic(self._path(key), bytes(data))

    # -------------------------------------------------------------- read
    def read_range(self, key: str, lo: int, hi: int) -> np.ndarray:
        if hi < lo:
            raise StoreError(f"bad range [{lo}, {hi})")
        try:
            fd = os.open(self._path(key), os.O_RDONLY)
        except FileNotFoundError:
            raise NotFoundError(key) from None
        try:
            out = np.empty(hi - lo, dtype=np.uint8)
            view = memoryview(out).cast("B")
            got = 0
            while got < len(view):
                chunk = os.preadv(fd, [view[got:]], lo + got)
                if chunk <= 0:
                    raise StoreError(
                        f"short read on {key!r}: wanted [{lo}, {hi}), "
                        f"got {got} bytes")
                got += chunk
            return out
        finally:
            os.close(fd)

    def size(self, key: str) -> int:
        try:
            return os.stat(self._path(key)).st_size
        except FileNotFoundError:
            raise NotFoundError(key) from None

    # --------------------------------------------------- listing / admin
    def list(self, prefix: str = "") -> List[str]:
        out = []
        for dirpath, _, names in os.walk(self.root):
            rel = os.path.relpath(dirpath, self.root)
            base = "" if rel == "." else rel.replace(os.sep, "/") + "/"
            for name in names:
                if _is_scratch(name):
                    continue
                key = base + name
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass
        self._prune(os.path.dirname(self._path(key)))

    def delete_prefix(self, prefix: str) -> int:
        n = super().delete_prefix(prefix)
        # sweep scratch (torn parts under a GC'd family) too
        root = self._path(prefix) if prefix else self.root
        if os.path.isdir(root):
            for dirpath, _, names in os.walk(root, topdown=False):
                for name in names:
                    if _is_scratch(name):
                        try:
                            os.unlink(os.path.join(dirpath, name))
                        except OSError:
                            pass
                self._prune(dirpath)
        return n

    def _prune(self, path: str) -> None:
        # drop now-empty directories so list()/walks stay cheap
        while path.startswith(self.root) and path != self.root:
            try:
                os.rmdir(path)
            except OSError:
                return
            path = os.path.dirname(path)

    # ----------------------------------------------------- scrub support
    def write_range(self, key: str, off: int, data) -> None:
        """Patch bytes in place at `off` (scrub repair fast path)."""
        try:
            fd = os.open(self._path(key), os.O_WRONLY)
        except FileNotFoundError:
            raise NotFoundError(key) from None
        try:
            os.pwrite(fd, bytes(data), off)
            os.fsync(fd)
        finally:
            os.close(fd)

    @property
    def config(self) -> dict:
        return {"kind": "local", "root": self.root}
