"""Object-store abstraction for the tier-4 durable rung.

The recovery ladder's durable story used to end at local `.reft` files
(tier 3): one node-local disk loss below the in-memory tiers and the
family was gone.  `ObjectStore` is the minimal remote-tier contract the
rest of the stack programs against:

  put_part / compose   multipart upload — the SMP's persist worker
                       streams one part per RAIM5 stripe, then composes
                       the final object (no staging copy, no torn
                       objects: the composed key appears atomically);
  read_range           positioned reads — restore plans (`LoadPlan`)
                       pull exactly the stripe sub-ranges they need;
  list / delete        discovery + retention (manifest listing, GC).

Implementations: `LocalObjectStore` (filesystem-backed, tests/CI) and
`FlakyStore` (an injectable wrapper simulating latency, throttling, and
transient 5xx-style errors to exercise retry-with-backoff).

Errors are split into `TransientStoreError` (throttle/5xx analogue —
retryable, `transient = True`) and terminal `StoreError`s; callers that
must survive a flaky remote wrap operations in `call_with_retries`
(bounded exponential backoff).  Stores are constructed from plain config
dicts via `store_from_config` so the SMP child process — a separate OS
process that only ever sees pickled persist messages — can build its own
instance on the far side of the pipe.
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np


class StoreError(RuntimeError):
    """Terminal object-store failure (bad key, malformed compose, ...)."""


class NotFoundError(StoreError):
    """The requested key does not exist."""


class TransientStoreError(StoreError):
    """Retryable failure (throttling / 5xx analogue).  The `transient`
    class attribute lets modules that must not import this package (the
    loader's `ObjectSource` sits below it) detect retryability with
    `getattr(err, "transient", False)`."""

    transient = True


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient store errors."""
    attempts: int = 5           # total tries (1 = no retry)
    base_s: float = 0.05        # first backoff
    max_s: float = 2.0          # backoff cap
    mult: float = 2.0


def retry_policy(cfg) -> RetryPolicy:
    """RetryPolicy from a plain dict (persist messages / spec.options),
    an existing policy, or None (defaults)."""
    if cfg is None:
        return RetryPolicy()
    if isinstance(cfg, RetryPolicy):
        return cfg
    return RetryPolicy(
        attempts=int(cfg.get("attempts", 5)),
        base_s=float(cfg.get("base_s", 0.05)),
        max_s=float(cfg.get("max_s", 2.0)),
        mult=float(cfg.get("mult", 2.0)))


def call_with_retries(fn: Callable[[], object],
                      policy: Optional[RetryPolicy] = None,
                      sleep: Callable[[float], None] = time.sleep
                      ) -> Tuple[object, int]:
    """Run `fn`, retrying `TransientStoreError` with bounded exponential
    backoff.  Returns (result, retries_used); terminal errors — and a
    transient error on the last attempt — propagate."""
    pol = policy or RetryPolicy()
    attempts = max(1, pol.attempts)
    delay = pol.base_s
    for i in range(attempts):
        try:
            return fn(), i
        except TransientStoreError:
            if i + 1 >= attempts:
                raise
            sleep(delay)
            delay = min(pol.max_s, delay * pol.mult)
    raise AssertionError("unreachable")


def retrier(retry_cfg) -> Callable[[Callable[[], object]], object]:
    """A `call -> result` wrapper the loader's `ObjectSource` takes: it
    never imports this package, so recovery hands it a closure instead."""
    pol = retry_policy(retry_cfg)
    return lambda fn: call_with_retries(fn, pol)[0]


class ObjectStore(abc.ABC):
    """Minimal object-store protocol (see module docstring).  Keys are
    `/`-separated paths; objects are immutable once composed."""

    kind: str = "abstract"

    # ------------------------------------------------------------ write
    @abc.abstractmethod
    def put_part(self, key: str, part: int, data) -> None:
        """Upload part `part` (0-based) of the object at `key`.  Parts
        are invisible until `compose`."""

    @abc.abstractmethod
    def compose(self, key: str, nparts: int) -> int:
        """Assemble parts 0..nparts-1 into the final object (atomic:
        readers see either the old object or the complete new one, never
        a prefix).  Returns the object size; the parts are consumed."""

    def put(self, key: str, data) -> None:
        """Single-shot object write (manifests, small blobs)."""
        self.put_part(key, 0, data)
        self.compose(key, 1)

    # ------------------------------------------------------------- read
    @abc.abstractmethod
    def read_range(self, key: str, lo: int, hi: int) -> np.ndarray:
        """Bytes [lo, hi) of the object as a uint8 array."""

    @abc.abstractmethod
    def size(self, key: str) -> int:
        """Object size in bytes; raises `NotFoundError` when absent."""

    def read(self, key: str) -> bytes:
        return bytes(self.read_range(key, 0, self.size(key)))

    def exists(self, key: str) -> bool:
        try:
            self.size(key)
            return True
        except NotFoundError:
            return False

    # -------------------------------------------------- listing / admin
    @abc.abstractmethod
    def list(self, prefix: str = "") -> List[str]:
        """Sorted keys of composed objects under `prefix` (parts and
        scratch are never listed)."""

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        """Remove one object (idempotent: absent keys are a no-op)."""

    def delete_prefix(self, prefix: str) -> int:
        """Remove every object under `prefix`; returns the count."""
        n = 0
        for key in self.list(prefix):
            self.delete(key)
            n += 1
        return n

    @property
    @abc.abstractmethod
    def config(self) -> dict:
        """A plain picklable dict `store_from_config` rebuilds this store
        from — the form persist messages carry across the SMP pipe."""


def store_from_config(cfg) -> "ObjectStore":
    """Construct a store from its config dict (or pass an instance
    through).  The factory every process boundary routes through."""
    if isinstance(cfg, ObjectStore):
        return cfg
    if not isinstance(cfg, dict):
        raise StoreError(f"bad store config {cfg!r}")
    kind = cfg.get("kind")
    if kind == "local":
        from repro.store.local import LocalObjectStore
        return LocalObjectStore(cfg["root"])
    if kind == "flaky":
        from repro.store.flaky import FlakyStore
        inner = store_from_config(cfg["inner"])
        return FlakyStore(
            inner,
            latency_s=float(cfg.get("latency_s", 0.0)),
            error_rate=float(cfg.get("error_rate", 0.0)),
            fail_every=int(cfg.get("fail_every", 0)),
            seed=int(cfg.get("seed", 0)))
    raise StoreError(f"unknown store kind {kind!r}")
