"""Background integrity scrubber for persisted REFT-Ckpt families.

Durable shards rot silently: a local `.reft` file or a remote shard
object can lose a stripe to bitrot/partial overwrite long before any
restore reads it — and the restore that finally notices is the one that
can least afford a missing rung.  The scrubber walks persisted families
on a cadence, re-verifies every stripe digest (the same per-block CRC
table the loader folds into restore reads), and — because the shard
layout IS RAIM5 — re-derives a lost/corrupt block from the surviving
stripe members and parity, rewriting it in place:

  data block (s, j) on node v   <- XOR(parity of stripe s,
                                       sibling blocks (s, j') j' != j)
  parity of stripe s on node s  <- XOR(data blocks (s, 0..n-2))

Both durable tiers scrub through one engine: `_FileFamily` adapts a
local family (positioned reads/writes around the pickled head),
`_ObjectFamily` a remote one (manifest digests + `read_range`, patching
via the store's `write_range` fast path when offered).  A stripe whose
digest table never recorded a CRC is skipped, not failed; a block whose
reconstruction inputs are themselves corrupt is reported unrepairable
(n == 1 families carry no parity at all).

`Scrubber` is the daemon: scan every `interval_s`, skip steps with
in-flight persists, fold results into `stats()` (surfaced through the
session like every other backend counter) and hand each `ScrubReport`
to an `on_report` callback (the objstore backend emits scrub events
from it).
"""
from __future__ import annotations

import os
import pickle
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analyze.lockgraph import named_lock
from repro.store.base import NotFoundError, ObjectStore, StoreError, \
    call_with_retries, retry_policy


@dataclass
class ScrubReport:
    """One family's scrub outcome."""
    step: int
    kind: str                       # "file" | "object"
    members: int = 0
    segments: int = 0               # digest-verified blocks (incl. parity)
    bytes_verified: int = 0
    corrupt: List[str] = field(default_factory=list)    # found this pass
    repaired: List[str] = field(default_factory=list)
    unrepairable: List[str] = field(default_factory=list)
    errors: List[str] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not (self.corrupt or self.errors)


# ------------------------------------------------------------- adapters
class _FileFamily:
    """Local `.reft` family: digests from the pickled heads, positioned
    reads/writes offset past them."""

    kind = "file"

    def __init__(self, step: int, paths: Dict[int, str]):
        from repro.core.smp import NodeLayout
        self.step = step
        self._paths = dict(paths)
        self._off: Dict[int, int] = {}
        self._stripes: Dict[int, Optional[dict]] = {}
        self._parity_crc: Dict[int, Optional[int]] = {}
        for node, path in sorted(paths.items()):
            with open(path, "rb") as f:
                head = pickle.load(f)
                self._off[node] = f.tell()
            self._stripes[node] = head.get("crc_stripes")
            try:
                meta = pickle.loads(head["meta"])
                self._parity_crc[node] = meta.get("crc_parity")
            except Exception:
                self._parity_crc[node] = None
            n, total = head["n"], head["total_bytes"]
        self.n, self.total_bytes = n, total
        self.layout = NodeLayout(self.n, self.total_bytes)

    @property
    def nodes(self) -> List[int]:
        return sorted(self._paths)

    def stripe_digests(self, node: int) -> Optional[dict]:
        return self._stripes[node]

    def parity_digest(self, node: int) -> Optional[int]:
        return self._parity_crc[node]

    def read(self, node: int, lo: int, hi: int) -> np.ndarray:
        with open(self._paths[node], "rb") as f:
            return np.frombuffer(
                os.pread(f.fileno(), hi - lo, self._off[node] + lo),
                np.uint8)

    def write(self, node: int, off: int, data: np.ndarray) -> None:
        fd = os.open(self._paths[node], os.O_WRONLY)
        try:
            os.pwrite(fd, bytes(memoryview(data).cast("B")),
                      self._off[node] + off)
            os.fsync(fd)
        finally:
            os.close(fd)


class _ObjectFamily:
    """Remote family: digests from the MANIFEST, ranged reads offset past
    the head blob; repair patches in place via `write_range` when the
    store offers it, else read-patch-put."""

    kind = "object"

    def __init__(self, store: ObjectStore, manifest: dict, retry=None):
        from repro.core.smp import NodeLayout
        self._store = store
        self._pol = retry_policy(retry)
        self.step = int(manifest["step"])
        self.n = int(manifest["n"])
        self.total_bytes = int(manifest["total_bytes"])
        self.layout = NodeLayout(self.n, self.total_bytes)
        self._nodes = {int(k): v for k, v in manifest["nodes"].items()}

    @property
    def nodes(self) -> List[int]:
        return sorted(self._nodes)

    def stripe_digests(self, node: int) -> Optional[dict]:
        return self._nodes[node].get("crc_stripes")

    def parity_digest(self, node: int) -> Optional[int]:
        return self._nodes[node].get("crc_parity")

    def read(self, node: int, lo: int, hi: int) -> np.ndarray:
        ent = self._nodes[node]
        off = int(ent["data_off"])
        out, _ = call_with_retries(
            lambda: self._store.read_range(ent["key"], off + lo, off + hi),
            self._pol)
        return out

    def write(self, node: int, off: int, data: np.ndarray) -> None:
        ent = self._nodes[node]
        blob = bytes(memoryview(data).cast("B"))
        base = int(ent["data_off"]) + off
        if hasattr(self._store, "write_range"):
            call_with_retries(
                lambda: self._store.write_range(ent["key"], base, blob),
                self._pol)
            return
        whole, _ = call_with_retries(
            lambda: bytearray(self._store.read(ent["key"])), self._pol)
        whole[base:base + len(blob)] = blob
        call_with_retries(
            lambda: self._store.put(ent["key"], bytes(whole)), self._pol)


class _ChainFamily:
    """A delta family resolved against its keyframe: reads go through
    `ChainSource` (newest layer first, holes fall through), so the bytes
    verified are the RESOLVED step's — checked against the NEWEST delta
    head's merged stripe table, exactly what a restore would verify.
    Repair WRITES are routed via `ChainSource.locate_spans` to whichever
    layer actually serves each span (a keyframe hole's reconstruction IS
    the keyframe's original bytes — nothing newer overlays it — so
    patching in place is sound at every link)."""

    kind = "chain"

    def __init__(self, src, write_base, write_layer):
        # write_base(node, local_off, data);
        # write_layer(layer_idx, node, payload_off, data)
        self._src = src
        self.step = src.step
        self.n = src.n
        self.total_bytes = src.total_bytes
        self.layout = src.layout
        self._write_base = write_base
        self._write_layer = write_layer

    @property
    def nodes(self) -> List[int]:
        return self._src.nodes

    def stripe_digests(self, node: int) -> Optional[dict]:
        return self._src.layers[-1].head(node).get("crc_stripes")

    def parity_digest(self, node: int) -> Optional[int]:
        try:
            return self._src.meta(node).get("crc_parity")
        except Exception:
            return None

    def read(self, node: int, lo: int, hi: int) -> np.ndarray:
        return self._src.read_local(node, lo, hi)

    def write(self, node: int, off: int, data) -> None:
        view = memoryview(data).cast("B")
        end = off + len(view)
        for li, poff, a, b in self._src.locate_spans(node, off, end):
            chunk = bytes(view[a - off:b - off])
            if li < 0:
                self._write_base(node, a, chunk)
            else:
                self._write_layer(li, node, poff, chunk)

    def close(self) -> None:
        self._src.close()


def _pwrite_at(path: str, off: int, blob: bytes) -> None:
    fd = os.open(path, os.O_WRONLY)
    try:
        os.pwrite(fd, blob, off)
        os.fsync(fd)
    finally:
        os.close(fd)


def _head_off(path: str) -> int:
    with open(path, "rb") as f:
        pickle.load(f)
        return f.tell()


def _chain_file_family(ckpt_dir: str, step: int, full, deltas
                       ) -> Optional[_ChainFamily]:
    """Build the scrub adapter for one local delta step, or None when
    its chain does not resolve."""
    from repro.core.recovery import (
        _delta_paths, _family_paths, _open_chain, resolve_chain,
    )
    res = resolve_chain(ckpt_dir, step, full, deltas)
    if res is None:
        return None
    kf, links = res
    src = _open_chain(ckpt_dir, step, full, deltas)
    try:
        nodes = sorted(range(src.n))
        base_paths = _family_paths(ckpt_dir, kf, nodes)
        base_off = {nd: _head_off(p) for nd, p in base_paths.items()}
        layer_paths = [_delta_paths(ckpt_dir, s, b, nodes)
                       for s, b in links]
        layer_off = [{nd: _head_off(p) for nd, p in lp.items()}
                     for lp in layer_paths]
    except BaseException:
        src.close()
        raise

    def write_base(node, off, blob):
        _pwrite_at(base_paths[node], base_off[node] + off, blob)

    def write_layer(li, node, poff, blob):
        _pwrite_at(layer_paths[li][node], layer_off[li][node] + poff, blob)

    return _ChainFamily(src, write_base, write_layer)


def _chain_object_family(store: ObjectStore, prefix: str, step: int,
                         retry=None) -> _ChainFamily:
    """Build the scrub adapter for one remote delta step by walking its
    manifest `base_step` links down to the full keyframe manifest."""
    from repro.core.loader import ChainSource, DeltaLayer, ObjectSource
    from repro.store.base import retrier
    from repro.store.manifest import load_manifest, manifest_base_step

    pol = retry_policy(retry)
    wrap = retrier(retry)
    man = load_manifest(store, prefix, step, retry=retry)
    link_mans: List[dict] = []
    seen = {int(step)}
    while True:
        base = manifest_base_step(man)
        if base is None:
            break
        link_mans.append(man)
        if base in seen:
            raise ValueError(f"delta chain for step {step} cycles at {base}")
        seen.add(base)
        man = load_manifest(store, prefix, base, retry=retry)
    link_mans.reverse()                              # oldest -> newest
    src = ChainSource(ObjectSource(store, man, retry=wrap),
                      [DeltaLayer.from_objects(store, m, retry=wrap)
                       for m in link_mans])

    def put_at(key, off, blob):
        if hasattr(store, "write_range"):
            call_with_retries(lambda: store.write_range(key, off, blob), pol)
            return
        whole, _ = call_with_retries(lambda: bytearray(store.read(key)), pol)
        whole[off:off + len(blob)] = blob
        call_with_retries(lambda: store.put(key, bytes(whole)), pol)

    base_nodes = {int(k): v for k, v in man["nodes"].items()}
    layer_nodes = [{int(k): v for k, v in m["nodes"].items()}
                   for m in link_mans]

    def write_base(node, off, blob):
        ent = base_nodes[node]
        put_at(ent["key"], int(ent["data_off"]) + off, blob)

    def write_layer(li, node, poff, blob):
        ent = layer_nodes[li][node]
        put_at(ent["key"], int(ent["data_off"]) + poff, blob)

    return _ChainFamily(src, write_base, write_layer)


# ----------------------------------------------------------- family scrub
def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(memoryview(arr).cast("B")) & 0xFFFFFFFF


def scrub_family(fam, repair: bool = True) -> ScrubReport:
    """Verify every recorded stripe digest of one family; with `repair`,
    reconstruct corrupt blocks from RAIM5 parity and rewrite them.
    Returns the pass's report (`corrupt` lists what verification found,
    `repaired`/`unrepairable` how repair fared)."""
    from repro.core import raim5

    rep = ScrubReport(step=fam.step, kind=fam.kind, members=len(fam.nodes))
    n, lay = fam.n, fam.layout
    bs = lay.bs if n > 1 else lay.own_bytes

    bad_data: set = set()           # (node, local_index)
    bad_parity: set = set()         # node (== stripe)
    for node in fam.nodes:
        digs = fam.stripe_digests(node)
        crcs = (digs or {}).get("crcs") or []
        nblocks = (n - 1) if n > 1 else 1
        for li in range(min(nblocks, len(crcs))):
            blob = fam.read(node, li * bs, (li + 1) * bs)
            rep.segments += 1
            rep.bytes_verified += blob.nbytes
            if _crc(blob) != crcs[li] & 0xFFFFFFFF:
                bad_data.add((node, li))
                rep.corrupt.append(f"node{node}:block{li}")
        pcrc = fam.parity_digest(node)
        if n > 1 and pcrc is not None:
            blob = fam.read(node, lay.own_bytes, lay.own_bytes + bs)
            rep.segments += 1
            rep.bytes_verified += blob.nbytes
            if _crc(blob) != pcrc & 0xFFFFFFFF:
                bad_parity.add(node)
                rep.corrupt.append(f"node{node}:parity")

    if not repair or not (bad_data or bad_parity):
        return rep

    if n == 1:                      # no parity, nothing to derive from
        rep.unrepairable = list(rep.corrupt)
        return rep

    def data_ref(node: int, li: int) -> Tuple[int, int]:
        r = raim5.data_blocks_of_node(node, n)[li]
        return r.stripe, r.index

    def slot(s: int, j: int) -> Tuple[int, int]:
        node = raim5.node_of_block(s, j, n)
        return node, raim5.local_block_index(node, s, j, n)

    # fixpoint: each repaired block may unlock another (a stripe with a
    # bad parity AND a bad data block is only repairable if one of the
    # two becomes clean first — it never does; but independent stripes
    # heal in any order)
    progress = True
    while progress and (bad_data or bad_parity):
        progress = False
        for node, li in sorted(bad_data):
            s, j = data_ref(node, li)
            if s in bad_parity:
                continue
            sibs = [slot(s, k) for k in range(n - 1) if k != j]
            if any(sl in bad_data for sl in sibs):
                continue
            blocks = [fam.read(s, lay.own_bytes, lay.own_bytes + bs)]
            blocks += [fam.read(sn, sl * bs, (sl + 1) * bs)
                       for sn, sl in sibs]
            fixed = raim5.xor_blocks(blocks)
            fam.write(node, li * bs, fixed)
            if _crc(fixed) == \
                    fam.stripe_digests(node)["crcs"][li] & 0xFFFFFFFF:
                bad_data.discard((node, li))
                rep.repaired.append(f"node{node}:block{li}")
                progress = True
        for s in sorted(bad_parity):
            slots = [slot(s, k) for k in range(n - 1)]
            if any(sl in bad_data for sl in slots):
                continue
            blocks = [fam.read(sn, sl * bs, (sl + 1) * bs)
                      for sn, sl in slots]
            fixed = raim5.xor_blocks(blocks)
            fam.write(s, lay.own_bytes, fixed)
            pcrc = fam.parity_digest(s)
            if pcrc is None or _crc(fixed) == pcrc & 0xFFFFFFFF:
                bad_parity.discard(s)
                rep.repaired.append(f"node{s}:parity")
                progress = True

    rep.unrepairable = sorted([f"node{nd}:block{li}"
                               for nd, li in bad_data]
                              + [f"node{s}:parity" for s in bad_parity])
    return rep


# ------------------------------------------------------------ tier walks
def scrub_local_dir(ckpt_dir: str, repair: bool = True,
                    skip_steps=()) -> List[ScrubReport]:
    """Scrub every COMPLETE local family under `ckpt_dir` (a family is
    complete when all shards of its own saved n are on disk — torn ones
    belong to GC, in-flight ones to `skip_steps`)."""
    from repro.core.recovery import checkpoint_families, delta_families
    skip = {int(s) for s in skip_steps}
    out: List[ScrubReport] = []
    full = checkpoint_families(ckpt_dir)
    deltas = delta_families(ckpt_dir)
    for step, nodes in sorted(full.items()):
        if step in skip:
            continue
        paths = {nd: os.path.join(ckpt_dir, f"step-{step}-node-{nd}.reft")
                 for nd in nodes}
        try:
            fam = _FileFamily(step, paths)
            if set(fam.nodes) != set(range(fam.n)):
                continue                       # torn: GC's problem
            out.append(scrub_family(fam, repair=repair))
        except Exception as e:                 # head unreadable / racing GC
            rep = ScrubReport(step=step, kind="file")
            rep.errors.append(repr(e))
            out.append(rep)
    for step in sorted(set(deltas) - set(full)):
        if step in skip:
            continue
        fam = None
        try:
            fam = _chain_file_family(ckpt_dir, step, full, deltas)
            if fam is None:
                continue                       # torn chain: GC's problem
            out.append(scrub_family(fam, repair=repair))
        except Exception as e:
            rep = ScrubReport(step=step, kind="chain")
            rep.errors.append(repr(e))
            out.append(rep)
        finally:
            if fam is not None:
                fam.close()
    return out


def scrub_object_store(store: ObjectStore, prefix: str = "families",
                       repair: bool = True, skip_steps=(),
                       retry=None) -> List[ScrubReport]:
    """Scrub every manifest-complete remote family under `prefix`."""
    from repro.store.manifest import load_manifest, object_families
    skip = {int(s) for s in skip_steps}
    out: List[ScrubReport] = []
    try:
        families = object_families(store, prefix)
    except StoreError:
        return out
    from repro.store.manifest import manifest_base_step
    for step in sorted(families):
        if step in skip:
            continue
        try:
            man = load_manifest(store, prefix, step, retry=retry)
            if manifest_base_step(man) is not None:
                fam = _chain_object_family(store, prefix, step, retry=retry)
            else:
                fam = _ObjectFamily(store, man, retry=retry)
            out.append(scrub_family(fam, repair=repair))
        except (StoreError, NotFoundError, KeyError, ValueError) as e:
            rep = ScrubReport(step=step, kind="object")
            rep.errors.append(repr(e))
            out.append(rep)
    return out


# --------------------------------------------------------------- daemon
class Scrubber:
    """Cadenced integrity scans over both durable tiers.

    `skip_steps` is a zero-arg callable returning steps to leave alone
    this pass (the manager's in-flight persists — their families are
    still growing); `on_report` receives each family's `ScrubReport`."""

    def __init__(self, ckpt_dir: Optional[str] = None,
                 store: Optional[ObjectStore] = None,
                 prefix: str = "families", *,
                 interval_s: float = 300.0, repair: bool = True,
                 skip_steps: Optional[Callable[[], list]] = None,
                 on_report: Optional[Callable[[ScrubReport], None]] = None,
                 retry=None):
        self.ckpt_dir = ckpt_dir
        self.store = store
        self.prefix = prefix
        self.interval_s = float(interval_s)
        self.repair = repair
        self._skip = skip_steps or (lambda: ())
        self._on_report = on_report
        self._retry = retry
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = named_lock("scrub.stats")
        self._stats = {"scrub_passes": 0, "scrub_families": 0,
                       "scrub_segments": 0, "scrub_bytes": 0,
                       "scrub_corrupt": 0, "scrub_repaired": 0,
                       "scrub_unrepairable": 0, "scrub_errors": 0,
                       "scrub_seconds": 0.0}

    # ------------------------------------------------------------ scans
    def scan_once(self) -> List[ScrubReport]:
        """One synchronous pass over both tiers; folds into stats()."""
        t0 = time.perf_counter()
        skip = list(self._skip())
        reports: List[ScrubReport] = []
        if self.ckpt_dir:
            reports += scrub_local_dir(self.ckpt_dir, repair=self.repair,
                                       skip_steps=skip)
        if self.store is not None:
            reports += scrub_object_store(self.store, self.prefix,
                                          repair=self.repair,
                                          skip_steps=skip,
                                          retry=self._retry)
        with self._lock:
            st = self._stats
            st["scrub_passes"] += 1
            st["scrub_seconds"] += time.perf_counter() - t0
            for r in reports:
                st["scrub_families"] += 1
                st["scrub_segments"] += r.segments
                st["scrub_bytes"] += r.bytes_verified
                st["scrub_corrupt"] += len(r.corrupt)
                st["scrub_repaired"] += len(r.repaired)
                st["scrub_unrepairable"] += len(r.unrepairable)
                st["scrub_errors"] += len(r.errors)
        if self._on_report is not None:
            for r in reports:
                try:
                    self._on_report(r)
                except Exception:
                    pass
        return reports

    def stats(self) -> dict:
        with self._lock:
            return dict(self._stats)

    # ----------------------------------------------------------- daemon
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="reft-scrubber")
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.scan_once()
            except Exception:
                with self._lock:
                    self._stats["scrub_errors"] += 1

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        t = self._thread
        self._thread = None
        if t is not None:
            t.join(timeout)
