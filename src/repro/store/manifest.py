"""Remote family layout + per-family manifests.

A persisted family lives under `<prefix>/step-<S>/`:

    <prefix>/step-<S>/node-<N>.reft      one shard object per member —
                                         the same head+buffer framing as
                                         the local `.reft` file, so one
                                         verify/parse path serves both
    <prefix>/step-<S>/MANIFEST.json      completeness marker + digests

The manifest is written LAST, after every shard object composed, so its
mere presence certifies the family: `CheckpointManager.latest()` and the
restore ladder only ever consider steps whose manifest exists, and a
torn upload (crash mid-stream) is invisible until GC sweeps its orphan
objects.  It records the saved topology (n, total_bytes, run) and, per
node, the shard key, byte offsets, and the stripe digest table — enough
for the scrubber to verify and parity-repair remote objects without
touching the shard heads at all.
"""
from __future__ import annotations

import json
import re
from typing import Dict, Optional, Set

from repro.store.base import ObjectStore, call_with_retries, retry_policy

MANIFEST_NAME = "MANIFEST.json"
MANIFEST_VERSION = 1

_STEP_DIR_RE = re.compile(r"(?:^|/)step-(\d+)/")
_MANIFEST_RE = re.compile(r"(?:^|/)step-(\d+)/" + re.escape(MANIFEST_NAME) + r"$")


def family_prefix(prefix: str, step: int) -> str:
    return f"{prefix}/step-{step}" if prefix else f"step-{step}"


def shard_key(prefix: str, step: int, node: int) -> str:
    return f"{family_prefix(prefix, step)}/node-{node}.reft"


def delta_shard_key(prefix: str, step: int, base_step: int,
                    node: int) -> str:
    """Key of a delta shard object: the base step rides in the name
    (mirroring the local `step-S-from-B-node-N.reftd` layout) so chain
    resolution and GC never have to open the object."""
    return (f"{family_prefix(prefix, step)}/"
            f"node-{node}-from-{int(base_step)}.reftd")


def manifest_key(prefix: str, step: int) -> str:
    return f"{family_prefix(prefix, step)}/{MANIFEST_NAME}"


def build_manifest(run: str, step: int, n: int, total_bytes: int,
                   nodes: Dict[int, dict]) -> dict:
    """Assemble the family manifest from per-node upload records (the
    `upload` info each persist round carries back: key, nbytes,
    data_off, parts, crc_stripes, crc_own, crc_parity)."""
    man = {
        "version": MANIFEST_VERSION,
        "run": run,
        "step": int(step),
        "n": int(n),
        "total_bytes": int(total_bytes),
        "nodes": {str(node): dict(rec) for node, rec in nodes.items()},
    }
    bases = {rec.get("base_step") for rec in nodes.values()} if nodes \
        else {None}
    if len(bases) == 1 and None not in bases:
        # uniform delta family (persist rounds are all-or-nothing): lift
        # the chain edge to the manifest top level so GC and chain
        # resolution read it without touching shard records
        man["kind"] = "delta"
        man["base_step"] = int(bases.pop())
    else:
        man["kind"] = "full"
    return man


def manifest_base_step(man: dict) -> Optional[int]:
    """The family's chain parent step, or None for a full family."""
    if man.get("kind") == "delta" and man.get("base_step") is not None:
        return int(man["base_step"])
    return None


def put_manifest(store: ObjectStore, prefix: str, man: dict,
                 retry=None) -> None:
    key = manifest_key(prefix, man["step"])
    blob = json.dumps(man, sort_keys=True).encode()
    call_with_retries(lambda: store.put(key, blob), retry_policy(retry))


def load_manifest(store: ObjectStore, prefix: str, step: int,
                  retry=None) -> dict:
    key = manifest_key(prefix, step)
    blob, _ = call_with_retries(lambda: store.read(key), retry_policy(retry))
    man = json.loads(bytes(blob).decode())
    man["nodes"] = {int(k): v for k, v in man.get("nodes", {}).items()}
    return man


def object_families(store: ObjectStore, prefix: str = "") -> Dict[int, str]:
    """Complete remote families: {step: family prefix} for every step
    whose manifest object exists (the completeness marker)."""
    out: Dict[int, str] = {}
    for key in store.list(prefix):
        m = _MANIFEST_RE.search(key)
        if m:
            out[int(m.group(1))] = key[: -len("/" + MANIFEST_NAME)]
    return out


def list_step_prefixes(store: ObjectStore, prefix: str = "") -> Set[int]:
    """Every step with ANY object under it — complete or torn.  The GC
    sweep diff's this against `object_families` to find orphans."""
    out: Set[int] = set()
    for key in store.list(prefix):
        m = _STEP_DIR_RE.search(key)
        if m:
            out.add(int(m.group(1)))
    return out


def delete_family(store: ObjectStore, prefix: str, step: int) -> int:
    return store.delete_prefix(family_prefix(prefix, step))
