"""Stripe-granular multipart shard upload.

Runs inside the SMP's persist worker thread, off the training path:
the shard object is streamed as one part for the pickled head plus one
part per RAIM5 stripe block of the pinned snapshot buffer (own region
sliced at `seg` = block size, parity tail as the final part), then
composed.  Parts are memoryview slices of the shared-memory buffer —
no staging copy — and each part write is wrapped in bounded
retry-with-backoff so a transient remote error never loses a family.

The optional `throttle` callback is the SMP's persist token bucket: it
charges each part before the write, so remote upload bandwidth and the
local `.reft` writes share one `persist_bw_limit` budget.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.store.base import ObjectStore, RetryPolicy, call_with_retries, \
    retry_policy


def upload_shard(store: ObjectStore, key: str, head_blob: bytes, buf,
                 seg: int, own_bytes: int, *,
                 retry=None,
                 throttle: Optional[Callable[[int], None]] = None) -> dict:
    """Upload one member shard (head + pinned buffer) as a multipart
    object at `key`.  `buf` is the member's full snapshot buffer (own
    region then parity); `seg` is the stripe block size the own region
    is sliced at.  Returns the upload record the family manifest stores.
    """
    t0 = time.perf_counter()
    pol = retry_policy(retry)
    view = memoryview(buf).cast("B")
    parts = [bytes(head_blob)]
    for lo in range(0, own_bytes, seg):
        parts.append(view[lo:min(lo + seg, own_bytes)])
    if own_bytes < len(view):                      # parity tail (n > 1)
        parts.append(view[own_bytes:])

    nbytes = 0
    retries = 0
    for i, data in enumerate(parts):
        if throttle is not None:
            throttle(len(data))
        _, r = call_with_retries(
            lambda i=i, data=data: store.put_part(key, i, data), pol)
        retries += r
        nbytes += len(data)
    _, r = call_with_retries(lambda: store.compose(key, len(parts)), pol)
    retries += r
    return {
        "key": key,
        "nbytes": nbytes,
        "data_off": len(head_blob),
        "parts": len(parts),
        "upload_bytes": nbytes,
        "upload_s": time.perf_counter() - t0,
        "retries": retries,
    }


_DELTA_PART_BYTES = 8 << 20


def upload_delta(store: ObjectStore, key: str, head_blob: bytes, buf,
                 extents, *, retry=None,
                 throttle: Optional[Callable[[int], None]] = None) -> dict:
    """Upload one member's `.reftd` delta shard: head (which records
    `base_step` + `extents`) followed by the raw bytes of each
    buffer-local extent, concatenated — byte-identical to the local
    `.reftd` file, so the chain loader parses either through one path.
    Extents are sliced into bounded parts; the object is usually tiny
    (that is the point), but a near-dense delta still streams."""
    t0 = time.perf_counter()
    pol = retry_policy(retry)
    view = memoryview(buf).cast("B")
    parts = [bytes(head_blob)]
    for lo, hi in extents:
        for a in range(int(lo), int(hi), _DELTA_PART_BYTES):
            parts.append(view[a:min(a + _DELTA_PART_BYTES, int(hi))])

    nbytes = 0
    retries = 0
    for i, data in enumerate(parts):
        if throttle is not None:
            throttle(len(data))
        _, r = call_with_retries(
            lambda i=i, data=data: store.put_part(key, i, data), pol)
        retries += r
        nbytes += len(data)
    _, r = call_with_retries(lambda: store.compose(key, len(parts)), pol)
    retries += r
    return {
        "key": key,
        "nbytes": nbytes,
        "data_off": len(head_blob),
        "parts": len(parts),
        "upload_bytes": nbytes,
        "upload_s": time.perf_counter() - t0,
        "retries": retries,
    }
