"""Tier-4 object-store durability (docs/API.md "Tier-4 object store").

`ObjectStore` protocol + filesystem/fault-injecting implementations,
stripe-granular multipart shard upload, per-family remote manifests,
and the background integrity `Scrubber`.  The `objstore` backend in
`repro.api.objstore` assembles these behind the uniform `Checkpointer`
facade.
"""
from repro.store.base import (
    NotFoundError, ObjectStore, RetryPolicy, StoreError,
    TransientStoreError, call_with_retries, retrier, retry_policy,
    store_from_config,
)
from repro.store.flaky import FlakyStore
from repro.store.local import LocalObjectStore
from repro.store.manifest import (
    MANIFEST_NAME, build_manifest, delete_family, delta_shard_key,
    family_prefix, list_step_prefixes, load_manifest, manifest_base_step,
    manifest_key, object_families, put_manifest, shard_key,
)
from repro.store.scrub import (
    ScrubReport, Scrubber, scrub_family, scrub_local_dir,
    scrub_object_store,
)
from repro.store.upload import upload_delta, upload_shard

__all__ = [
    "ObjectStore", "LocalObjectStore", "FlakyStore",
    "StoreError", "NotFoundError", "TransientStoreError",
    "RetryPolicy", "retry_policy", "call_with_retries", "retrier",
    "store_from_config", "upload_shard", "upload_delta",
    "MANIFEST_NAME", "family_prefix", "shard_key", "delta_shard_key",
    "manifest_key", "build_manifest", "put_manifest", "load_manifest",
    "manifest_base_step", "object_families", "list_step_prefixes",
    "delete_family",
    "ScrubReport", "Scrubber", "scrub_family", "scrub_local_dir",
    "scrub_object_store",
]
