"""Fault-injecting `ObjectStore` wrapper.

Wraps any inner store and perturbs the *data-path* calls (put_part /
compose / put / read_range / read) with configurable latency and
transient 5xx-style failures, so retry-with-backoff paths are exercised
under test without a real unreliable remote.  Faults are deterministic:
`fail_every=k` trips every k-th data-path op (a counter, so a bounded
retry always eventually succeeds), and `error_rate` draws from a seeded
RNG.  Listing/admin calls pass through untouched — fault injection aims
at upload/restore, not discovery.

A fault fires *before* the inner call, so a failed put never partially
lands — matching a rejected-by-throttle request.
"""
from __future__ import annotations

import random
import time
from typing import List

import numpy as np

from repro.store.base import ObjectStore, TransientStoreError


class FlakyStore(ObjectStore):
    kind = "flaky"

    def __init__(self, inner: ObjectStore, *, latency_s: float = 0.0,
                 error_rate: float = 0.0, fail_every: int = 0,
                 seed: int = 0):
        self.inner = inner
        self.latency_s = float(latency_s)
        self.error_rate = float(error_rate)
        self.fail_every = int(fail_every)
        self._rng = random.Random(seed)
        self._seed = int(seed)
        self.counts = {"ops": 0, "faults": 0}

    def _perturb(self, op: str) -> None:
        self.counts["ops"] += 1
        if self.latency_s:
            time.sleep(self.latency_s)
        trip = (self.fail_every
                and self.counts["ops"] % self.fail_every == 0)
        if not trip and self.error_rate:
            trip = self._rng.random() < self.error_rate
        if trip:
            self.counts["faults"] += 1
            raise TransientStoreError(
                f"simulated 503 on {op} (op #{self.counts['ops']})")

    # ---------------------------------------------------------- faulted
    def put_part(self, key: str, part: int, data) -> None:
        self._perturb("put_part")
        self.inner.put_part(key, part, data)

    def compose(self, key: str, nparts: int) -> int:
        self._perturb("compose")
        return self.inner.compose(key, nparts)

    def put(self, key: str, data) -> None:
        self._perturb("put")
        self.inner.put(key, data)

    def read_range(self, key: str, lo: int, hi: int) -> np.ndarray:
        self._perturb("read_range")
        return self.inner.read_range(key, lo, hi)

    def read(self, key: str) -> bytes:
        self._perturb("read")
        return self.inner.read(key)

    # ------------------------------------------------------ passthrough
    def size(self, key: str) -> int:
        return self.inner.size(key)

    def exists(self, key: str) -> bool:
        return self.inner.exists(key)

    def list(self, prefix: str = "") -> List[str]:
        return self.inner.list(prefix)

    def delete(self, key: str) -> None:
        self.inner.delete(key)

    def delete_prefix(self, prefix: str) -> int:
        return self.inner.delete_prefix(prefix)

    def write_range(self, key: str, off: int, data) -> None:
        # only when the inner store offers the scrub fast path
        self.inner.write_range(key, off, data)

    @property
    def config(self) -> dict:
        return {"kind": "flaky", "inner": self.inner.config,
                "latency_s": self.latency_s, "error_rate": self.error_rate,
                "fail_every": self.fail_every, "seed": self._seed}
