"""Deterministic synthetic data pipeline + dry-run input specs.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input — the dry-run lowers against these with zero device allocation.  The
modality carve-out lives here: audio/VLM configs receive precomputed
frame/patch embeddings of the documented shape instead of raw media.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.family == "vlm":
        return seq_len - cfg.num_patches
    return seq_len


def batch_shapes(cfg: ModelConfig, shape: InputShape) -> dict:
    """Name -> (shape, dtype) for the given (arch, input-shape)."""
    B, S = shape.global_batch, shape.seq_len
    embed_dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens": ((B, 1), jnp.int32)}
    out = {}
    if cfg.family == "vlm":
        out["patches"] = ((B, cfg.num_patches, cfg.d_model), embed_dt)
        out["tokens"] = ((B, _text_len(cfg, S)), jnp.int32)
        out["labels"] = ((B, S), jnp.int32)
    elif not cfg.embed_inputs:                  # audio frames
        out["frames"] = ((B, S, cfg.d_model), embed_dt)
        out["labels"] = ((B, S), jnp.int32)
    else:
        out["tokens"] = ((B, S), jnp.int32)
        out["labels"] = ((B, S), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    return {k: jax.ShapeDtypeStruct(s, d)
            for k, (s, d) in batch_shapes(cfg, shape).items()}


def make_batch(cfg: ModelConfig, shape: InputShape, seed: int = 0) -> dict:
    """Concrete deterministic batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (s, d) in batch_shapes(cfg, shape).items():
        if jnp.dtype(d) == jnp.int32:
            hi = cfg.vocab_size if k in ("tokens", "labels") else 2
            out[k] = jnp.asarray(rng.integers(0, hi, size=s, dtype=np.int64),
                                 jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s), d)
    return out


class SyntheticDataset:
    """Deterministic, restartable token stream.

    `state()`/`restore()` give the exact RNG position — this is the "RNG
    state" the paper's snapshots must capture for bit-exact resume.
    """

    def __init__(self, cfg: ModelConfig, shape: InputShape, seed: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self._step = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self._step}

    def restore(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self._step = int(state["step"])

    def __next__(self) -> dict:
        batch = make_batch(self.cfg, self.shape,
                           seed=hash((self.seed, self._step)) % (2 ** 31))
        self._step += 1
        return batch

    def __iter__(self):
        return self
