"""REFT-JAX: reliable & efficient in-memory fault tolerance for
hybrid-parallel training — production-grade JAX reproduction.

Subpackages: api (unified checkpointing facade), core (the paper), models,
configs, optim, data, dist, ckpt, kernels (Pallas TPU), launch, plus
tests/ benchmarks/ examples/ at the repo root. See README.md / DESIGN.md /
EXPERIMENTS.md and docs/API.md.
"""
__version__ = "1.0.0"
