"""Distributed-sharding layer: mesh-aware spec adaptation + rule tables."""
from repro.dist.api import adapt_spec, shard, use_mesh

__all__ = ["adapt_spec", "shard", "use_mesh"]
