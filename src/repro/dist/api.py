"""Mesh-aware sharding primitives.

`shard(x, spec)` is the single annotation primitive the model code uses:
inside a mesh context it lowers to `with_sharding_constraint` after
adapting the spec to the axes the active mesh actually has; outside any
mesh (CPU smoke runs, the REFT training driver) it is the identity, so
the same model code runs everywhere.

`adapt_spec` implements the adaptation rules the dry-run relies on:
  * axis names the mesh does not have are dropped;
  * an axis (or tuple prefix) only survives if its cumulative size divides
    the corresponding array dimension — GSPMD requires even sharding.

Works on both modern jax (`jax.set_mesh` / abstract meshes) and the
legacy 0.4.x global-mesh context (`with mesh:`).
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _active_mesh():
    """The mesh of the enclosing mesh context, or None outside any."""
    try:                                     # modern jax: jax.set_mesh(...)
        from jax.sharding import get_abstract_mesh
        m = get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except ImportError:
        pass
    try:                                     # legacy jax: `with mesh:`
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except (ImportError, AttributeError):
        pass
    return None


def use_mesh(mesh):
    """Version-portable mesh context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh                              # legacy Mesh is a context manager


def adapt_spec(spec: P, shape: Sequence[int], mesh) -> P:
    """Drop spec axes the mesh lacks or whose size does not divide the dim."""
    sizes = _axis_sizes(mesh)
    out = []
    for dim, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        kept, prod = [], 1
        for nm in names:
            if nm not in sizes:
                continue                     # axis not on this mesh
            if shape[dim] % (prod * sizes[nm]) != 0:
                break                        # longest dividing prefix only
            kept.append(nm)
            prod *= sizes[nm]
        if not kept:
            out.append(None)
        elif isinstance(entry, tuple):
            out.append(tuple(kept))
        else:
            out.append(kept[0])
    return P(*out)


def shard(x: Any, spec: P) -> Any:
    """Constrain `x` to `spec` on the active mesh (identity without one)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    sp = adapt_spec(spec, x.shape, mesh)
    if all(e is None for e in sp):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, sp))
