"""Sharding rule tables: PartitionSpecs for params / state / batch / cache.

Rules are name-based over the last key of each leaf path, expressed as a
*tail* spec over the leaf's trailing dims — the scanned layer stack adds a
leading periods dim that is always replicated, and `_pad` aligns the tail
to the leaf's rank.  `adapt_spec` later drops anything the concrete mesh
cannot honour (missing axes, non-dividing dims), so the table can be
written against the ideal production mesh.

Megatron-style tensor parallelism over "model": column-parallel input
projections shard their fan-out dim, row-parallel output projections their
fan-in dim.  Batch dims shard over ("pod", "data").
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.api import adapt_spec

# name -> spec over the leaf's trailing dims (rank-2/3 tails)
_PARAM_TAILS: Dict[str, tuple] = {
    # attention: qkv column-parallel, output row-parallel
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "wo": ("model", None),
    # dense / MoE FFN (moe adds a leading experts dim via _pad)
    "wi_gate": (None, "model"), "wi_up": (None, "model"),
    # SSM: fused in_proj is row-sharded on d_model, out_proj on d_inner
    "in_proj": ("model", None), "out_proj": ("model", None),
    "conv_w": (None, "model"),
    # embeddings / heads: shard the d_model dim (always 16-divisible)
    "embed": (None, "model"), "lm_head": ("model", None),
    "proj_in": (None, "model"),
}

_BATCH_AXES = ("pod", "data")


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _pad(tail: tuple, ndim: int) -> P:
    """Right-align a tail spec inside an ndim-rank leaf (leading dims —
    scan periods, expert stacks — stay replicated)."""
    if ndim < len(tail):
        return P(*tail[len(tail) - ndim:])
    return P(*((None,) * (ndim - len(tail)) + tail))


def param_specs(cfg, shapes) -> Any:
    """PartitionSpec pytree matching the params pytree (leaf-for-leaf)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = []
    for path, leaf in flat:
        tail = _PARAM_TAILS.get(_leaf_name(path))
        nd = len(leaf.shape)
        specs.append(_pad(tail, nd) if tail and nd else P())
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_specs(cfg, state) -> dict:
    """Specs for the full train state; optimizer moments mirror params."""
    p = param_specs(cfg, state["params"])
    return {
        "params": p,
        "opt_state": {"mu": p, "nu": p, "step": P()},
        "step": P(),
        "rng": P(),
    }


def batch_specs(cfg, batch) -> dict:
    """Inputs shard their leading (global batch) dim over ("pod","data")."""
    return {k: P(_BATCH_AXES, *((None,) * (len(v.shape) - 1)))
            if len(v.shape) else P()
            for k, v in batch.items()}


def cache_specs(cfg, cache, global_batch: int, mesh) -> Any:
    """Decode caches shard their batch dim; everything else replicates."""
    def spec(leaf):
        sh = leaf.shape
        if len(sh) >= 2 and sh[1] == global_batch:      # (periods, B, ...)
            return P(None, _BATCH_AXES, *((None,) * (len(sh) - 2)))
        if len(sh) >= 1 and sh[0] == global_batch:
            return P(_BATCH_AXES, *((None,) * (len(sh) - 1)))
        return P()
    return jax.tree.map(spec, cache)


def named(specs, shapes, mesh) -> Any:
    """Spec pytree -> NamedSharding pytree, adapted to `mesh`."""
    return jax.tree.map(
        lambda sp, sh: NamedSharding(mesh, adapt_spec(sp, sh.shape, mesh)),
        specs, shapes, is_leaf=lambda x: isinstance(x, P))
