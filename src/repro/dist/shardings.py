"""Sharding rule tables: PartitionSpecs for params / state / batch / cache.

Rules are name-based over the last key of each leaf path, expressed as a
*tail* spec over the leaf's trailing dims — the scanned layer stack adds a
leading periods dim that is always replicated, and `_pad` aligns the tail
to the leaf's rank.  `adapt_spec` later drops anything the concrete mesh
cannot honour (missing axes, non-dividing dims), so the table can be
written against the ideal production mesh.

Megatron-style tensor parallelism over "model": column-parallel input
projections shard their fan-out dim, row-parallel output projections their
fan-in dim.  Batch dims shard over ("pod", "data").

Two opt-in rule tables compose on top:
  * FSDP (`cfg.fsdp`): every table-ruled param additionally shards one
    replicated trailing dim over the "data" axis (ZeRO-3 style weight
    sharding — the batch axes double as the weight-shard axes);
  * expert parallelism (`cfg.moe_ep`): stacked MoE expert leaves
    (`wi_gate`/`wi_up`/`wo` with a leading experts dim) shard experts
    over "model" and, under FSDP, their fan-in dim over the batch axes —
    matching `repro.models.moe.moe_ffn_ep`'s `w_spec` exactly, so the
    shard_map path consumes the params without a relayout.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.api import adapt_spec

# name -> spec over the leaf's trailing dims (rank-2/3 tails)
_PARAM_TAILS: Dict[str, tuple] = {
    # attention: qkv column-parallel, output row-parallel
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "wo": ("model", None),
    # dense / MoE FFN (moe adds a leading experts dim via _pad)
    "wi_gate": (None, "model"), "wi_up": (None, "model"),
    # SSM: fused in_proj is row-sharded on d_model, out_proj on d_inner
    "in_proj": ("model", None), "out_proj": ("model", None),
    "conv_w": (None, "model"),
    # embeddings / heads: shard the d_model dim (always 16-divisible)
    "embed": (None, "model"), "lm_head": ("model", None),
    "proj_in": (None, "model"),
}

_BATCH_AXES = ("pod", "data")

# stacked expert leaves (leading dim = num_experts) under cfg.moe_ep
_EP_LEAVES = ("wi_gate", "wi_up", "wo")


def _with_fsdp(tail: tuple, axis) -> tuple:
    """FSDP rule: shard the first replicated dim of the tail over the
    data axis (the tensor-parallel dim keeps "model")."""
    out = list(tail)
    for i, e in enumerate(out):
        if e is None:
            out[i] = axis
            return tuple(out)
    return tail


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _pad(tail: tuple, ndim: int) -> P:
    """Right-align a tail spec inside an ndim-rank leaf (leading dims —
    scan periods, expert stacks — stay replicated)."""
    if ndim < len(tail):
        return P(*tail[len(tail) - ndim:])
    return P(*((None,) * (ndim - len(tail)) + tail))


def param_specs(cfg, shapes) -> Any:
    """PartitionSpec pytree matching the params pytree (leaf-for-leaf)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    ep = bool(getattr(cfg, "moe_ep", False))
    n_exp = int(getattr(cfg, "num_experts", 0) or 0)
    fsdp = _BATCH_AXES if getattr(cfg, "fsdp", False) else None
    specs = []
    for path, leaf in flat:
        name = _leaf_name(path)
        nd = len(leaf.shape)
        if (ep and n_exp > 1 and name in _EP_LEAVES and nd >= 3
                and leaf.shape[nd - 3] == n_exp):
            # stacked expert leaf (E, fan-in, fan-out): experts over
            # "model", fan-in over the data axes under FSDP — the exact
            # w_spec `moe_ffn_ep`'s shard_map consumes
            specs.append(_pad(("model", fsdp, None), nd))
            continue
        tail = _PARAM_TAILS.get(name)
        if not (tail and nd):
            specs.append(P())
            continue
        if fsdp:
            tail = _with_fsdp(tail, fsdp)
        specs.append(_pad(tail, nd))
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_specs(cfg, state) -> dict:
    """Specs for the full train state; optimizer moments mirror params."""
    p = param_specs(cfg, state["params"])
    return {
        "params": p,
        "opt_state": {"mu": p, "nu": p, "step": P()},
        "step": P(),
        "rng": P(),
    }


def batch_specs(cfg, batch) -> dict:
    """Inputs shard their leading (global batch) dim over ("pod","data")."""
    return {k: P(_BATCH_AXES, *((None,) * (len(v.shape) - 1)))
            if len(v.shape) else P()
            for k, v in batch.items()}


def cache_specs(cfg, cache, global_batch: int, mesh) -> Any:
    """Decode caches shard their batch dim; everything else replicates."""
    def spec(leaf):
        sh = leaf.shape
        if len(sh) >= 2 and sh[1] == global_batch:      # (periods, B, ...)
            return P(None, _BATCH_AXES, *((None,) * (len(sh) - 2)))
        if len(sh) >= 1 and sh[0] == global_batch:
            return P(_BATCH_AXES, *((None,) * (len(sh) - 1)))
        return P()
    return jax.tree.map(spec, cache)


def named(specs, shapes, mesh) -> Any:
    """Spec pytree -> NamedSharding pytree, adapted to `mesh`."""
    return jax.tree.map(
        lambda sp, sh: NamedSharding(mesh, adapt_spec(sp, sh.shape, mesh)),
        specs, shapes, is_leaf=lambda x: isinstance(x, P))
