"""LocalCluster — a real-process simulation of one sharding group.

Spawns one OS process per "node"; each node runs a deterministic trainer
loop with a real SnapshotEngine (whose SMP is a further child process).
Fault injection is real: software failure = SIGKILL the trainer (orphaning
its SMP, which survives and keeps the shared-memory snapshot); node failure
= SIGKILL trainer + SMP and unlink the node's segments.

The trainer state evolves by an exact integer-friendly update so recovery
can be asserted *bit-exact* against the independently recomputed state.
"""
from __future__ import annotations

import os
import signal
import time
from dataclasses import dataclass
from multiprocessing import get_context
from typing import Dict, List, Optional

import numpy as np

from repro.core.smp import ReadOnlyNode
from repro.core.snapshot import ReftConfig, SnapshotEngine
from repro.core.treebytes import make_flat_spec

_MP = get_context("spawn")


def make_state(seed: int, nbytes_approx: int = 1 << 16) -> dict:
    """Deterministic initial trainer state (numpy pytree)."""
    rng = np.random.default_rng(seed)
    n = max(64, nbytes_approx // 16)
    return {
        "params": {"w": rng.standard_normal(n).astype(np.float32),
                   "b": rng.standard_normal(n // 4).astype(np.float32)},
        "opt": {"mu": np.zeros(n, np.float32),
                "nu": np.zeros(n // 4, np.float64)},
        "step": np.int64(0),
        "rng_state": rng.integers(0, 2 ** 31, size=4).astype(np.int64),
    }


def update_state(state: dict, step: int) -> dict:
    """Exact, reproducible pseudo-training update."""
    return {
        "params": {"w": state["params"]["w"] + np.float32(step),
                   "b": state["params"]["b"] * np.float32(-1.0)},
        "opt": {"mu": state["opt"]["mu"] + np.float32(1.0),
                "nu": state["opt"]["nu"] + np.float64(step) * 0.5},
        "step": np.int64(step),
        "rng_state": state["rng_state"] ^ np.int64(step),
    }


def state_at(seed: int, step: int, nbytes_approx: int = 1 << 16) -> dict:
    s = make_state(seed, nbytes_approx)
    for t in range(1, step + 1):
        s = update_state(s, t)
    return s


def _node_main(conn, node: int, n: int, run: str, seed: int,
               nbytes: int, max_steps: int, snapshot_every: int,
               step_time: float, ckpt_dir: str, bucket_bytes: int,
               start_state_blob):
    import pickle
    state = (pickle.loads(start_state_blob) if start_state_blob
             else make_state(seed, nbytes))
    start = int(state["step"])
    cfg = ReftConfig(bucket_bytes=bucket_bytes, ckpt_dir=ckpt_dir,
                     checkpoint_every_snapshots=10 ** 9)
    engine = SnapshotEngine(node, n, state, cfg, run_id=run)
    # analyze: ok ANZ003 — lockstep sim: one thread per pipe end
    conn.send(("smp_pid", engine.smp.proc.pid))
    step = start
    try:
        while True:
            # Lockstep: the coordinator's "go" plays the role of the
            # synchronous all-reduce barrier of DP training.
            cmd = conn.recv()
            if cmd == "ckpt":
                path = os.path.join(
                    ckpt_dir,
                    f"step-{engine.last_clean_step}-node-{node}.reft")
                engine.persist(path)
                conn.send(("ckpted",  # analyze: ok ANZ003 — lockstep
                           engine.last_clean_step))
                continue
            if cmd == "stats":
                conn.send(("stats", engine.stats))  # analyze: ok ANZ003 — lockstep
                continue
            if cmd == "stop":
                break
            assert cmd == "go", cmd
            step += 1
            state = update_state(state, step)
            if step_time:
                # analyze: ok ANZ007 — simulated fwd+bwd compute time
                time.sleep(step_time)
            if step % snapshot_every == 0:
                engine.snapshot_sync(state, step,
                                     extra_meta={"seed": seed})
            conn.send(("at", step))  # analyze: ok ANZ003 — lockstep
    finally:
        engine.close()


@dataclass
class NodeProc:
    proc: object
    conn: object
    smp_pid: Optional[int] = None
    last_step: int = 0
    last_ckpt: int = -1
    alive: bool = True


class LocalCluster:
    """One SG of `n` node processes on this host."""

    def __init__(self, n: int, *, seed: int = 0, nbytes: int = 1 << 16,
                 max_steps: int = 10 ** 6, snapshot_every: int = 1,
                 step_time: float = 0.0, ckpt_dir: str = "/tmp/reft-ckpt",
                 bucket_bytes: int = 1 << 20, run_id: str = None,
                 spec=None):
        import uuid
        if spec is not None:                  # repro.api.CheckpointSpec
            if spec.backend != "reft":
                raise ValueError(
                    f"LocalCluster simulates the REFT stack (SMP processes "
                    f"+ RAIM5); got spec.backend={spec.backend!r}")
            ckpt_dir = spec.ckpt_dir
            bucket_bytes = spec.bucket_bytes
            snapshot_every = spec.snapshot_every_steps
            run_id = run_id or spec.run_id
        self.n, self.seed, self.nbytes = n, seed, nbytes
        self.run = run_id or uuid.uuid4().hex[:8]
        self.ckpt_dir = ckpt_dir
        os.makedirs(ckpt_dir, exist_ok=True)
        self.template = make_state(seed, nbytes)
        self.total_bytes = make_flat_spec(self.template).total_bytes
        self.last_load_stats = None           # LoadStats of the last recover
        self.nodes: Dict[int, NodeProc] = {}
        self._args = dict(n=n, run=self.run, seed=seed, nbytes=nbytes,
                          max_steps=max_steps, snapshot_every=snapshot_every,
                          step_time=step_time, ckpt_dir=ckpt_dir,
                          bucket_bytes=bucket_bytes)
        for i in range(n):
            self._spawn(i)

    def _spawn(self, node: int, start_state_blob=None):
        import pickle
        parent, child = _MP.Pipe()
        a = self._args
        p = _MP.Process(target=_node_main,
                        args=(child, node, a["n"], a["run"], a["seed"],
                              a["nbytes"], a["max_steps"],
                              a["snapshot_every"], a["step_time"],
                              a["ckpt_dir"], a["bucket_bytes"],
                              start_state_blob),
                        name=f"trainer-{self.run}-n{node}")
        p.start()
        child.close()
        np_ = NodeProc(proc=p, conn=parent)
        self.nodes[node] = np_

    # ---------------------------------------------------------- control
    def pump(self, node: int, timeout: float = 0.0):
        """Drain progress messages from a node."""
        np_ = self.nodes[node]
        while np_.conn.poll(timeout):
            msg = np_.conn.recv()
            if msg[0] == "smp_pid":
                np_.smp_pid = msg[1]
            elif msg[0] == "at":
                np_.last_step = msg[1]
            elif msg[0] == "done":
                np_.last_step = msg[1]
            elif msg[0] == "ckpted":
                np_.last_ckpt = msg[1]
            timeout = 0.0

    def run_rounds(self, rounds: int, timeout: float = 120.0):
        """Drive `rounds` synchronous steps across all alive nodes."""
        for _ in range(rounds):
            alive = [i for i, np_ in self.nodes.items() if np_.alive]
            target = {i: self.nodes[i].last_step + 1 for i in alive}
            for i in alive:
                self.nodes[i].conn.send("go")
            t0 = time.time()
            pending = set(alive)
            while pending:
                if time.time() - t0 > timeout:
                    raise TimeoutError("round did not complete")
                for i in list(pending):
                    self.pump(i, 0.01)
                    if self.nodes[i].last_step >= target[i]:
                        pending.discard(i)

    def kill_trainer(self, node: int):
        """Software failure: trainer dies, SMP survives (orphaned)."""
        np_ = self.nodes[node]
        self.pump(node)
        os.kill(np_.proc.pid, signal.SIGKILL)
        np_.proc.join()
        np_.alive = False

    def kill_node(self, node: int):
        """Hardware failure: trainer + SMP die, volatile memory wiped."""
        np_ = self.nodes[node]
        self.pump(node)
        os.kill(np_.proc.pid, signal.SIGKILL)
        np_.proc.join()
        if np_.smp_pid:
            try:
                os.kill(np_.smp_pid, signal.SIGKILL)
            except ProcessLookupError:
                pass
        ReadOnlyNode.unlink_node(self.run, node)
        np_.alive = False

    def checkpoint(self, timeout: float = 60.0):
        """Ask every alive trainer's SMP to persist (REFT-Ckpt)."""
        for i, np_ in self.nodes.items():
            if np_.alive:
                np_.conn.send("ckpt")  # analyze: ok ANZ003 — coordinator is single-threaded
        t0 = time.time()
        while time.time() - t0 < timeout:
            if all(np_.last_ckpt >= 0 for np_ in self.nodes.values()
                   if np_.alive):
                return
            for i, np_ in self.nodes.items():
                if np_.alive:
                    self.pump(i, 0.01)
        raise TimeoutError("checkpoint acks missing")

    def kill_smp(self, node: int):
        """SMP-only crash (trainer keeps running; snapshots degrade)."""
        np_ = self.nodes[node]
        if np_.smp_pid:
            os.kill(np_.smp_pid, signal.SIGKILL)

    # --------------------------------------------------------- recovery
    def recover(self, target=None):
        """3-tier recovery via the shared ladder. (state, step, tier).
        The per-phase `LoadStats` land on `self.last_load_stats`."""
        from repro.api.backends import reft_recovery_ladder
        res = reft_recovery_ladder(self.run, self.n, self.total_bytes,
                                   self.template, list(range(self.n)),
                                   self.ckpt_dir, target=target)
        self.last_load_stats = res.load
        return res.state, res.step, res.tier

    def restart_node(self, node: int, state: dict):
        """Elastic replacement node resumes from the recovered state."""
        import pickle
        self._cleanup_node_procs(node)
        self._spawn(node, start_state_blob=pickle.dumps(state))

    def _cleanup_node_procs(self, node: int):
        np_ = self.nodes.get(node)
        if np_ is None:
            return
        if np_.proc.is_alive():
            os.kill(np_.proc.pid, signal.SIGKILL)
            np_.proc.join()
        if np_.smp_pid:
            try:
                os.kill(np_.smp_pid, signal.SIGKILL)
            except ProcessLookupError:
                pass
        ReadOnlyNode.unlink_node(self.run, node)

    def expected_state(self, step: int) -> dict:
        return state_at(self.seed, step, self.nbytes)

    def close(self):
        for i in list(self.nodes):
            self._cleanup_node_procs(i)
