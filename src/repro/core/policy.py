"""Reliability model and optimal-frequency policy (paper §5 + Appendix A).

Implements:
  Eq. 1   Weibull single-node survival        P = exp(-lam * t^c)
  Eq. 2   REFT survival (<=1 node loss / SG)  P_re_survive
  Eq. 3   checkpoint-only survival            P_ck_survive
  Eq. 5   classic optimal interval            T = sqrt(2 O_save / lam)
  Eq. 7   REFT unrecoverable-failure rate     lam_re_fail
  Eq. 8   effective saving overhead           O_save = relu(T_ft - T_comp)
  Eq. 9-11 optimal snapshot/checkpoint intervals
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def weibull_survival(lam: float, t: float, c: float = 1.0) -> float:
    """Eq. 1: cumulative survival probability of one node at time t."""
    return math.exp(-lam * (t ** c))


def reft_survival(k: int, n: int, t: float, *, lam_hw: float,
                  lam_smp: float = 0.0, c: float = 1.0) -> float:
    """Eq. 2: parameters survive iff every SG of n nodes has <=1 hardware
    failure and all SMPs are healthy. k = total nodes, k/n SGs."""
    assert k % n == 0, "k must be a multiple of the SG size"
    ps = weibull_survival(lam_hw, t, c)
    p_sg = ps ** n + n * (1.0 - ps) * ps ** (n - 1)
    p_smp = weibull_survival(lam_smp, t, c) ** k
    return (p_sg ** (k // n)) * p_smp


def ckpt_survival(k: int, t: float, *, lam_hw: float, lam_sw: float,
                  c: float = 1.0) -> float:
    """Eq. 3: without REFT, in-memory parameters survive only if every node
    survives both hardware and software failures."""
    ps = weibull_survival(lam_hw, t, c)
    ptr = weibull_survival(lam_sw, t, c)
    return (ps ** k) * (ptr ** k)


def safe_horizon(survive_fn, threshold: float = 0.9,
                 t_max: float = 1e5) -> float:
    """Largest t (bisection) with survive_fn(t) >= threshold (Fig. 8's
    '16.22 days vs 0.5 days' numbers)."""
    lo, hi = 0.0, t_max
    if survive_fn(hi) >= threshold:
        return hi
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if survive_fn(mid) >= threshold:
            lo = mid
        else:
            hi = mid
    return lo


def reft_fail_rate(lam_node: float, n: int) -> float:
    """Eq. 7: rate of >=2 failures within an SG of n nodes (the only event
    that forces a restart from a persisted checkpoint)."""
    p = lam_node
    return 1.0 - (1.0 - p) ** n - n * p * (1.0 - p) ** (n - 1)


def effective_save_overhead(t_ft: float, t_comp: float) -> float:
    """Eq. 8: only the part of the fault-tolerance time not hidden behind
    compute counts: O = 0.5 (|T_ft - T_comp| + T_ft - T_comp) = relu(.)"""
    return 0.5 * (abs(t_ft - t_comp) + t_ft - t_comp)


def optimal_interval(o_save: float, lam_fail: float) -> float:
    """Eq. 5: T = sqrt(2 O_save / lambda). O_save==0 -> snapshot every step
    (interval 0 means 'as often as possible')."""
    if lam_fail <= 0:
        return math.inf
    return math.sqrt(2.0 * max(o_save, 0.0) / lam_fail)


@dataclass(frozen=True)
class FrequencyPlan:
    snapshot_interval: float      # seconds between REFT-Sn snapshots
    checkpoint_interval: float    # seconds between REFT-Ckpt persists
    o_snapshot: float
    o_checkpoint: float
    lam_node: float
    lam_unrecoverable: float


def plan_frequencies(*, t_snapshot: float, t_checkpoint: float,
                     t_comp: float, lam_node: float, n: int
                     ) -> FrequencyPlan:
    """Appendix A, Eqs. 9-11: snapshot interval against single-node failures
    (REFT-Sn repairs those); checkpoint interval against the rare >=2-per-SG
    event (Eq. 7)."""
    o_sn = effective_save_overhead(t_snapshot, t_comp)
    o_ck = effective_save_overhead(t_checkpoint, t_comp)
    lam_un = reft_fail_rate(lam_node, n)
    return FrequencyPlan(
        snapshot_interval=optimal_interval(o_sn, lam_node),
        checkpoint_interval=optimal_interval(o_sn, lam_un),
        o_snapshot=o_sn,
        o_checkpoint=o_ck,
        lam_node=lam_node,
        lam_unrecoverable=lam_un,
    )


def total_overhead(t_total: float, t_save_interval: float, o_save: float,
                   lam_fail: float, t_sch: float = 0.0,
                   t_load: float = 0.0) -> float:
    """Eq. 4: O_total = O_save * T/T_save + O_restart * T * lambda, where
    O_restart = T_save/2 (average lost recomputation) + T_sch + T_load."""
    if t_save_interval <= 0:
        return math.inf
    o_restart = t_save_interval / 2.0 + t_sch + t_load
    return (o_save * t_total / t_save_interval
            + o_restart * t_total * lam_fail)
