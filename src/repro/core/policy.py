"""Reliability model and optimal-frequency policy (paper §5 + Appendix A).

Implements:
  Eq. 1   Weibull single-node survival        P = exp(-lam * t^c)
  Eq. 2   REFT survival (<=1 node loss / SG)  P_re_survive
  Eq. 3   checkpoint-only survival            P_ck_survive
  Eq. 5   classic optimal interval            T = sqrt(2 O_save / lam)
  Eq. 7   REFT unrecoverable-failure rate     lam_re_fail
  Eq. 8   effective saving overhead           O_save = relu(T_ft - T_comp)
  Eq. 9-11 optimal snapshot/checkpoint intervals
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field


def weibull_survival(lam: float, t: float, c: float = 1.0) -> float:
    """Eq. 1: cumulative survival probability of one node at time t."""
    return math.exp(-lam * (t ** c))


def reft_survival(k: int, n: int, t: float, *, lam_hw: float,
                  lam_smp: float = 0.0, c: float = 1.0) -> float:
    """Eq. 2: parameters survive iff every SG of n nodes has <=1 hardware
    failure and all SMPs are healthy. k = total nodes, k/n SGs."""
    assert k % n == 0, "k must be a multiple of the SG size"
    ps = weibull_survival(lam_hw, t, c)
    p_sg = ps ** n + n * (1.0 - ps) * ps ** (n - 1)
    p_smp = weibull_survival(lam_smp, t, c) ** k
    return (p_sg ** (k // n)) * p_smp


def ckpt_survival(k: int, t: float, *, lam_hw: float, lam_sw: float,
                  c: float = 1.0) -> float:
    """Eq. 3: without REFT, in-memory parameters survive only if every node
    survives both hardware and software failures."""
    ps = weibull_survival(lam_hw, t, c)
    ptr = weibull_survival(lam_sw, t, c)
    return (ps ** k) * (ptr ** k)


def safe_horizon(survive_fn, threshold: float = 0.9,
                 t_max: float = 1e5) -> float:
    """Largest t (bisection) with survive_fn(t) >= threshold (Fig. 8's
    '16.22 days vs 0.5 days' numbers)."""
    lo, hi = 0.0, t_max
    if survive_fn(hi) >= threshold:
        return hi
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if survive_fn(mid) >= threshold:
            lo = mid
        else:
            hi = mid
    return lo


def reft_fail_rate(lam_node: float, n: int) -> float:
    """Eq. 7: rate of >=2 failures within an SG of n nodes (the only event
    that forces a restart from a persisted checkpoint)."""
    p = lam_node
    return 1.0 - (1.0 - p) ** n - n * p * (1.0 - p) ** (n - 1)


def effective_save_overhead(t_ft: float, t_comp: float) -> float:
    """Eq. 8: only the part of the fault-tolerance time not hidden behind
    compute counts: O = 0.5 (|T_ft - T_comp| + T_ft - T_comp) = relu(.)"""
    return 0.5 * (abs(t_ft - t_comp) + t_ft - t_comp)


def optimal_interval(o_save: float, lam_fail: float) -> float:
    """Eq. 5: T = sqrt(2 O_save / lambda). O_save==0 -> snapshot every step
    (interval 0 means 'as often as possible')."""
    if lam_fail <= 0:
        return math.inf
    return math.sqrt(2.0 * max(o_save, 0.0) / lam_fail)


@dataclass(frozen=True)
class FrequencyPlan:
    snapshot_interval: float      # seconds between REFT-Sn snapshots
    checkpoint_interval: float    # seconds between REFT-Ckpt persists
    o_snapshot: float
    o_checkpoint: float
    lam_node: float
    lam_unrecoverable: float


def failure_load_rate(lam: float, t_restore: float) -> float:
    """Failure rate per *useful* second.  Each failure burns ~t_restore
    seconds of wall clock that produce no progress, so per useful second
    failures arrive faster than per wall second: lam / (1 - lam*t_restore).
    Clamped so a pathological restore cost cannot send the rate negative
    or unbounded."""
    if lam <= 0:
        return lam
    return lam / max(1.0 - lam * t_restore, 0.05)


def plan_frequencies(*, t_snapshot: float, t_checkpoint: float,
                     t_comp: float, lam_node: float, n: int,
                     t_restore_snapshot: float = 0.0,
                     t_restore_checkpoint: float = 0.0) -> FrequencyPlan:
    """Appendix A, Eqs. 9-11: snapshot interval against single-node failures
    (REFT-Sn repairs those); checkpoint interval against the rare >=2-per-SG
    event (Eq. 7).

    `t_restore_*` fold observed per-tier restore costs (LoadStats read +
    decode + h2d seconds) into the plan: restore time is pure badput, so the
    effective failure rate per useful second rises with it and the optimal
    interval shrinks accordingly."""
    o_sn = effective_save_overhead(t_snapshot, t_comp)
    o_ck = effective_save_overhead(t_checkpoint, t_comp)
    lam_sn = failure_load_rate(lam_node, t_restore_snapshot)
    lam_un = failure_load_rate(reft_fail_rate(lam_node, n),
                               t_restore_checkpoint)
    return FrequencyPlan(
        snapshot_interval=optimal_interval(o_sn, lam_sn),
        checkpoint_interval=optimal_interval(o_ck, lam_un),
        o_snapshot=o_sn,
        o_checkpoint=o_ck,
        lam_node=lam_sn,
        lam_unrecoverable=lam_un,
    )


# Tiers whose restore reads live shm (cheap, snapshot-class) vs tiers that
# hit durable media (expensive, checkpoint-class).  Used to bucket observed
# LoadStats when feeding restore costs back into plan_frequencies.
SNAPSHOT_TIERS = frozenset({"in-memory", "raim5"})


@dataclass
class FailureObserver:
    """Online MTBF + restore-cost estimator feeding plan_frequencies.

    Failure arrivals are modelled as Poisson with a Gamma(w, w/prior)
    conjugate prior, so the posterior rate after observing k failures over
    T node-seconds is (k + w) / (T*n + w/prior): with no evidence it
    returns the static prior (spec.lam_node), and each observed failure
    pulls it toward the measured rate.  `weight` is the prior's
    pseudo-failure count — higher means slower to move off the prior.

    Restore costs are bucketed by recovery tier into snapshot-class
    (in-memory / raim5: shm reads) and checkpoint-class (disk / object
    store) and averaged over the most recent `window` observations.
    """
    weight: float = 2.0
    window: int = 16
    clock: object = time.monotonic       # injectable for tests
    failures: list = field(default_factory=list)     # timestamps
    restores: dict = field(default_factory=lambda: {"snapshot": [],
                                                    "checkpoint": []})
    # learned per-source effective bandwidth (bytes/s) keyed "kind:node",
    # harvested from each restore's LoadStats; seeds the next restore's
    # read-scheduler EWMA priors so a known-slow source starts slow
    source_bw: dict = field(default_factory=dict)
    _t0: float = None

    def __post_init__(self):
        if self._t0 is None:
            self._t0 = self.clock()

    def record_failure(self, when: float = None) -> None:
        self.failures.append(self.clock() if when is None else when)

    def record_restore(self, seconds: float, tier: str = "in-memory",
                       load=None) -> None:
        """Log one restore's cost.  `load` (a LoadStats) refines the
        wall-clock `seconds` with per-phase read/decode/h2d attribution
        when available.  Read and decode are span-based and may overlap
        (pipelined decode), so the phased total subtracts the measured
        intersection instead of double-counting it."""
        if load is not None:
            phased = (getattr(load, "read_seconds", 0.0)
                      + getattr(load, "decode_seconds", 0.0)
                      - getattr(load, "overlap_seconds", 0.0)
                      + getattr(load, "h2d_seconds", 0.0))
            seconds = max(seconds, phased)
            for key, bw in (getattr(load, "source_bandwidth", None)
                            or {}).items():
                self.record_source_bw(key, bw)
        cls = "snapshot" if tier in SNAPSHOT_TIERS else "checkpoint"
        bucket = self.restores[cls]
        bucket.append(float(seconds))
        del bucket[:-self.window]

    def record_source_bw(self, key: str, bw: float) -> None:
        """Blend one observed effective bandwidth (bytes/s) for a restore
        source into the cross-restore estimate (equal-weight EWMA)."""
        if bw is None or bw <= 0:
            return
        prev = self.source_bw.get(key)
        self.source_bw[key] = bw if prev is None else 0.5 * prev + 0.5 * bw

    def observed_span(self) -> float:
        return max(self.clock() - self._t0, 1e-9)

    def lam_node(self, prior: float, n: int = 1) -> float:
        """Posterior per-node failure rate (per second)."""
        prior = max(prior, 1e-12)
        k = len(self.failures)
        t_node = self.observed_span() * max(n, 1)
        return (k + self.weight) / (t_node + self.weight / prior)

    def restore_cost(self, cls: str) -> float:
        bucket = self.restores.get(cls, ())
        return sum(bucket) / len(bucket) if bucket else 0.0

    def mtbf(self) -> float:
        """Observed mean time between failures (inf when none seen)."""
        if not self.failures:
            return math.inf
        return self.observed_span() / len(self.failures)


def total_overhead(t_total: float, t_save_interval: float, o_save: float,
                   lam_fail: float, t_sch: float = 0.0,
                   t_load: float = 0.0) -> float:
    """Eq. 4: O_total = O_save * T/T_save + O_restart * T * lambda, where
    O_restart = T_save/2 (average lost recomputation) + T_sch + T_load."""
    if t_save_interval <= 0:
        return math.inf
    o_restart = t_save_interval / 2.0 + t_sch + t_load
    return (o_save * t_total / t_save_interval
            + o_restart * t_total * lam_fail)
