"""Sharded & parallel asynchronous snapshotting (paper §4.1).

Each SG member snapshots (a) its own 1/n byte-shard of the train state and
(b) the blocks of its parity stripe (XOR-folded in the SMP), in tiny
buckets, asynchronously with training.

JAX adaptation note (DESIGN.md §2): jax.Arrays are immutable, so holding a
reference to the step-t state pins a consistent snapshot for free — no
GPU-side tensor duplication is needed before the async d2h copy, unlike the
PyTorch original.  The async thread transfers leaf-by-leaf (device_get),
stages into shared memory, and the SMP owns everything after that.
"""
from __future__ import annotations

import bisect
import pickle
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import raim5
from repro.core.smp import NodeLayout, SMPHandle
from repro.core.treebytes import FlatSpec, leaf_arrays, make_flat_spec


@dataclass(frozen=True)
class ReftConfig:
    bucket_bytes: int = 4 << 20
    stage_slots: int = 8
    snapshot_every_steps: int = 1
    checkpoint_every_snapshots: int = 50       # REFT-Ckpt tier
    ckpt_dir: str = "/tmp/reft-ckpt"
    run_id: str = field(default_factory=lambda: uuid.uuid4().hex[:8])


class _LeafReader:
    """Random byte-range access over the flat stream with per-snapshot
    host caching (each leaf is device_get at most once per snapshot)."""

    def __init__(self, spec: FlatSpec, leaves: List[Any]):
        self.spec = spec
        self.leaves = leaves
        self.offsets = [l.offset for l in spec.leaves]
        self._host: Dict[int, np.ndarray] = {}

    def _leaf_bytes(self, i: int) -> np.ndarray:
        if i not in self._host:
            arr = np.asarray(self.leaves[i])          # d2h happens here
            self._host[i] = np.ascontiguousarray(arr).reshape(-1) \
                .view(np.uint8)
        return self._host[i]

    def read(self, lo: int, hi: int, out: np.ndarray) -> None:
        i = bisect.bisect_right(self.offsets, lo) - 1
        pos = lo
        while pos < hi and i < len(self.spec.leaves):
            ls = self.spec.leaves[i]
            a = max(pos, ls.offset)
            b = min(hi, ls.offset + ls.nbytes)
            if b > a:
                out[a - lo:b - lo] = self._leaf_bytes(i)[a - ls.offset:
                                                         b - ls.offset]
            pos = b
            i += 1
        if pos < hi:                                   # zero-pad past end
            out[pos - lo:hi - lo] = 0


class SnapshotEngine:
    """REFT-Sn for one node of an SG of n members."""

    def __init__(self, node: int, n: int, state_template: Any,
                 cfg: Optional[ReftConfig] = None, run_id: str = None):
        # NB: a `cfg=ReftConfig()` default would be evaluated once at import,
        # so every default-constructed engine would share one run_id (one
        # shm namespace) — construct a fresh config per instance instead.
        cfg = cfg if cfg is not None else ReftConfig()
        self.node, self.n, self.cfg = node, n, cfg
        self.run = run_id or cfg.run_id
        self.spec = make_flat_spec(state_template)
        self.layout = NodeLayout(n, self.spec.total_bytes)
        self.smp = SMPHandle(self.run, node, n, self.spec.total_bytes,
                             stage_slots=cfg.stage_slots,
                             bucket_bytes=cfg.bucket_bytes)
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        self.degraded = False      # SMP unreachable: snapshots paused, not fatal
        self.last_clean_step = -1
        self.stats = {"snapshots": 0, "bytes_sent": 0, "seconds": 0.0}

    # ------------------------------------------------------------- plan
    def _own_plan(self) -> List[Tuple[int, int, int]]:
        """[(dst_offset_in_own_region, lo, hi)] global byte ranges."""
        lay = self.layout
        if self.n == 1:
            return [(0, 0, self.spec.total_bytes)]
        out = []
        for li, ref in enumerate(raim5.data_blocks_of_node(self.node, self.n)):
            lo, hi = ref.byte_range(lay.bs, self.n)
            out.append((li * lay.bs, lo, hi))
        return out

    def _stripe_plan(self) -> List[Tuple[int, int]]:
        if self.n == 1:
            return []
        lay = self.layout
        return [ref.byte_range(lay.bs, self.n)
                for ref in raim5.parity_stripe_of_node(self.node, self.n)]

    # -------------------------------------------------------- snapshot
    def snapshot_async(self, state: Any, step: int,
                       extra_meta: dict = None) -> bool:
        """Fire-and-forget; returns False if the previous one is running
        (frequency self-limits to the achievable rate, Figure 4)."""
        if self.degraded or (self._thread is not None
                             and self._thread.is_alive()):
            return False
        self._raise_pending()
        leaves = leaf_arrays(state)                    # pin the references
        self._thread = threading.Thread(
            target=self._run, args=(leaves, int(step), extra_meta or {}),
            daemon=True, name=f"snap-n{self.node}")
        self._thread.start()
        return True

    def snapshot_sync(self, state: Any, step: int,
                      extra_meta: dict = None) -> int:
        if not self.snapshot_async(state, step, extra_meta):
            return self.last_clean_step        # degraded: keep training
        return self.wait()

    def wait(self, timeout: float = 300.0) -> int:
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        self._raise_pending()
        return self.last_clean_step

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            if isinstance(err, (BrokenPipeError, EOFError, ConnectionError,
                                TimeoutError, OSError)):
                # SMP process is gone: the paper's stance is that training
                # must not die with its fault-tolerance sidecar — degrade.
                self.degraded = True
                return
            raise err

    def _run(self, leaves, step, extra_meta):
        try:
            import zlib
            t0 = time.time()
            # prefetch: start async device->host copies for every leaf this
            # node will touch (on TPU this overlaps DMA with the staging
            # writes; on CPU it's a no-op)
            for leaf in leaves:
                try:
                    leaf.copy_to_host_async()
                except AttributeError:
                    pass
            reader = _LeafReader(self.spec, leaves)
            bb = self.cfg.bucket_bytes
            scratch = np.empty(bb, np.uint8)
            sent = 0
            crc = 0
            self.smp.begin(step)
            for dst0, lo, hi in self._own_plan():
                for a in range(lo, hi, bb):
                    b = min(a + bb, hi)
                    reader.read(a, b, scratch[:b - a])
                    crc = zlib.crc32(scratch[:b - a], crc)
                    self.smp.send_bucket(0, dst0 + (a - lo), scratch[:b - a])
                    sent += b - a
            for lo, hi in self._stripe_plan():
                for a in range(lo, hi, bb):
                    b = min(a + bb, hi)
                    reader.read(a, b, scratch[:b - a])
                    self.smp.send_bucket(1, a - lo, scratch[:b - a])
                    sent += b - a
            meta = {"spec": self.spec.to_json(), "step": step,
                    "extra": extra_meta, "crc_own": crc}
            self.smp.end(step, pickle.dumps(meta))
            self.last_clean_step = self.smp.wait_clean()
            self.stats["snapshots"] += 1
            self.stats["bytes_sent"] += sent
            self.stats["seconds"] += time.time() - t0
        except BaseException as e:                      # surfaced on wait()
            self._err = e

    # ------------------------------------------------------------ ckpt
    def persist(self, path: str, step: Optional[int] = None) -> str:
        """REFT-Ckpt: SMP writes its clean shard+parity to disk without
        touching the training process (a specific clean step if given)."""
        return self.smp.persist(path, step=step)

    def close(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout=30)
        self.smp.stop()
