"""Sharded & parallel asynchronous snapshotting (paper §4.1).

Each SG member snapshots (a) its own 1/n byte-shard of the train state and
(b) the blocks of its parity stripe (XOR-folded in the SMP), in tiny
buckets, asynchronously with training.

JAX adaptation note (DESIGN.md §2): jax.Arrays are immutable, so holding a
reference to the step-t state pins a consistent snapshot for free — no
GPU-side tensor duplication is needed before the async d2h copy, unlike the
PyTorch original.

`SnapshotEngine` is a thin facade: the saving hot path is the hierarchical
async pipeline in `repro.core.pipeline` (L1 device pump / L2 host stager /
L3 event-driven SMP — HASC).  ``ReftConfig(pipeline=False)`` keeps the
pre-refactor single serial thread (read -> CRC -> blocking ring send per
bucket) as a measurable baseline for the pipeline's interference win.
"""
from __future__ import annotations

import pickle
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import raim5
from repro.core.delta import DeltaLog, DeltaTracker
from repro.core.pipeline import (DeltaBaseMismatch, LeafReader,
                                 PipelineFlight, SnapshotPipeline,
                                 leaf_budget, resolve_affinity,
                                 resolve_device_encode)
from repro.core.smp import NodeLayout, SMPHandle
from repro.core.treebytes import FlatSpec, leaf_arrays, make_flat_spec

# Back-compat alias: the reader grew eviction budgets and moved into the
# pipeline module where both the pipelined and serial paths share it.
_LeafReader = LeafReader


def _trace_default() -> bool:
    import os
    return os.environ.get("REPRO_TRACE_PROTOCOL", "") not in ("", "0")


@dataclass(frozen=True)
class ReftConfig:
    bucket_bytes: int = 4 << 20
    stage_slots: int = 8
    snapshot_every_steps: int = 1
    checkpoint_every_snapshots: int = 50       # REFT-Ckpt tier
    ckpt_dir: str = "/tmp/reft-ckpt"
    run_id: str = field(default_factory=lambda: uuid.uuid4().hex[:8])
    # --- HASC pipeline knobs (repro.core.pipeline) ---
    pipeline: bool = True            # False = pre-refactor serial thread
    prefetch_window: int = 4         # buckets of copy_to_host_async ahead
    scratch_buffers: int = 2         # double-buffered L1 scratch fills
    opt_first: bool = True           # drain optimizer-moment leaves first
    yield_every_buckets: int = 4     # L1 yields to training this often
    boundary_timeout_s: float = 0.005  # max wait for a step boundary
    # --- device-side encode + multi-flight (docs/API.md) ---
    device_encode: str = "auto"      # "auto" (on iff a real accelerator
                                     # backs JAX) | "on" | "off"
    crc_impl: str = "pallas"         # device CRC: "pallas" | "jnp" fallback
    max_flights: int = 1             # >1: snapshot N+1's L1 may overlap
                                     # snapshot N's L2/L3 drain
    pin_cpus: Any = "auto"           # saving-path CPU set for the L2
                                     # stager + SMP: "auto" | "off" | ids
    # --- async REFT-Ckpt persistence (docs/API.md "Async persistence") ---
    persist_delay_s: float = 0.0     # simulated durable-tier latency per
                                     # persist (tests / interference bench)
    persist_bw_limit: float = 0.0    # token-bucket cap (bytes/s) on the
                                     # SMP's background persist + upload
                                     # writes; 0 = unlimited
    # --- dirty-delta snapshots (docs/API.md "Delta snapshots") ---
    delta: bool = False              # delta flights between full keyframes
                                     # (requires pipeline=True, max_flights=1)
    delta_keyframe: int = 8          # force a full keyframe every N flights
    delta_dirty_threshold: float = 0.6   # dirty fraction above which a
                                     # delta saves nothing -> keyframe
    delta_digest: bool = True        # per-bucket CRC compare vs the base
                                     # (off: provider ranges only)
    ranged_fetch: str = "auto"       # sparse delta flights d2h only the
                                     # touched leaf extents: "auto" (on iff
                                     # a real accelerator) | "on" | "off"
    # --- straggler-aware loading (docs/API.md "Straggler-aware loading") ---
    restore_sched: str = "adaptive"  # restore read executor: "fcfs"
                                     # (legacy one-thread-per-member) |
                                     # "steal" (chunked work-stealing) |
                                     # "adaptive" (+ parity reroute/hedges)
    restore_bw_limit: float = 0.0    # token-bucket cap (bytes/s) on all
                                     # restore reads; 0 = unlimited
                                     # (read-side twin of persist_bw_limit)
    # runtime SMP protocol validation (repro.analyze.protocol): every
    # pipe message is checked against the flight FSM; desyncs raise
    # ProtocolViolation instead of wedging a blocking recv.  Defaults to
    # the REPRO_TRACE_PROTOCOL env var so CI can turn it on fleet-wide.
    trace_protocol: bool = field(default_factory=lambda: _trace_default())


class SnapshotEngine:
    """REFT-Sn for one node of an SG of n members (facade over the HASC
    pipeline; one snapshot in flight at a time)."""

    def __init__(self, node: int, n: int, state_template: Any,
                 cfg: Optional[ReftConfig] = None, run_id: str = None):
        # NB: a `cfg=ReftConfig()` default would be evaluated once at import,
        # so every default-constructed engine would share one run_id (one
        # shm namespace) — construct a fresh config per instance instead.
        cfg = cfg if cfg is not None else ReftConfig()
        self.node, self.n, self.cfg = node, n, cfg
        self.run = run_id or cfg.run_id
        self.spec = make_flat_spec(state_template)
        self.layout = NodeLayout(n, self.spec.total_bytes)
        affinity = resolve_affinity(getattr(cfg, "pin_cpus", None))
        self.smp = SMPHandle(self.run, node, n, self.spec.total_bytes,
                             stage_slots=cfg.stage_slots,
                             bucket_bytes=cfg.bucket_bytes,
                             pin_cpus=affinity,
                             trace=cfg.trace_protocol)
        self._own = self._own_plan()
        self._stripe = self._stripe_plan()
        self._pipeline: Optional[SnapshotPipeline] = None
        if cfg.pipeline:
            self._pipeline = SnapshotPipeline(self.smp, self.spec, cfg,
                                              self._own, self._stripe)
        self._max_flights = max(1, int(getattr(cfg, "max_flights", 1))) \
            if cfg.pipeline else 1
        # dirty-delta snapshotting: only meaningful on the pipelined path
        # with a single flight in the air (a delta's base must be the
        # SMP's latest clean step, which overlap would race)
        self._tracker: Optional[DeltaTracker] = None
        self._delta_log: Optional[DeltaLog] = None
        self._dirty_provider = None
        if getattr(cfg, "delta", False) and cfg.pipeline \
                and self._max_flights == 1:
            self._tracker = DeltaTracker(
                keyframe_every=max(1, int(getattr(cfg, "delta_keyframe",
                                                  8))),
                dirty_threshold=float(getattr(cfg, "delta_dirty_threshold",
                                              0.6)),
                digest=bool(getattr(cfg, "delta_digest", True)))
            self._delta_log = DeltaLog()
        self._flight_bytes = sum(t.hi - t.lo
                                 for t in self._pipeline.schedule) \
            if self._pipeline is not None else self.spec.total_bytes
        self._flights: List[PipelineFlight] = []
        self._thread: Optional[threading.Thread] = None    # serial mode
        self._err: Optional[BaseException] = None
        self.degraded = False      # SMP unreachable: snapshots paused, not fatal
        # mutable copy of cfg.persist_delay_s: ReftConfig is frozen, but
        # fault injection (slow-persist / slow-NFS scenarios) must be able
        # to raise durable-tier latency mid-run
        self.persist_delay_s = float(getattr(cfg, "persist_delay_s", 0.0))
        self.last_clean_step = -1
        self._persists: Dict[int, dict] = {}    # seq -> in-flight record
        self.stats = {"snapshots": 0, "bytes_sent": 0, "seconds": 0.0,
                      "l1_seconds": 0.0, "l1_stall_seconds": 0.0,
                      "l2_seconds": 0.0, "l3_seconds": 0.0,
                      "overlapped_flights": 0,
                      "persists": 0, "persist_inflight": 0,
                      "persist_seconds": 0.0,
                      "persist_overlap_seconds": 0.0,
                      "persist_errors": 0,
                      "persist_throttle_seconds": 0.0,
                      "persist_upload_seconds": 0.0,
                      "persist_upload_bytes": 0,
                      "persist_upload_retries": 0,
                      "device_encode": (self._pipeline.device_encode
                                        if self._pipeline else False),
                      "stager_affinity": None,
                      "skipped_buckets": 0, "delta_flights": 0,
                      "keyframe_flights": 0, "delta_base_misses": 0}

    @property
    def _flight(self) -> Optional[PipelineFlight]:
        """Newest owned flight (back-compat accessor; multi-flight engines
        own a queue)."""
        return self._flights[-1] if self._flights else None

    # ------------------------------------------------------------- plan
    def _own_plan(self) -> List[Tuple[int, int, int]]:
        """[(dst_offset_in_own_region, lo, hi)] global byte ranges."""
        lay = self.layout
        if self.n == 1:
            return [(0, 0, self.spec.total_bytes)]
        out = []
        for li, ref in enumerate(raim5.data_blocks_of_node(self.node, self.n)):
            lo, hi = ref.byte_range(lay.bs, self.n)
            out.append((li * lay.bs, lo, hi))
        return out

    def _stripe_plan(self) -> List[Tuple[int, int]]:
        if self.n == 1:
            return []
        lay = self.layout
        return [ref.byte_range(lay.bs, self.n)
                for ref in raim5.parity_stripe_of_node(self.node, self.n)]

    # -------------------------------------------------------- snapshot
    def in_flight(self) -> bool:
        if any(f.in_flight() for f in self._flights):
            return True
        return self._thread is not None and self._thread.is_alive()

    def snapshot_async(self, state: Any, step: int,
                       extra_meta: dict = None) -> bool:
        """Fire-and-forget; returns False when no flight slot is free
        (frequency self-limits to the achievable rate, Figure 4).  With
        `max_flights > 1` a new flight may launch while its predecessor
        is still draining L2/L3 (multi-flight overlap)."""
        if self.degraded:
            return False
        if self._thread is not None and self._thread.is_alive():
            return False                       # serial mode: single flight
        self._collect_finished()
        self._raise_pending()
        if self.degraded:                  # the drain just found a dead SMP
            return False
        if len(self._flights) >= self._max_flights:
            return False
        leaves = leaf_arrays(state)                    # pin the references
        if self._pipeline is not None:
            overlapped = any(f.in_flight() for f in self._flights)
            plan = None
            if self._tracker is not None:
                ranges = None
                if self._dirty_provider is not None:
                    ranges = self._dirty_provider()
                plan = self._tracker.plan(self.last_clean_step,
                                          self._pipeline.schedule, ranges,
                                          self.spec.total_bytes)
            self._flights.append(self._pipeline.start(leaves, int(step),
                                                      extra_meta or {},
                                                      delta=plan))
            if overlapped:
                self.stats["overlapped_flights"] += 1
            return True
        self._thread = threading.Thread(
            target=self._run_serial, args=(leaves, int(step),
                                           extra_meta or {}),
            daemon=True, name=f"snap-n{self.node}")
        self._thread.start()
        return True

    def set_dirty_provider(self, fn) -> None:
        """Install the delta saving path's dirtiness signal: a callable
        returning the merged GLOBAL byte ranges that may have changed
        since the previous flight (or None for "unknown — digest-compare
        everything").  E.g. `repro.core.delta.expert_dirty_ranges` over
        the MoE router's `TOUCHED.consume()` mask.  Consumed once per
        launched flight; no-op for non-delta engines."""
        self._dirty_provider = fn

    def snapshot_sync(self, state: Any, step: int,
                      extra_meta: dict = None) -> int:
        if not self.snapshot_async(state, step, extra_meta):
            return self.last_clean_step        # degraded: keep training
        return self.wait()

    def wait(self, timeout: float = 300.0) -> int:
        """Drain every in-flight snapshot (oldest first).  On timeout the
        live flight handles are KEPT (a snapshot can never be dropped
        while live) and a `TimeoutError` is raised instead."""
        deadline = time.monotonic() + timeout
        while self._flights:
            left = max(0.0, deadline - time.monotonic())
            self._collect_flight(left)         # raises TimeoutError if live
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"serial snapshot thread still running after "
                    f"{timeout:.1f}s; still in flight")
            self._thread = None
        self._raise_pending()
        return self.last_clean_step

    def _collect_finished(self):
        """Fold every already-finished flight (oldest first) into stats
        without blocking on the live ones."""
        while self._flights and self._flights[0].done.is_set():
            self._collect_flight(0.0)

    def _collect_flight(self, timeout: float):
        """Fold the OLDEST flight into stats.  A TimeoutError from a flight
        that is genuinely still LIVE propagates (the flight stays owned);
        a flight that FAILED with an internal TimeoutError (e.g. the SMP
        ack timed out) is a dead flight and is routed through _err so the
        engine degrades exactly like the serial path."""
        if not self._flights:
            return
        flight = self._flights[0]
        try:
            res = flight.wait(timeout)
        except TimeoutError:
            if flight.in_flight():
                raise                          # flight stays current
            try:                               # finished during the wait:
                res = flight.wait(0.0)         # collect its real outcome
            except BaseException as e:
                self._flights.pop(0)
                self._flight_failed(e)
                return                         # surfaced by _raise_pending
        except BaseException as e:
            self._flights.pop(0)
            self._flight_failed(e)
            return                             # surfaced by _raise_pending
        self._flights.pop(0)
        self.last_clean_step = res.clean_step
        st = self.stats
        st["snapshots"] += 1
        st["bytes_sent"] += res.bytes_sent
        st["seconds"] += res.wall_seconds
        st["l1_seconds"] += res.l1_seconds
        st["l1_stall_seconds"] += res.l1_stall_seconds
        st["l2_seconds"] += res.l2_seconds
        st["l3_seconds"] += res.l3_seconds
        if self._pipeline is not None:
            st["stager_affinity"] = self._pipeline.applied_affinity
        if self._tracker is not None:
            was_delta = res.delta_base is not None
            frac = (res.bytes_sent / self._flight_bytes
                    if self._flight_bytes else 1.0)
            self._tracker.commit(res.clean_step, res.digests, was_delta,
                                 frac)
            self._delta_log.record(res.clean_step,
                                   res.sent_extents if was_delta else None)
            st["skipped_buckets"] += res.skipped_buckets
            st["delta_flights" if was_delta else "keyframe_flights"] += 1

    def _flight_failed(self, e: BaseException) -> None:
        """A flight died without publishing: remember the error AND drop
        the delta base — provider dirty ranges consumed by the dead
        flight are lost, so the next flight must be a full keyframe."""
        if self._tracker is not None:
            self._tracker.invalidate()
        if self._err is None:
            self._err = e

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            if isinstance(err, DeltaBaseMismatch):
                # the SMP's clean buffer rotated away from the planned
                # base (e.g. under persist-pin pressure): the flight
                # aborted cleanly, nothing was published, and the tracker
                # was already invalidated — next flight keyframes.  Not a
                # fault: training and snapshotting both continue.
                if self._tracker is not None:
                    self._tracker.base_misses += 1
                self.stats["delta_base_misses"] += 1
                return
            if isinstance(err, (BrokenPipeError, EOFError, ConnectionError,
                                TimeoutError, OSError)):
                # SMP process is gone: the paper's stance is that training
                # must not die with its fault-tolerance sidecar — degrade.
                self.degraded = True
                return
            raise err

    # ------------------------------------------------- serial baseline
    def _run_serial(self, leaves, step, extra_meta):
        """Pre-refactor monolithic path (read -> CRC -> blocking ring send
        per bucket), kept as the interference baseline the HASC pipeline
        is measured against (`ReftConfig(pipeline=False)`)."""
        try:
            import zlib
            t0 = time.time()
            for leaf in leaves:
                try:
                    leaf.copy_to_host_async()
                except AttributeError:
                    pass
            budget = leaf_budget(
                self.spec, [(lo, hi) for _, lo, hi in self._own]
                + list(self._stripe))
            reader = LeafReader(self.spec, leaves, budget)
            bb = self.cfg.bucket_bytes
            scratch = np.empty(bb, np.uint8)
            sent = 0
            crc = 0
            l1 = l2 = l3 = 0.0
            t = time.perf_counter()
            self.smp.begin(step)
            l3 += time.perf_counter() - t
            for dst0, lo, hi in self._own:
                for a in range(lo, hi, bb):
                    b = min(a + bb, hi)
                    t = time.perf_counter()
                    reader.read(a, b, scratch[:b - a])
                    crc = zlib.crc32(scratch[:b - a], crc)
                    l1 += time.perf_counter() - t
                    t = time.perf_counter()
                    self.smp.send_bucket(0, dst0 + (a - lo), scratch[:b - a])
                    l2 += time.perf_counter() - t
                    sent += b - a
            for lo, hi in self._stripe:
                for a in range(lo, hi, bb):
                    b = min(a + bb, hi)
                    t = time.perf_counter()
                    reader.read(a, b, scratch[:b - a])
                    l1 += time.perf_counter() - t
                    t = time.perf_counter()
                    self.smp.send_bucket(1, a - lo, scratch[:b - a])
                    l2 += time.perf_counter() - t
                    sent += b - a
            meta = {"spec": self.spec.to_json(), "step": step,
                    "extra": extra_meta, "crc_own": crc}
            t = time.perf_counter()
            self.smp.end(step, pickle.dumps(meta))
            self.last_clean_step = self.smp.wait_clean()
            l3 += time.perf_counter() - t
            self.stats["snapshots"] += 1
            self.stats["bytes_sent"] += sent
            self.stats["seconds"] += time.time() - t0
            self.stats["l1_seconds"] += l1
            self.stats["l2_seconds"] += l2
            self.stats["l3_seconds"] += l3
        except BaseException as e:                      # surfaced on wait()
            self._err = e

    # ------------------------------------------------------------ ckpt
    def delta_extents_since(self, base: Optional[int],
                            step: int) -> Optional[List[Tuple[int, int]]]:
        """Buffer-local extents a `.reftd` persisted at `step` must carry
        relative to a base persisted at `base`, or None when no valid
        chain exists (keyframe in the span, unknown base, delta off) and
        the persist must be a full `.reft`."""
        if self._delta_log is None or base is None:
            return None
        return self._delta_log.extents_since(int(base), int(step))

    def persist_async(self, path: str, step: Optional[int] = None,
                      remote: Optional[dict] = None,
                      delta_base: Optional[int] = None) -> int:
        """REFT-Ckpt, overlapped: fire the persist and return a ticket
        (the SMP streams the pinned shard to disk on its own background
        thread while snapshots keep flowing).  Collect with
        `poll_persists` / `persist_join` / `persist_wait_all`.
        `remote` ({store, key, retry}) asks the SMP worker to mirror the
        shard to an object store — tier 4 — after the local write.
        `delta_base` (with an explicit `step`) asks for a `.reftd` delta
        shard carrying only the extents rewritten since that base — the
        caller must have verified the chain via `delta_extents_since`."""
        opts = {}
        bw = float(getattr(self.cfg, "persist_bw_limit", 0.0) or 0.0)
        if bw > 0:
            opts["bw_limit"] = bw
        if remote:
            opts["remote"] = remote
        if delta_base is not None and step is not None:
            ext = self.delta_extents_since(delta_base, step)
            if ext is None:
                raise ValueError(
                    f"no delta chain from step {delta_base} to {step}")
            opts["delta"] = {"base_step": int(delta_base),
                             "extents": [(int(a), int(b)) for a, b in ext]}
        seq = self.smp.persist_send(
            path, step, delay_s=self.persist_delay_s,
            opts=opts or None)
        self._persists[seq] = {"path": path, "step": step,
                               "t0": time.monotonic(), "blocked": 0.0}
        self.stats["persist_inflight"] = len(self._persists)
        return seq

    def _finish_persist(self, seq: int, msg) -> dict:
        rec = self._persists.pop(seq)
        dt = time.monotonic() - rec["t0"]
        st = self.stats
        st["persist_inflight"] = len(self._persists)
        st["persists"] += 1
        st["persist_seconds"] += dt
        # the share of the persist's lifetime nobody spent blocked on it
        # — the paper's "durable tier off the training path" in seconds
        st["persist_overlap_seconds"] += max(0.0, dt - rec["blocked"])
        out = {"seq": seq, "path": rec["path"], "step": rec["step"],
               "seconds": dt, "error": None}
        if msg[0] == "persist-error":
            st["persist_errors"] += 1
            out["error"] = msg[2]
        else:
            out["path"], out["step"] = msg[2], msg[3]
            info = msg[4] if len(msg) > 4 and isinstance(msg[4], dict) \
                else {}
            st["persist_throttle_seconds"] += info.get("throttle_s", 0.0)
            up = info.get("upload")
            if up:
                st["persist_upload_seconds"] += up.get("upload_s", 0.0)
                st["persist_upload_bytes"] += up.get("upload_bytes", 0)
                st["persist_upload_retries"] += up.get("retries", 0)
                out["upload"] = up
        return out

    def _lost_persist(self, seq: int, why: str) -> dict:
        """SMP died under an in-flight persist: degrade (snapshots pause,
        training continues) and surface the loss as an error record."""
        self.degraded = True
        rec = self._persists.pop(seq)
        self.stats["persist_inflight"] = len(self._persists)
        self.stats["persist_errors"] += 1
        return {"seq": seq, "path": rec["path"], "step": rec["step"],
                "seconds": time.monotonic() - rec["t0"], "error": why}

    def has_persist_ticket(self, seq: int) -> bool:
        """True while ticket `seq` is outstanding (fired, not yet
        collected by poll/join) — the group's drain liveness check."""
        return seq in self._persists

    def poll_persists(self) -> List[dict]:
        """Non-blocking: completion records of every finished persist
        ({seq, path, step, seconds, error})."""
        done = []
        for seq in sorted(self._persists):
            try:
                msg = self.smp.persist_poll(seq)
            except (EOFError, BrokenPipeError, ConnectionError, OSError):
                done.append(self._lost_persist(seq, "SMP lost mid-persist"))
                continue
            if msg is not None:
                done.append(self._finish_persist(seq, msg))
        return done

    def persist_join(self, seq: int, timeout: float = 120.0) -> dict:
        """Block until ticket `seq` completes; returns its record (an
        `error` entry instead of raising — callers decide policy)."""
        rec = self._persists[seq]
        t0 = time.monotonic()
        try:
            msg = self.smp.persist_result(seq, timeout)
        except TimeoutError:
            rec["blocked"] += time.monotonic() - t0
            # the handle marked the seq stale (its late reply will be
            # discarded), so this ticket can never complete: drop it
            self._persists.pop(seq, None)
            self.stats["persist_inflight"] = len(self._persists)
            self.stats["persist_errors"] += 1
            raise
        except (EOFError, BrokenPipeError, ConnectionError, OSError):
            return self._lost_persist(seq, "SMP lost mid-persist")
        rec["blocked"] += time.monotonic() - t0
        return self._finish_persist(seq, msg)

    def persist_wait_all(self, timeout: float = 120.0) -> List[dict]:
        """Join every outstanding persist (oldest first)."""
        deadline = time.monotonic() + timeout
        out = []
        for seq in sorted(self._persists):
            out.append(self.persist_join(
                seq, max(0.01, deadline - time.monotonic())))
        return out

    def persist(self, path: str, step: Optional[int] = None,
                timeout: float = 120.0) -> str:
        """REFT-Ckpt, blocking: SMP writes its clean shard+parity to disk
        without touching the training process (a specific clean step if
        given); raises on persist failure."""
        rec = self.persist_join(self.persist_async(path, step), timeout)
        if rec["error"]:
            raise RuntimeError(f"SMP persist failed: {rec['error']}")
        return rec["path"]

    def close(self):
        try:
            if self.in_flight():
                self.wait(timeout=30)
        except Exception:
            pass
        try:
            if self._persists:            # never strand a durable write
                self.persist_wait_all(timeout=30)
        except Exception:
            pass
        self.smp.stop()
