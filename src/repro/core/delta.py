"""Dirty-delta snapshot planning: pay for what changed, not model size.

An MoE training step touches only the experts its router selected, and
optimizer moments for cold leaves are bit-identical across adjacent
flights — yet every HASC flight copies every byte.  This module plans
*delta flights*: a flight that re-sends only the buckets that may have
changed since the previous published snapshot (the *base*), with the SMP
seeding the new shard buffer from the base so untouched bytes carry over.

Two independent dirtiness signals compose:

  * a *provider* (e.g. the MoE router's touched-expert mask, mapped to
    global byte ranges by `expert_dirty_ranges`) rules buckets clean
    BEFORE any read — the L1 pump never prefetches or `device_get`s
    them; and
  * a *digest compare* (per-bucket CRC32 vs the previous flight's
    table — the device path reuses the Pallas CRC kernel, so only the
    4-byte digest crosses d2h for a clean bucket) catches bit-identical
    buckets inside nominally-dirty ranges.

`DeltaTracker` owns the policy: it keeps the previous flight's digest
table, refuses a delta when the base is not the SMP's latest clean step,
and forces a full keyframe every `keyframe_every` flights or when the
dirty fraction exceeds `dirty_threshold` (delta saves nothing dense).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

Range = Tuple[int, int]

# leaves whose leading dim is the expert axis (params and their optimizer
# moments share path suffixes)
EXPERT_LEAF_MARKERS = ("wi_gate", "wi_up", "wo", "expert")


# ------------------------------------------------------------- ranges
def merge_ranges(ranges: Sequence[Range]) -> List[Range]:
    """Sort + coalesce (lo, hi) byte ranges; drops empties."""
    out: List[Range] = []
    for lo, hi in sorted((int(a), int(b)) for a, b in ranges):
        if hi <= lo:
            continue
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out

def ranges_bytes(ranges: Sequence[Range]) -> int:
    return sum(hi - lo for lo, hi in ranges)


def ranges_intersect(ranges: Sequence[Range], lo: int, hi: int) -> bool:
    """True iff [lo, hi) overlaps any of the MERGED, SORTED `ranges`."""
    if hi <= lo or not ranges:
        return False
    i = bisect.bisect_right([r[0] for r in ranges], lo)
    if i and ranges[i - 1][1] > lo:
        return True
    return i < len(ranges) and ranges[i][0] < hi


def task_dirty(task, ranges: Sequence[Range]) -> bool:
    """Does a `BucketTask` touch any dirty global byte range?  Own-data
    buckets check their own span; fused parity buckets check every
    source block slice (parity must refresh when ANY sibling moved)."""
    if task.kind == 2 and task.sources:
        return any(ranges_intersect(ranges, a, b) for a, b in task.sources)
    return ranges_intersect(ranges, task.lo, task.hi)


def expert_dirty_ranges(spec, touched: Sequence[bool],
                        markers: Sequence[str] = EXPERT_LEAF_MARKERS
                        ) -> List[Range]:
    """Touched-expert mask -> conservative global dirty byte ranges.

    Expert-stacked leaves (leading dim == len(touched), path naming an
    expert weight) contribute only their touched experts' slices; every
    other leaf (router, norms, embeddings, scalars — all updated every
    step) is whole-leaf dirty."""
    E = len(touched)
    out: List[Range] = []
    for leaf in spec.leaves:
        stacked = (E > 1 and len(leaf.shape) >= 1 and leaf.shape[0] == E
                   and leaf.nbytes % E == 0
                   and any(m in leaf.path for m in markers))
        if not stacked:
            out.append((leaf.offset, leaf.offset + leaf.nbytes))
            continue
        per = leaf.nbytes // E
        for e in range(E):
            if touched[e]:
                out.append((leaf.offset + e * per,
                            leaf.offset + (e + 1) * per))
    return merge_ranges(out)


# ------------------------------------------------------------- planning
@dataclass(frozen=True)
class FlightDelta:
    """One delta flight's plan, handed to `PipelineFlight`.

    `base_step` must be the SMP's latest clean step (the buffer the SMP
    seeds the new shard from); `prev` maps full-schedule task index ->
    that base flight's bucket CRC32; `skip` are task indices ruled clean
    by the provider (never read); `digest` enables the per-bucket
    digest-compare skip for the rest."""
    base_step: int
    prev: Dict[int, int]
    skip: FrozenSet[int] = frozenset()
    digest: bool = True


@dataclass
class DeltaTracker:
    """Keyframe/delta policy + the previous flight's digest table."""
    keyframe_every: int = 8
    dirty_threshold: float = 0.6
    digest: bool = True
    base_step: int = -1
    digests: Optional[Dict[int, int]] = None
    flights_since_keyframe: int = 0
    force_keyframe: bool = False
    base_misses: int = 0

    def invalidate(self) -> None:
        """Drop the base: the next flight MUST be a keyframe (engine
        degraded/healed, SMP respawned, or a delta-begin base miss)."""
        self.digests = None
        self.base_step = -1

    def plan(self, last_clean_step: int, sched,
             dirty_ranges: Optional[Sequence[Range]],
             total_bytes: int) -> Optional[FlightDelta]:
        """None -> take a full keyframe; else the delta plan."""
        if (self.digests is None or last_clean_step < 0
                or self.base_step != last_clean_step):
            return None
        if self.force_keyframe \
                or self.flights_since_keyframe >= self.keyframe_every:
            return None
        skip: FrozenSet[int] = frozenset()
        if dirty_ranges is not None:
            ranges = merge_ranges(dirty_ranges)
            if ranges_bytes(ranges) > self.dirty_threshold * total_bytes:
                return None
            skip = frozenset(i for i, t in enumerate(sched)
                             if not task_dirty(t, ranges))
        return FlightDelta(self.base_step, dict(self.digests), skip,
                           self.digest)

    def commit(self, clean_step: int, digests: Optional[Dict[int, int]],
               was_delta: bool, sent_frac: float) -> None:
        """Fold a finished flight back in: its digest table becomes the
        next base; a delta that turned out dense forces a keyframe."""
        self.digests = dict(digests) if digests is not None else None
        self.base_step = clean_step if digests is not None else -1
        self.flights_since_keyframe = \
            self.flights_since_keyframe + 1 if was_delta else 0
        self.force_keyframe = was_delta \
            and sent_frac > self.dirty_threshold


# ------------------------------------------------------- persist chains
@dataclass
class DeltaLog:
    """Per-engine record of which buffer-local extents each published
    step rewrote (None => keyframe: the whole shard).  `extents_since`
    answers "what must a `.reftd` persisted at `step` carry relative to
    a base persisted at `base`" — the union over every flight in
    (base, step], or None when the chain is broken (a missing step, a
    keyframe in between, or an unknown base) and the persist must be a
    full `.reft`."""
    cap: int = 128
    entries: Dict[int, Optional[Tuple[Range, ...]]] = field(
        default_factory=dict)

    def record(self, step: int, extents: Optional[Sequence[Range]]) -> None:
        self.entries[int(step)] = (tuple(merge_ranges(extents))
                                   if extents is not None else None)
        while len(self.entries) > self.cap:
            del self.entries[min(self.entries)]

    def extents_since(self, base: int, step: int) -> Optional[List[Range]]:
        if base is None or base < 0 or step <= base \
                or base not in self.entries:
            return None
        acc: List[Range] = []
        for s in range(base + 1, step + 1):
            if s not in self.entries:
                continue                     # step not snapshotted: fine
            ext = self.entries[s]
            if ext is None:                  # keyframe in the span
                return None
            acc.extend(ext)
        if not acc:                          # nothing changed: still emit
            return []                        # an (empty) delta
        return merge_ranges(acc)
