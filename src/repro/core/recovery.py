"""Recovery paths (paper §3 step 5, §4.2 "Loading", §4.3 decoding).

Three tiers, tried in order:
  1. software failure (trainer died, SMPs alive): reassemble the full state
     from every SG member's in-memory shard;
  2. single node failure per SG: RAIM5-decode the dead node's blocks from
     survivors' shards + parities, then reassemble;
  3. >1 node failure in an SG: fall back to the last persisted REFT-Ckpt.
"""
from __future__ import annotations

import glob
import os
import pickle
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import raim5
from repro.core.smp import NodeLayout, ReadOnlyNode
from repro.core.treebytes import FlatSpec, buffer_to_tree


class RecoveryError(RuntimeError):
    pass


def attach_survivors(run: str, nodes: List[int], n: int, total_bytes: int
                     ) -> Dict[int, ReadOnlyNode]:
    views = {}
    for node in nodes:
        try:
            views[node] = ReadOnlyNode(run, node, n, total_bytes)
        except (FileNotFoundError, RuntimeError):
            pass
    return views


def common_step(views: Dict[int, ReadOnlyNode]) -> Optional[int]:
    """Newest step CLEAN on *every* surviving view."""
    sets = [set(v.clean_steps()) for v in views.values()]
    if not sets:
        return None
    common = set.intersection(*sets)
    return max(common) if common else None


def verify_crc(view: ReadOnlyNode, step: int, n: int,
               total_bytes: int) -> bool:
    """Recompute the snapshot's own-shard checksum (written by the engine
    at save time). Detects silent in-memory corruption — a corrupt member
    is treated like a failed node and repaired from RAIM5 parity."""
    import zlib
    try:
        meta = pickle.loads(view.meta(step))
    except Exception:
        return False
    expect = meta.get("crc_own")
    if expect is None:                       # legacy snapshot: no checksum
        return True
    # the engine streams the own region contiguously (full blocks incl.
    # the zero padding of the tail block), so one pass over it suffices
    buf = view.read_own(step)
    span = total_bytes if n == 1 else view.layout.own_bytes
    return zlib.crc32(buf[:span]) == expect


def _read_block_fn(views, step):
    def read_block(node, stripe, index):
        return views[node].read_block(step, stripe, index)
    return read_block


def restore_bytes(views: Dict[int, ReadOnlyNode], n: int, total_bytes: int,
                  step: int, failed: Optional[int] = None) -> np.ndarray:
    """Full state bytes at `step`; RAIM5-decodes `failed`'s blocks if set."""
    if n == 1:
        (view,) = views.values()
        return view.read_own(step)[:total_bytes].copy()
    recovered = None
    if failed is not None:
        recovered = raim5.decode_node(
            failed, n, total_bytes,
            read_block=_read_block_fn(views, step),
            read_parity=lambda s: views[s].read_parity(step))
    return raim5.reassemble(n, total_bytes, _read_block_fn(views, step),
                            recovered)


def restore_state(run: str, n: int, total_bytes: int, template: Any,
                  alive_nodes: List[int],
                  info: Optional[dict] = None) -> Tuple[Any, int, dict]:
    """End-to-end in-memory restore. Returns (state_tree, step, extra_meta).

    Raises RecoveryError when more than one node per SG is gone (tier 3
    must take over).  When `info` (a dict) is passed it is filled with
    what actually happened: {"attached", "corrupt", "missing"} — callers
    derive the recovery tier from it instead of re-probing segments.
    """
    views = attach_survivors(run, alive_nodes, n, total_bytes)
    try:
        if info is not None:
            info["attached"] = sorted(views)
        # Newest usable step: clean on every member, or clean on all but
        # ONE — a member whose async round lagged behind (its buffers
        # rotated past the step) is byte-for-byte equivalent to a failed
        # node at that step, and RAIM5 decodes its shard from the others'
        # parity.  Corrupt members (CRC mismatch) are demoted the same way.
        clean = {node: set(v.clean_steps()) for node, v in views.items()}
        candidates = sorted(set().union(*clean.values()), reverse=True) \
            if clean else []
        chosen = None
        crc_ok: Dict[Tuple[int, int], bool] = {}   # (node, step) -> verdict
        for step in candidates:
            holders = [nd for nd, steps in clean.items() if step in steps]
            if n - len(holders) > 1:
                continue
            for nd in holders:                     # CRC once per (node,step)
                if (nd, step) not in crc_ok:
                    crc_ok[nd, step] = verify_crc(views[nd], step, n,
                                                  total_bytes)
            corrupt = [nd for nd in holders if not crc_ok[nd, step]]
            usable = [nd for nd in holders if nd not in corrupt]
            # need every member but at most one (RAIM5's budget), and at
            # least one actual source to read from (n==1 + corrupt would
            # otherwise slip through as usable=[])
            if usable and len(usable) >= n - 1:
                chosen = (step, usable, corrupt)
                break
        if chosen is None:
            raise RecoveryError(
                f"no usable snapshot step across survivors (dead: "
                f"{sorted(set(range(n)) - set(views))}, clean steps: "
                f"{ {nd: sorted(s) for nd, s in clean.items()} }); "
                f"RAIM5 protects exactly one member")
        step, usable, corrupt = chosen
        missing = sorted(set(range(n)) - set(usable))
        if info is not None:
            info["corrupt"] = corrupt
            info["missing"] = missing
            info["stale"] = [nd for nd in views
                             if nd not in usable and nd not in corrupt]
        use_views = {nd: views[nd] for nd in usable}
        failed = missing[0] if missing else None
        buf = restore_bytes(use_views, n, total_bytes, step, failed)
        any_view = next(iter(use_views.values()))
        meta = pickle.loads(any_view.meta(step))
        spec = FlatSpec.from_json(meta["spec"])
        tree = buffer_to_tree(template, spec, buf)
        return tree, step, meta.get("extra", {})
    finally:
        for v in views.values():
            v.close()


# --------------------------------------------------------------- tier 3
def latest_checkpoint_step(ckpt_dir: str,
                           n: Optional[int] = None) -> Optional[int]:
    """Newest persisted step; with `n`, newest COMPLETE family (all n
    member shards on disk) — torn families are not restorable."""
    families: Dict[int, set] = {}
    for p in glob.glob(os.path.join(ckpt_dir, "step-*-node-*.reft")):
        parts = os.path.basename(p).split("-")
        families.setdefault(int(parts[1]), set()).add(int(parts[3].split(".")[0]))
    steps = [s for s, nodes in families.items()
             if n is None or nodes == set(range(n))]
    return max(steps) if steps else None


def restore_from_checkpoint(ckpt_dir: str, n: int, template: Any,
                            step: Optional[int] = None
                            ) -> Tuple[Any, int, dict]:
    """Rebuild from REFT-Ckpt files (each node persisted shard+parity)."""
    step = latest_checkpoint_step(ckpt_dir, n) if step is None else step
    if step is None:
        raise RecoveryError("no complete checkpoint available")
    shards = {}
    head = None
    for node in range(n):
        path = os.path.join(ckpt_dir, f"step-{step}-node-{node}.reft")
        try:
            with open(path, "rb") as f:
                head = pickle.load(f)
                shards[node] = np.frombuffer(f.read(), np.uint8)
        except FileNotFoundError:
            raise RecoveryError(f"checkpoint family step {step} is torn: "
                                f"missing {os.path.basename(path)}")
    total = head["total_bytes"]
    lay = NodeLayout(n, total)
    if n == 1:
        buf = shards[0][:total]
    else:
        def read_block(node, stripe, index):
            refs = raim5.data_blocks_of_node(node, n)
            li = next(i for i, r in enumerate(refs)
                      if (r.stripe, r.index) == (stripe, index))
            return shards[node][li * lay.bs:(li + 1) * lay.bs]
        buf = raim5.reassemble(n, total, read_block)
    meta = pickle.loads(head["meta"])
    spec = FlatSpec.from_json(meta["spec"])
    tree = buffer_to_tree(template, spec, buf)
    return tree, head["step"], meta.get("extra", {})
