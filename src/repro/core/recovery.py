"""Recovery paths (paper §3 step 5, §4.2 "Loading", §4.3 decoding).

Three tiers, tried in order:
  1. software failure (trainer died, SMPs alive): reassemble the state
     from every SG member's in-memory shard;
  2. single node failure per SG: RAIM5-decode the dead node's blocks from
     survivors' shards + parities, then reassemble;
  3. >1 node failure in an SG: fall back to the last persisted REFT-Ckpt.

This module is the *tier policy*; the data movement lives in
`repro.core.loader`: every tier routes through a `LoadPlan` executed with
parallel ranged reads (shared-memory segments for tiers 1-2, seek+read
over `.reft` files for tier 3), range-limited RAIM5 decode, incremental
CRC folded into the read pass, and streamed per-leaf assembly.  Tier 3
additionally supports reshard-on-restore: a family saved by an n-member
SG restores under an m-member group (elastic n->m restart) because the
saved layout is rediscovered from the file heads.
"""
from __future__ import annotations

import glob
import os
import pickle
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.loader import (
    CHUNK_BYTES, ChainSource, CrcMismatch, DeltaLayer, FileSource, LoadStats,
    ShmSource, build_plan, load_bytes, load_tree, probe_crc, stream_crc,
)
from repro.core.readsched import SourceLost
from repro.core.smp import ReadOnlyNode
from repro.core.treebytes import FlatSpec

CRC_CHUNK_BYTES = CHUNK_BYTES       # one streaming granularity everywhere


class RecoveryError(RuntimeError):
    pass


def attach_survivors(run: str, nodes: List[int], n: int, total_bytes: int
                     ) -> Dict[int, ReadOnlyNode]:
    views = {}
    for node in nodes:
        try:
            views[node] = ReadOnlyNode(run, node, n, total_bytes)
        except (FileNotFoundError, RuntimeError):
            pass
    return views


def common_step(views: Dict[int, ReadOnlyNode]) -> Optional[int]:
    """Newest step CLEAN on *every* surviving view."""
    sets = [set(v.clean_steps()) for v in views.values()]
    if not sets:
        return None
    common = set.intersection(*sets)
    return max(common) if common else None


def verify_crc(view: ReadOnlyNode, step: int, n: int, total_bytes: int,
               chunk_bytes: int = CRC_CHUNK_BYTES) -> bool:
    """Standalone integrity probe: recompute the snapshot's own-shard
    checksum (written at save time) in fixed-size streamed chunks — never
    holds more than `chunk_bytes`, so probing a large member does not
    spike RSS.  The recovery ladder itself no longer calls this (its
    checks are folded into the loader's read pass / `loader.probe_crc`);
    it remains the public health-check utility for scrubbers and tests,
    with identical verdict semantics (unreadable meta = corrupt)."""
    try:
        meta = pickle.loads(view.meta(step))
    except Exception:
        return False
    expect = meta.get("crc_own")
    if expect is None:                       # legacy snapshot: no checksum
        return True
    # the engine streams the own region contiguously (full blocks incl.
    # the zero padding of the tail block), so one pass over it suffices
    span = total_bytes if n == 1 else view.layout.own_bytes
    try:
        crc = stream_crc(lambda lo, hi: view.read_range(step, lo, hi),
                         span, chunk_bytes)
    except Exception:
        return False
    return crc == expect


def restore_bytes(views: Dict[int, ReadOnlyNode], n: int, total_bytes: int,
                  step: int, failed: Optional[int] = None,
                  need: Optional[Sequence[Tuple[int, int]]] = None,
                  stats: Optional[LoadStats] = None,
                  sched=None) -> np.ndarray:
    """State bytes at `step` via the ranged loader; RAIM5-decodes exactly
    the plan-intersecting sub-ranges of `failed` if set.  With `need`,
    bytes outside the requested ranges stay zero."""
    plan = build_plan(n, total_bytes, need=need, failed=failed)
    buf, _ = load_bytes(plan, ShmSource(views, step), verify=False,
                        stats=stats, sched=sched)
    return buf


def _load_with_demotion(n: int, total_bytes: int, template: Any,
                        spec: FlatSpec, source_of, holders: List[int],
                        absent: List[int],
                        need: Optional[Sequence[Tuple[int, int]]],
                        device_put: bool, stats: LoadStats,
                        sched=None) -> Tuple[Any, List[int], List[int]]:
    """Execute the plan for one candidate step, folding each fully-read
    member's CRC into its read pass (full plans) or streaming a probe of
    the members the plan reads first (partial plans — `crc_own` is a
    whole-region digest); either way a mismatch demotes that member to
    failed and re-plans (RAIM5's one-member budget permitting).

    `source_of(usable)` builds the range source over the given members.
    Returns (tree, usable, corrupt); raises `RecoveryError` when the
    demotions exceed the parity budget.  The adaptive scheduler's
    `SourceLost` (a member died mid-read and its chunks could not be
    cleanly rerouted to parity) demotes exactly like a digest mismatch —
    this loop is the ladder's mid-flight re-plan acceptance."""
    corrupt: List[int] = []
    probed_ok: set = set()
    while True:
        usable = [nd for nd in holders if nd not in corrupt]
        missing = sorted(set(range(n)) - set(usable))
        if not usable or len(missing) > 1:
            raise RecoveryError(
                f"member demotions exceed RAIM5 budget (absent: {absent}, "
                f"corrupt: {corrupt})")
        failed = missing[0] if missing else None
        plan = build_plan(n, total_bytes, need=need, failed=failed)
        src = source_of(usable)
        if need is not None:
            # only members verified against the WHOLE-region digest may be
            # skipped on a demotion retry: a stripe-digest probe covered
            # exactly the current plan's segments, and the re-plan's
            # decode may touch new ones (re-probing those is cheap — that
            # is the point of the table)
            bad = probe_crc(plan, src, stats=stats, skip=probed_ok,
                            full_verified=probed_ok)
            if bad:
                corrupt.extend(bad)
                continue
            try:
                tree, _ = load_tree(plan, src, template, spec,
                                    verify=False, device_put=device_put,
                                    stats=stats, sched=sched)
                return tree, usable, corrupt
            except SourceLost as e:
                corrupt.append(e.node)
                continue
        try:
            tree, _ = load_tree(plan, src, template, spec, verify=True,
                                device_put=device_put, stats=stats,
                                sched=sched)
            return tree, usable, corrupt
        except (CrcMismatch, SourceLost) as e:
            corrupt.append(e.node)


def restore_state(run: str, n: int, total_bytes: int, template: Any,
                  alive_nodes: List[int],
                  info: Optional[dict] = None,
                  step: Optional[int] = None,
                  need: Optional[Sequence[Tuple[int, int]]] = None,
                  device_put: bool = False,
                  stats: Optional[LoadStats] = None,
                  sched=None) -> Tuple[Any, int, dict]:
    """End-to-end in-memory restore. Returns (state_tree, step, extra_meta).

    Raises RecoveryError when more than one node per SG is gone (tier 3
    must take over).  When `info` (a dict) is passed it is filled with
    what actually happened: {"attached", "corrupt", "missing"} — callers
    derive the recovery tier from it instead of re-probing segments.
    `step` pins a specific snapshot step; `need` restricts the load to
    global byte ranges (partial / resharded restore); `stats` (a
    `LoadStats`) collects per-phase accounting."""
    st = stats if stats is not None else LoadStats()
    views = attach_survivors(run, alive_nodes, n, total_bytes)
    try:
        if info is not None:
            info["attached"] = sorted(views)
        # Newest usable step: clean on every member, or clean on all but
        # ONE — a member whose async round lagged behind (its buffers
        # rotated past the step) is byte-for-byte equivalent to a failed
        # node at that step, and RAIM5 decodes its shard from the others'
        # parity.  Corrupt members (CRC mismatch, folded into the loader's
        # read pass) are demoted the same way.
        clean = {node: set(v.clean_steps()) for node, v in views.items()}
        candidates = sorted(set().union(*clean.values()), reverse=True) \
            if clean else []
        if step is not None:
            candidates = [s for s in candidates if s == step]
        chosen = None
        for cand in candidates:
            holders = [nd for nd, steps in clean.items() if cand in steps]
            if n - len(holders) > 1:
                continue
            absent = sorted(set(range(n)) - set(holders))
            try:
                tree, usable, corrupt = _load_with_demotion(
                    n, total_bytes, template,
                    _spec_of(views, holders, cand),
                    lambda members, c=cand: ShmSource(
                        {nd: views[nd] for nd in members}, c),
                    holders, absent, need, device_put, st, sched=sched)
            except RecoveryError:
                continue
            chosen = (cand, tree, usable, corrupt)
            break
        if chosen is None:
            raise RecoveryError(
                f"no usable snapshot step across survivors (dead: "
                f"{sorted(set(range(n)) - set(views))}, clean steps: "
                f"{ {nd: sorted(s) for nd, s in clean.items()} }); "
                f"RAIM5 protects exactly one member")
        cand, tree, usable, corrupt = chosen
        missing = sorted(set(range(n)) - set(usable))
        if info is not None:
            info["corrupt"] = corrupt
            info["missing"] = missing
            info["stale"] = [nd for nd in views
                             if nd not in usable and nd not in corrupt]
        extra = {}
        for nd in usable:              # usable members' metas parsed during
            try:                       # the load; loop is belt-and-braces
                extra = pickle.loads(views[nd].meta(cand)).get("extra", {})
                break
            except Exception:
                continue
        return tree, cand, extra
    finally:
        for v in views.values():
            v.close()


def _spec_of(views, holders, step) -> FlatSpec:
    """Spec from the first holder whose meta parses — a member with a
    corrupt meta must be DEMOTED by the loader (it is), not allowed to
    crash the ladder before the load even starts."""
    last: Optional[Exception] = None
    for nd in holders:
        try:
            meta = pickle.loads(views[nd].meta(step))
            return FlatSpec.from_json(meta["spec"])
        except Exception as e:
            last = e
    raise RecoveryError(
        f"no member meta parseable at step {step}: {last!r}")


# --------------------------------------------------------------- tier 3
_CKPT_RE = re.compile(r"^step-(\d+)-node-(\d+)\.reft$")
_DELTA_RE = re.compile(r"^step-(\d+)-from-(\d+)-node-(\d+)\.reftd$")


def checkpoint_families(ckpt_dir: str) -> Dict[int, set]:
    """{step: {nodes on disk}} from anchored-regex filename parsing (a
    future name with extra dashes can no longer corrupt the step/node
    split the way `split("-")` indexing did)."""
    families: Dict[int, set] = {}
    for p in glob.glob(os.path.join(ckpt_dir, "step-*-node-*.reft")):
        m = _CKPT_RE.match(os.path.basename(p))
        if not m:
            continue
        families.setdefault(int(m.group(1)), set()).add(int(m.group(2)))
    return families


def delta_families(ckpt_dir: str) -> Dict[int, Dict[int, set]]:
    """{step: {base_step: {nodes on disk}}} from `.reftd` filenames.  The
    base step rides in the NAME (`step-S-from-B-node-N.reftd`) so chain
    resolution and GC liveness never open a file."""
    fams: Dict[int, Dict[int, set]] = {}
    for p in glob.glob(os.path.join(ckpt_dir, "step-*-from-*-node-*.reftd")):
        m = _DELTA_RE.match(os.path.basename(p))
        if not m:
            continue
        step, base, node = (int(m.group(1)), int(m.group(2)),
                            int(m.group(3)))
        fams.setdefault(step, {}).setdefault(base, set()).add(node)
    return fams


def resolve_chain(ckpt_dir: str, step: int,
                  full: Optional[Dict[int, set]] = None,
                  deltas: Optional[Dict[int, Dict[int, set]]] = None
                  ) -> Optional[Tuple[int, List[Tuple[int, int]]]]:
    """Resolve `step` against the on-disk delta chains: returns
    `(keyframe_step, links)` with links `[(step, base_step), ...]`
    oldest -> newest ending at `step`, or None when no chain bottoms out
    at a full `.reft` family.  A full family at `step` itself resolves
    to `(step, [])`.  Cycles and dangling bases fall through to None."""
    if full is None:
        full = checkpoint_families(ckpt_dir)
    if deltas is None:
        deltas = delta_families(ckpt_dir)

    def walk(s: int, seen: frozenset
             ) -> Optional[Tuple[int, List[Tuple[int, int]]]]:
        if s in full:
            return s, []
        if s in seen or s not in deltas:
            return None
        for base in sorted(deltas[s], reverse=True):
            r = walk(base, seen | {s})
            if r is not None:
                kf, links = r
                return kf, links + [(s, base)]
        return None

    return walk(int(step), frozenset())


def _chain_complete(links: Sequence[Tuple[int, int]],
                    deltas: Dict[int, Dict[int, set]], n: int) -> bool:
    want = set(range(n))
    return all(deltas.get(s, {}).get(b, set()) & want == want
               for s, b in links)


def restorable_steps(ckpt_dir: str, n: Optional[int] = None) -> List[int]:
    """Sorted steps with a restorable on-disk family; with `n`, only
    COMPLETE ones (all n member shards).  A delta step counts when its
    whole chain — every `.reftd` link plus the keyframe it bottoms out
    at — is complete; a torn link poisons every dependent step."""
    families = checkpoint_families(ckpt_dir)
    deltas = delta_families(ckpt_dir)
    steps = [s for s, nodes in families.items()
             if n is None or nodes == set(range(n))]
    for s in deltas:
        if s in families:
            continue
        res = resolve_chain(ckpt_dir, s, families, deltas)
        if res is None:
            continue
        kf, links = res
        if n is None or (families.get(kf) == set(range(n))
                         and _chain_complete(links, deltas, n)):
            steps.append(s)
    return sorted(steps)


def latest_checkpoint_step(ckpt_dir: str,
                           n: Optional[int] = None) -> Optional[int]:
    """Newest persisted step; with `n`, newest COMPLETE (chain-
    resolvable) family — torn families are not restorable."""
    steps = restorable_steps(ckpt_dir, n)
    return max(steps) if steps else None


def _family_paths(ckpt_dir: str, step: int, nodes) -> Dict[int, str]:
    return {node: os.path.join(ckpt_dir, f"step-{step}-node-{node}.reft")
            for node in nodes}


def _open_family(ckpt_dir: str, step: int, nodes: set) -> FileSource:
    """Attach a family, validating completeness against its OWN saved
    layout (the heads record n) — an n-member family restores under any
    current group size (reshard-on-restore)."""
    if not nodes:
        raise RecoveryError(f"checkpoint family step {step} has no shards")
    # lightweight probe: one head read to learn the saved layout (the one
    # file re-opened by the full FileSource below)
    path = _family_paths(ckpt_dir, step, [min(nodes)])[min(nodes)]
    with open(path, "rb") as f:
        saved_n = pickle.load(f)["n"]
    want = set(range(saved_n))
    if nodes & want != want:
        missing = sorted(want - nodes)[0]
        raise RecoveryError(
            f"checkpoint family step {step} is torn: missing "
            f"step-{step}-node-{missing}.reft")
    return FileSource(_family_paths(ckpt_dir, step, sorted(want)))


def _delta_paths(ckpt_dir: str, step: int, base: int, nodes) -> Dict[int, str]:
    return {node: os.path.join(
        ckpt_dir, f"step-{step}-from-{base}-node-{node}.reftd")
        for node in nodes}


def _open_chain(ckpt_dir: str, step: int,
                full: Optional[Dict[int, set]] = None,
                deltas: Optional[Dict[int, Dict[int, set]]] = None):
    """Attach `step`, resolving a delta chain back to its keyframe when
    `step` has no full family of its own.  Returns a source with the
    standard interface (`FileSource` for a full family, `ChainSource`
    over `DeltaLayer`s otherwise); completeness of every link is checked
    against the keyframe's OWN saved layout, so an n-member chain
    restores under any current group size."""
    if full is None:
        full = checkpoint_families(ckpt_dir)
    if deltas is None:
        deltas = delta_families(ckpt_dir)
    if step in full:
        return _open_family(ckpt_dir, step, full[step])
    res = resolve_chain(ckpt_dir, step, full, deltas)
    if res is None:
        raise RecoveryError(
            f"no resolvable delta chain for step {step} in {ckpt_dir}")
    kf, links = res
    base = _open_family(ckpt_dir, kf, full[kf])
    layers: List[DeltaLayer] = []
    try:
        want = set(range(base.n))
        for s, b in links:
            have = deltas.get(s, {}).get(b, set())
            if have & want != want:
                missing = sorted(want - have)[0]
                raise RecoveryError(
                    f"delta family step {s} (base {b}) is torn: missing "
                    f"step-{s}-from-{b}-node-{missing}.reftd")
            layers.append(DeltaLayer.from_files(
                _delta_paths(ckpt_dir, s, b, sorted(want))))
        return ChainSource(base, layers)
    except BaseException:
        for ly in layers:
            ly.close()
        base.close()
        raise


def restore_from_checkpoint(ckpt_dir: str, n: int, template: Any,
                            step: Optional[int] = None,
                            need: Optional[Sequence[Tuple[int, int]]] = None,
                            device_put: bool = False,
                            stats: Optional[LoadStats] = None,
                            sched=None) -> Tuple[Any, int, dict]:
    """Rebuild from REFT-Ckpt files through the same `LoadPlan` executors
    as the in-memory tiers: per-member-parallel ranged file reads, CRC
    folded into the pass, RAIM5 demotion of a corrupt shard, and elastic
    reshard when the family was saved with a different SG size than `n`."""
    st = stats if stats is not None else LoadStats()
    if not st.target_n:       # the ladder presets target.sg_size; keep it
        st.target_n = n
    families = checkpoint_families(ckpt_dir)
    deltas = delta_families(ckpt_dir)
    resolvable = set(families) | {
        s for s in deltas
        if resolve_chain(ckpt_dir, s, families, deltas) is not None}
    if step is not None:
        if step not in resolvable:
            raise RecoveryError(f"no checkpoint for step {step} "
                                f"in {ckpt_dir}")
        candidates = [step]
    else:
        candidates = sorted(resolvable, reverse=True)
    last_err: Optional[Exception] = None
    for cand in candidates:
        try:
            src = _open_chain(ckpt_dir, cand, families, deltas)
        except (RecoveryError, FileNotFoundError, EOFError, KeyError,
                TypeError, pickle.UnpicklingError) as e:
            last_err = e                # malformed head = unusable family
            continue
        try:
            saved_n = src.n
            st.saved_n = saved_n
            st.resharded = bool(n) and saved_n != n
            meta = spec = None
            for nd in src.nodes:       # a member with a corrupt meta blob
                try:                   # is demoted by the loader — any
                    meta = src.meta(nd)            # parseable meta will do
                    spec = FlatSpec.from_json(meta["spec"])
                    break
                except Exception:
                    continue
            if spec is None:
                raise RecoveryError(
                    f"family step {src.step}: no member meta parseable")
            holders = list(src.nodes)
            tree, usable, corrupt = _load_with_demotion(
                saved_n, src.total_bytes, template, spec,
                lambda members: src, holders, [], need, device_put, st,
                sched=sched)
            return tree, src.step, meta.get("extra", {})
        except (RecoveryError, KeyError, TypeError, ValueError, EOFError,
                pickle.UnpicklingError) as e:
            last_err = e               # malformed family: try the next one
            continue
        finally:
            src.close()
    if step is not None and last_err is not None:
        raise RecoveryError(str(last_err))
    raise RecoveryError(
        f"no complete checkpoint available"
        + (f" ({last_err})" if last_err else ""))


# --------------------------------------------------------------- tier 4
def _open_remote_chain(store, prefix: str, step: int, retry=None):
    """Attach a remote family at `step`, following manifest `base_step`
    links back to a full keyframe family.  Returns `(src, holders)`:
    the chain (or plain) source plus the members whose shard objects all
    exist at EVERY link — a member missing any link of its chain cannot
    serve reads and is left to RAIM5 reconstruction."""
    from repro.core.loader import ObjectSource
    from repro.store.base import retrier
    from repro.store.manifest import load_manifest, manifest_base_step

    wrap = retrier(retry)
    man = load_manifest(store, prefix, step, retry=retry)
    link_mans: List[dict] = []           # newest -> oldest delta manifests
    seen = {int(step)}
    while True:
        base = manifest_base_step(man)
        if base is None:
            break
        link_mans.append(man)
        if base in seen:
            raise RecoveryError(
                f"remote delta chain for step {step} cycles at {base}")
        seen.add(base)
        man = load_manifest(store, prefix, base, retry=retry)
    base_man = man
    src = ObjectSource(store, base_man, retry=wrap)
    if link_mans:
        src = ChainSource(src, [DeltaLayer.from_objects(store, m, retry=wrap)
                                for m in reversed(link_mans)])
    holders = []
    for nd in range(src.n):
        if all(nd in m["nodes"] and store.exists(m["nodes"][nd]["key"])
               for m in [base_man] + link_mans):
            holders.append(nd)
    return src, holders


def restore_from_objstore(store, prefix: str, n: int, template: Any,
                          step: Optional[int] = None,
                          need: Optional[Sequence[Tuple[int, int]]] = None,
                          device_put: bool = False,
                          stats: Optional[LoadStats] = None,
                          retry=None, sched=None) -> Tuple[Any, int, dict]:
    """Rebuild from a remote object-store family: the manifest names the
    shard objects and saved topology, `ObjectSource` turns `LoadPlan`
    ranges into positioned remote reads (no local staging copy), and the
    rest — folded CRC verify, RAIM5 demotion, elastic n->m reshard —
    is the same `_load_with_demotion` machinery every other tier uses.
    Only manifest-complete families are candidates, so a torn upload can
    never be surfaced."""
    from repro.store.base import StoreError
    from repro.store.manifest import object_families

    st = stats if stats is not None else LoadStats()
    if not st.target_n:
        st.target_n = n
    try:
        families = object_families(store, prefix)
    except StoreError as e:
        raise RecoveryError(f"object store unavailable: {e!r}")
    if step is not None:
        if step not in families:
            raise RecoveryError(
                f"no remote family for step {step} under {prefix!r}")
        candidates = [step]
    else:
        candidates = sorted(families, reverse=True)
    last_err: Optional[Exception] = None
    for cand in candidates:
        try:
            # a manifest-complete family names all saved_n shards; a
            # shard object deleted since (GC race, remote loss) becomes
            # a missing member the RAIM5 demotion path reconstructs.
            # Delta manifests chain through `base_step` links back to a
            # full keyframe family, served as one overlay source.
            src, holders = _open_remote_chain(store, prefix, cand,
                                              retry=retry)
            saved_n = src.n
            st.saved_n = saved_n
            st.resharded = bool(n) and saved_n != n
            absent = [nd for nd in range(saved_n) if nd not in holders]
            meta = spec = None
            for nd in holders:
                try:
                    meta = src.meta(nd)
                    spec = FlatSpec.from_json(meta["spec"])
                    break
                except Exception:
                    continue
            if spec is None:
                raise RecoveryError(
                    f"remote family step {cand}: no member meta parseable")
            tree, usable, corrupt = _load_with_demotion(
                saved_n, src.total_bytes, template, spec,
                lambda members: src, holders, absent, need, device_put, st,
                sched=sched)
            return tree, src.step, meta.get("extra", {})
        except (RecoveryError, StoreError, KeyError, TypeError, ValueError,
                EOFError, pickle.UnpicklingError) as e:
            last_err = e               # malformed family: try the next one
            continue
    if step is not None and last_err is not None:
        raise RecoveryError(str(last_err))
    raise RecoveryError(
        f"no complete remote family available"
        + (f" ({last_err})" if last_err else ""))
