"""Elastic coordinator (paper §4.2 "Elastic Functionality").

State machine per node: HEALTHY -> SNAP (snapshotting) -> HEALTHY;
UNHEALTHY = software failure (trainer lost, SMP alive);
OFFLINE  = node failure (SMP + memory gone).

`ReftGroup` drives one SG (n members) from a synchronous training loop —
the paper's setting: all DP members snapshot the same iteration.  Each
member owns a real SMP process; snapshotting runs in parallel member
threads (the simulated analogue of parallel per-host PCIe links).
"""
from __future__ import annotations

import enum
import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.policy import FrequencyPlan, plan_frequencies
from repro.core.recovery import (
    RecoveryError, restore_from_checkpoint, restore_state,
)
from repro.core.snapshot import ReftConfig, SnapshotEngine


class NodeState(enum.Enum):
    HEALTHY = "HEALTHY"
    SNAP = "SNAP"
    UNHEALTHY = "UNHEALTHY"      # software failure: trainer gone, SMP alive
    OFFLINE = "OFFLINE"          # node failure: SMP and its memory gone


class ReftGroup:
    """REFT for one sharding group of `n` members."""

    def __init__(self, n: int, state_template: Any,
                 cfg: Optional[ReftConfig] = None):
        # NB: a `cfg=ReftConfig()` default would be evaluated once at class
        # definition, making every default-constructed group share one
        # run_id (and thus one set of shm segments) — construct per call.
        cfg = cfg if cfg is not None else ReftConfig()
        self.n, self.cfg = n, cfg
        self.run = cfg.run_id
        self.engines = [SnapshotEngine(i, n, state_template, cfg,
                                       run_id=self.run) for i in range(n)]
        self.template = state_template
        self.total_bytes = self.engines[0].spec.total_bytes
        self.states = {i: NodeState.HEALTHY for i in range(n)}
        self.last_load_stats = None           # LoadStats of the last recover
        self._snapshots_since_ckpt = 0
        # async REFT-Ckpt rounds in flight: {"step", "parts": [(engine,
        # seq)], "t0"}; completed per-engine records keyed by (node, seq)
        self._persist_rounds: List[dict] = []
        self._persist_done: Dict[Tuple[int, int], dict] = {}
        os.makedirs(cfg.ckpt_dir, exist_ok=True)

    # ------------------------------------------------------------- save
    def snapshot(self, state: Any, step: int, extra_meta: dict = None,
                 wait: bool = True) -> bool:
        """All members snapshot iteration `step` in parallel (async).

        The list comprehension is deliberate: a short-circuiting all(gen)
        would stop asking members after the first refusal, leaving the SG
        with a partially-initiated snapshot round."""
        started = all([e.snapshot_async(state, step, extra_meta)
                       for e in self.engines
                       if self.states[e.node] == NodeState.HEALTHY])
        if wait:
            self.wait()
        return started

    def wait(self, timeout: float = 300.0) -> int:
        """Drive every member's pipeline to completion under one shared
        deadline (the members' flights run concurrently, so the budget is
        for the whole SG, not per member)."""
        deadline = time.monotonic() + timeout
        steps = []
        for e in self.engines:
            if self.states[e.node] != NodeState.HEALTHY:
                continue
            steps.append(e.wait(max(0.001, deadline - time.monotonic())))
        self._snapshots_since_ckpt += 1
        if self._snapshots_since_ckpt >= self.cfg.checkpoint_every_snapshots:
            self.checkpoint()
        return min(steps) if steps else -1

    def level_seconds(self) -> Dict[str, float]:
        """Aggregate per-level pipeline timing across members (HASC):
        l1 = device reads (+stall = scratch-credit waits), l2 = staging
        ring writes, l3 = SMP signaling + clean-ack."""
        out = {"l1": 0.0, "l1_stall": 0.0, "l2": 0.0, "l3": 0.0}
        for e in self.engines:
            out["l1"] += e.stats.get("l1_seconds", 0.0)
            out["l1_stall"] += e.stats.get("l1_stall_seconds", 0.0)
            out["l2"] += e.stats.get("l2_seconds", 0.0)
            out["l3"] += e.stats.get("l3_seconds", 0.0)
        return out

    def checkpoint_async(self, remote: Optional[dict] = None,
                         delta_base: Optional[int] = None
                         ) -> Optional[int]:
        """REFT-Ckpt, overlapped: every healthy SMP persists its shard on
        its own background thread (no trainer involvement, no trainer
        blocking).  All members persist the SAME step — the newest one
        every healthy member holds clean — so the on-disk family is
        SG-consistent and restorable.  Returns the step fired (a round
        ticket); collect with `poll_persists` / `drain_persists`.
        `remote` ({store, prefix, retry}) additionally mirrors each shard
        to the object store under `<prefix>/step-<S>/node-<N>.reft`.

        `delta_base` requests a DELTA round against an already-persisted
        step: each member writes only the bytes its flights touched since
        (`step-<S>-from-<B>-node-<N>.reftd`).  All-or-nothing — if any
        member cannot produce a chain from `delta_base` to the chosen
        step (keyframe crossed, log trimmed, engine restarted), the whole
        round falls back to full shards, keeping families uniform."""
        from repro.core.recovery import attach_survivors, common_step
        healthy = [e for e in self.engines
                   if self.states[e.node] == NodeState.HEALTHY
                   and not e.degraded]
        self._snapshots_since_ckpt = 0
        if not healthy:
            return None
        # newest step clean on EVERY healthy member (the 3-buffer rotation
        # means members that skipped a round still hold older clean steps)
        views = attach_survivors(self.run, [e.node for e in healthy],
                                 self.n, self.total_bytes)
        try:
            step = common_step(views)
        finally:
            for v in views.values():
                v.close()
        if step is None or step < 0:
            return None
        base = None
        if delta_base is not None and int(delta_base) < step:
            base = int(delta_base)
            if any(e.delta_extents_since(base, step) is None
                   for e in healthy):
                base = None                      # fall back to full shards
        parts = []
        for e in healthy:
            if base is not None:
                path = os.path.join(
                    self.cfg.ckpt_dir,
                    f"step-{step}-from-{base}-node-{e.node}.reftd")
            else:
                path = os.path.join(self.cfg.ckpt_dir,
                                    f"step-{step}-node-{e.node}.reft")
            rnode = None
            if remote:
                from repro.store.manifest import delta_shard_key, shard_key
                rnode = {k: v for k, v in remote.items() if k != "prefix"}
                prefix = remote.get("prefix", "")
                rnode["key"] = (
                    delta_shard_key(prefix, step, base, e.node)
                    if base is not None else
                    shard_key(prefix, step, e.node))
            parts.append((e, e.persist_async(path, step=step, remote=rnode,
                                             delta_base=base)))
        self._persist_rounds.append({"step": step, "parts": parts,
                                     "t0": time.monotonic(),
                                     "base_step": base})
        return step

    def _fold_round(self, rnd: dict) -> Optional[dict]:
        """Round -> completion record once every member's record is in."""
        recs = [self._persist_done.get((e.node, seq))
                for e, seq in rnd["parts"]]
        if any(r is None for r in recs):
            return None
        for e, seq in rnd["parts"]:
            self._persist_done.pop((e.node, seq), None)
        errors = [f"node{e.node}: {r['error']}"
                  for (e, _), r in zip(rnd["parts"], recs) if r["error"]]
        uploads = {e.node: r["upload"]
                   for (e, _), r in zip(rnd["parts"], recs)
                   if r.get("upload")}
        out = {"step": rnd["step"], "ok": not errors, "errors": errors,
               "seconds": time.monotonic() - rnd["t0"]}
        base = rnd.get("base_step")
        out["kind"] = "delta" if base is not None else "full"
        if base is not None:
            out["base_step"] = base
        if uploads:
            out["uploads"] = uploads
        return out

    def poll_persists(self) -> List[dict]:
        """Non-blocking: completion records ({step, ok, errors, seconds})
        of every REFT-Ckpt round whose members have all finished."""
        for e in self.engines:
            for rec in e.poll_persists():
                self._persist_done[(e.node, rec["seq"])] = rec
        out = []
        keep = []
        for rnd in self._persist_rounds:
            folded = self._fold_round(rnd)
            if folded is None:
                keep.append(rnd)
            else:
                out.append(folded)
        self._persist_rounds = keep
        return out

    def persist_inflight(self) -> int:
        return len(self._persist_rounds)

    def drain_persists(self, timeout: float = 120.0) -> List[dict]:
        """Join every outstanding REFT-Ckpt round (oldest first) under one
        shared deadline."""
        deadline = time.monotonic() + timeout
        out = self.poll_persists()
        while self._persist_rounds:
            rnd = self._persist_rounds[0]
            for e, seq in rnd["parts"]:
                if (e.node, seq) in self._persist_done:
                    continue
                if not e.has_persist_ticket(seq):   # collected or lost
                    self._persist_done[(e.node, seq)] = {
                        "seq": seq, "path": None, "step": rnd["step"],
                        "seconds": 0.0, "error": "persist record lost"}
                    continue
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(
                        f"REFT-Ckpt round for step {rnd['step']} still in "
                        f"flight after {timeout:.1f}s")
                self._persist_done[(e.node, seq)] = e.persist_join(seq, left)
            out += self.poll_persists()
        return out

    def checkpoint(self, timeout: float = 120.0) -> Optional[int]:
        """Blocking REFT-Ckpt (fire + drain); raises when the fired
        round's persists failed."""
        step = self.checkpoint_async()
        if step is None:
            return None
        rounds = self.drain_persists(timeout)
        mine = next((r for r in rounds if r["step"] == step), None)
        if mine is not None and not mine["ok"]:
            raise RuntimeError(
                f"REFT-Ckpt persist failed: {'; '.join(mine['errors'])}")
        return step

    # ---------------------------------------------------------- failure
    def inject_software_failure(self, node: int):
        """Trainer process dies; SMP and its segments survive."""
        self.states[node] = NodeState.UNHEALTHY

    def inject_node_failure(self, node: int):
        """Whole node dies: SMP killed, volatile memory wiped."""
        e = self.engines[node]
        e.smp.kill()
        from repro.core.smp import ReadOnlyNode
        ReadOnlyNode.unlink_node(self.run, node)
        self.states[node] = NodeState.OFFLINE

    # ---------------------------------------------------------- recover
    def recover(self, target=None) -> Tuple[Any, int, dict, str]:
        """Returns (state, step, extra_meta, tier) per the 3-tier policy.
        `target` (a `repro.api.RestoreTarget`) restricts the load plan;
        the per-phase `LoadStats` of the last recover is kept on
        `self.last_load_stats`."""
        from repro.api.backends import reft_recovery_ladder
        alive = [i for i in range(self.n)
                 if self.states[i] != NodeState.OFFLINE]
        res = reft_recovery_ladder(self.run, self.n, self.total_bytes,
                                   self.template, alive, self.cfg.ckpt_dir,
                                   target=target)
        self.last_load_stats = res.load
        return res.state, res.step, res.extra_meta, res.tier

    def heal(self, node: int):
        """Elastic replacement node rejoins (new SMP).  A degraded member
        (its SMP died under it) needs a respawn just like an offline one —
        as does one whose SMP is dead but not yet *noticed* (killed between
        snapshots, so no send ever raised and `degraded` never flipped)."""
        e = self.engines[node]
        if self.states[node] == NodeState.OFFLINE or e.degraded \
                or not e.smp.alive():
            try:
                e.close()                     # drop stale segments/handles
            except Exception:
                pass
            self.engines[node] = SnapshotEngine(
                node, self.n, self.template, self.cfg, run_id=self.run)
        self.states[node] = NodeState.HEALTHY

    def close(self):
        for e in self.engines:
            try:
                e.close()
            except Exception:
                pass


class Reft:
    """User-facing per-trainer facade: policy-scheduled REFT-Sn + REFT-Ckpt.

    With ``auto=True`` it implements Appendix A's adaptive policy: it
    benchmarks the observed per-step compute time and per-snapshot saving
    time, derives the effective overhead (Eq. 8) and the optimal snapshot
    interval (Eq. 9 with the single-node failure rate), and re-tunes
    ``snapshot_every`` on the fly.

    >>> reft = Reft(group, auto=True, lam_node=1e-4)
    >>> for step, batch in enumerate(data):
    ...     state, _ = train_step(state, batch)
    ...     reft.maybe_snapshot(state, step, extra_meta=data.state())
    """

    def __init__(self, group: ReftGroup, plan: FrequencyPlan = None,
                 snapshot_every: int = 1, *, auto: bool = False,
                 lam_node: float = 1e-4, warmup: int = 4):
        self.group = group
        self.plan = plan
        self.snapshot_every = snapshot_every
        self.auto = auto
        self.lam_node = lam_node
        self.warmup = warmup
        self._last = -1
        self._last_call_t: Optional[float] = None
        self._step_times: List[float] = []

    def _retune(self):
        from repro.core.policy import (effective_save_overhead,
                                       optimal_interval)
        stats = [e.stats for e in self.group.engines
                 if e.stats["snapshots"] > 0]
        if not stats or len(self._step_times) < self.warmup:
            return
        t_comp = sum(self._step_times[-self.warmup:]) / self.warmup
        t_sn = max(s["seconds"] / s["snapshots"] for s in stats)
        o_save = effective_save_overhead(t_sn, t_comp)
        t_opt = optimal_interval(o_save, self.lam_node)
        # interval in steps; o_save==0 -> snapshot every step (Figure 4)
        self.snapshot_every = max(1, int(t_opt / max(t_comp, 1e-9)))

    def maybe_snapshot(self, state, step, extra_meta=None, wait=False):
        now = time.time()
        if self._last_call_t is not None:
            self._step_times.append(now - self._last_call_t)
        self._last_call_t = now
        if self.auto:
            self._retune()
        if step - self._last >= self.snapshot_every:
            if self.group.snapshot(state, step, extra_meta, wait=wait):
                self._last = step
                return True
        return False
