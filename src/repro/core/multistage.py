"""Multi-stage (3D-parallel) REFT: one sharding group per pipeline stage.

The paper's full setting: the model is cut into `n_pp` stage slices; all
DP replicas of one stage form an SG ("all PP_0 nodes formulate SG_0",
Fig. 5).  Each SG protects *its stage's* slice independently, so failures
in different stages recover concurrently, and a single node loss per SG —
up to one per stage simultaneously — is decodable.

`MultiStageGroup` composes per-stage `ReftGroup`s over a stage-partitioned
train state.  Stage slicing is by the flat byte stream (same machinery as
SG-internal sharding), which mirrors how PP assigns contiguous layer
blocks to stages.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.core.coordinator import NodeState, ReftGroup
from repro.core.snapshot import ReftConfig
from repro.core.treebytes import (buffer_to_tree, make_flat_spec,
                                  tree_to_buffer)


def split_state_by_stage(state: Any, n_pp: int) -> List[Dict]:
    """Partition the pytree's leaves into n_pp contiguous groups of
    roughly equal bytes (PP layer assignment analogue).

    Returns per-stage {"leaves": {idx: array}} trees; leaf indices refer
    to the flatten order so the full state can be reassembled.
    """
    flat, _ = jax.tree_util.tree_flatten(state)
    sizes = [np.asarray(x).nbytes for x in flat]
    total = sum(sizes)
    target = total / n_pp
    stages: List[Dict] = [{} for _ in range(n_pp)]
    acc, si = 0.0, 0
    for i, (leaf, sz) in enumerate(zip(flat, sizes)):
        if acc >= target * (si + 1) and si < n_pp - 1:
            si += 1
        stages[si][f"leaf{i:04d}"] = leaf
        acc += sz
    return stages


def join_stages(template: Any, stage_trees: List[Dict]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten(template)
    out = list(flat)
    for st in stage_trees:
        for key, leaf in st.items():
            out[int(key[4:])] = leaf
    return jax.tree_util.tree_unflatten(treedef, out)


class MultiStageGroup:
    """REFT over an n_pp x dp grid of simulated nodes (one SG per stage)."""

    def __init__(self, n_pp: int, dp: int, state_template: Any,
                 cfg: Optional[ReftConfig] = None):
        # NB: a `cfg=ReftConfig()` default would be evaluated once at class
        # definition — every default-constructed grid would share one
        # run_id (one shm namespace); construct a fresh config per call.
        cfg = cfg if cfg is not None else ReftConfig()
        self.n_pp, self.dp = n_pp, dp
        self.template = state_template
        self.last_load_stats = None   # per-stage LoadStats of last recover
        self.stage_templates = split_state_by_stage(state_template, n_pp)
        self.groups: List[ReftGroup] = []
        for s, st in enumerate(self.stage_templates):
            scfg = dataclasses.replace(
                cfg, run_id=f"{cfg.run_id}-pp{s}",
                ckpt_dir=f"{cfg.ckpt_dir}/pp{s}")
            self.groups.append(ReftGroup(dp, st, scfg))

    def snapshot(self, state: Any, step: int, extra_meta: dict = None,
                 wait: bool = True) -> bool:
        """Launch every stage's per-member pipelines first (all SGs' L1
        pumps overlap), then optionally drain them under one deadline."""
        stage_states = split_state_by_stage(state, self.n_pp)
        ok = True
        for g, st in zip(self.groups, stage_states):
            ok &= g.snapshot(st, step, extra_meta, wait=False)
        if wait:
            self.wait()
        return ok

    def wait(self, timeout: float = 300.0) -> int:
        """Drain all stages' in-flight pipelines; the shared deadline spans
        the whole grid since the flights run concurrently.  Returns the min
        consistent step across stages (-1 when nothing completed)."""
        deadline = time.monotonic() + timeout
        steps = [g.wait(max(0.001, deadline - time.monotonic()))
                 for g in self.groups]
        return min(steps) if steps else -1

    def level_seconds(self) -> Dict[str, float]:
        out = {"l1": 0.0, "l1_stall": 0.0, "l2": 0.0, "l3": 0.0}
        for g in self.groups:
            for k, v in g.level_seconds().items():
                out[k] += v
        return out

    def checkpoint(self):
        for g in self.groups:
            g.checkpoint()

    def inject_node_failure(self, stage: int, member: int):
        self.groups[stage].inject_node_failure(member)

    def inject_software_failure(self, stage: int, member: int):
        self.groups[stage].inject_software_failure(member)

    def recover(self, target=None) -> Tuple[Any, int, str]:
        """Stage-local recovery; the restart step is the min consistent
        step across stages (synchronous training keeps them equal).  Each
        stage's SG runs its own `LoadPlan` (ranged parallel reads +
        range-limited decode); the per-stage `LoadStats` land in
        `self.last_load_stats` (list, one per stage)."""
        stage_states = []
        steps = []
        tiers = []
        self.last_load_stats = []
        for g in self.groups:
            st, step, _, tier = g.recover(target=target)
            stage_states.append(st)
            steps.append(step)
            tiers.append(tier)
            self.last_load_stats.append(getattr(g, "last_load_stats", None))
        assert len(set(steps)) == 1, f"stage steps diverged: {steps}"
        worst = max(tiers, key=["in-memory", "raim5", "checkpoint"].index)
        return join_stages(self.template, stage_states), steps[0], worst

    def heal_all(self):
        for g in self.groups:
            for i in range(self.dp):
                g.heal(i)
            g.states = {i: NodeState.HEALTHY for i in range(self.dp)}

    def close(self):
        for g in self.groups:
            g.close()
