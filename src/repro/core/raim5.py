"""RAIM5 — Redundant Array of Independent Memory 5 (paper §4.3).

The SG's full state (W bytes) is cut into n stripes x (n-1) equal blocks.
Layout (matches Figure 7): stripe s keeps its parity on node s; data block
j of stripe s lives on node (s + 1 + j) mod n.  Each node therefore:

  * persists (n-1) data blocks  (its 1/n shard of W), and
  * additionally snapshots the (n-1) blocks of its parity stripe —
    "doubling the snapshotting parameter size" — XORs them locally into
    one parity block, then releases them.

Any single node loss per SG is decodable: the dead node's parity is
re-encoded from survivors, and each of its data blocks is XOR-decoded from
its stripe's parity + surviving siblings.

XOR runs on uint64 lanes on the host (paper: "byte-wise on the CPU"); the
TPU-side Pallas kernels (kernels/xor_parity.py, kernels/stage.py) are the
beyond-paper on-accelerator variant.  Decode is encode-agnostic: XOR is
its own inverse and the device encode path produces byte-identical parity
blocks, so `decode_node` reconstructs kernel-encoded and host-encoded
snapshots alike — no format flag, no second path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


def block_size(total_bytes: int, n: int) -> int:
    """Equal block size (padded up) for n nodes: n*(n-1) blocks cover W."""
    nblocks = n * (n - 1)
    return -(-total_bytes // nblocks)           # ceil


@dataclass(frozen=True)
class BlockRef:
    stripe: int
    index: int                                   # data block index in stripe

    def byte_range(self, bs: int, n: int) -> Tuple[int, int]:
        blk = self.stripe * (n - 1) + self.index
        return blk * bs, (blk + 1) * bs


def node_of_block(stripe: int, index: int, n: int) -> int:
    return (stripe + 1 + index) % n


def data_blocks_of_node(node: int, n: int) -> List[BlockRef]:
    """The (n-1) data blocks stored on `node` (one per stripe != node)."""
    out = []
    for s in range(n):
        if s == node:
            continue
        j = (node - s - 1) % n
        assert node_of_block(s, j, n) == node and 0 <= j < n - 1
        out.append(BlockRef(s, j))
    return out


def local_block_index(node: int, stripe: int, index: int, n: int) -> int:
    """Slot of data block (stripe, index) within `node`'s local shard
    (the `data_blocks_of_node` order every store layout follows)."""
    refs = data_blocks_of_node(node, n)
    return next(i for i, r in enumerate(refs)
                if (r.stripe, r.index) == (stripe, index))


def parity_stripe_of_node(node: int, n: int) -> List[BlockRef]:
    """Blocks XOR-ed into the parity that `node` stores (its own stripe)."""
    return [BlockRef(node, j) for j in range(n - 1)]


def snapshot_ranges(node: int, n: int, total_bytes: int
                    ) -> List[Tuple[int, int]]:
    """Byte ranges this node must snapshot: own data blocks + parity-stripe
    blocks (the doubled traffic of §4.3), clipped to total_bytes."""
    bs = block_size(total_bytes, n)
    refs = data_blocks_of_node(node, n) + parity_stripe_of_node(node, n)
    out = []
    for r in refs:
        lo, hi = r.byte_range(bs, n)
        out.append((min(lo, total_bytes), min(hi, total_bytes)))
    return out


def xor_blocks(blocks: Sequence[np.ndarray]) -> np.ndarray:
    """XOR-reduce equal-length byte blocks on uint64 lanes."""
    assert blocks, "no blocks"
    n = blocks[0].nbytes
    pad = (-n) % 8
    acc = None
    for b in blocks:
        assert b.nbytes == n
        v = b.reshape(-1).view(np.uint8)
        if pad:
            v = np.concatenate([v, np.zeros(pad, np.uint8)])
        v64 = v.view(np.uint64)
        acc = v64.copy() if acc is None else np.bitwise_xor(acc, v64, out=acc)
    return acc.view(np.uint8)[:n]


def encode_parity(node: int, n: int, full_state: np.ndarray) -> np.ndarray:
    """Parity block for `node`'s stripe, from the (replicated) full state.
    Blocks beyond total_bytes are zero-padded (XOR identity)."""
    bs = block_size(full_state.nbytes, n)
    blocks = []
    for ref in parity_stripe_of_node(node, n):
        lo, hi = ref.byte_range(bs, n)
        blk = np.zeros(bs, np.uint8)
        a, b = min(lo, full_state.nbytes), min(hi, full_state.nbytes)
        if b > a:
            blk[:b - a] = full_state[a:b]
        blocks.append(blk)
    return xor_blocks(blocks)


def decode_node(failed: int, n: int, total_bytes: int,
                read_block, read_parity) -> Dict[Tuple[int, int], np.ndarray]:
    """Reconstruct every data block of `failed`.

    read_block(node, stripe, index) -> np.uint8[bs]   (from survivor SMPs)
    read_parity(node) -> np.uint8[bs]
    Returns {(stripe, index): bytes} for the failed node's blocks.
    """
    bs = block_size(total_bytes, n)
    out = {}
    for ref in data_blocks_of_node(failed, n):
        s = ref.stripe
        assert s != failed
        siblings = [read_block(node_of_block(s, j, n), s, j)
                    for j in range(n - 1) if j != ref.index]
        parity = read_parity(s)                  # stripe s parity on node s
        out[(s, ref.index)] = xor_blocks(siblings + [parity])
    return out


# ----------------------------------------------------- range-limited decode
def blocks_intersecting(failed: int, n: int, total_bytes: int,
                        ranges: Sequence[Tuple[int, int]]
                        ) -> List[Tuple[BlockRef, List[Tuple[int, int]]]]:
    """`failed`'s data blocks whose global byte span intersects `ranges`,
    each with the block-LOCAL sub-ranges [(o1, o2), ...] that do.

    `ranges` must be sorted, disjoint global [lo, hi) pairs.  This is the
    planning half of range-limited decode: a restore that only needs a
    few byte ranges of a lost member pays XOR + sibling reads for exactly
    the intersecting stripe sub-ranges, not the whole shard."""
    bs = block_size(total_bytes, n)
    out: List[Tuple[BlockRef, List[Tuple[int, int]]]] = []
    for ref in data_blocks_of_node(failed, n):
        g_lo, g_hi = ref.byte_range(bs, n)
        g_hi = min(g_hi, total_bytes)
        subs = []
        for a, b in ranges:
            a2, b2 = max(a, g_lo), min(b, g_hi)
            if b2 > a2:
                subs.append((a2 - g_lo, b2 - g_lo))
        if subs:
            out.append((ref, subs))
    return out


def decode_node_ranges(failed: int, n: int, total_bytes: int,
                       ranges: Sequence[Tuple[int, int]],
                       read_block_range, read_parity_range
                       ) -> Dict[Tuple[int, int],
                                 List[Tuple[int, int, np.ndarray]]]:
    """Reconstruct only the sub-ranges of `failed`'s blocks that intersect
    the global byte `ranges` (sorted, disjoint).

    XOR decode is byte-wise, so a lost block's bytes [o1, o2) are exactly
    the XOR of the SAME offsets of its stripe's surviving siblings and
    parity — no whole-block (let alone whole-shard) decode is needed:

      read_block_range(node, stripe, index, o1, o2) -> np.uint8[o2-o1]
      read_parity_range(stripe, o1, o2)             -> np.uint8[o2-o1]

    Returns {(stripe, index): [(o1, o2, bytes), ...]} covering only the
    requested intersections.
    """
    out: Dict[Tuple[int, int], List[Tuple[int, int, np.ndarray]]] = {}
    for ref, subs in blocks_intersecting(failed, n, total_bytes, ranges):
        s = ref.stripe
        assert s != failed
        pieces = []
        for o1, o2 in subs:
            parts = [read_block_range(node_of_block(s, j, n), s, j, o1, o2)
                     for j in range(n - 1) if j != ref.index]
            parts.append(read_parity_range(s, o1, o2))
            pieces.append((o1, o2, xor_blocks(parts)))
        out[(s, ref.index)] = pieces
    return out


def reassemble(n: int, total_bytes: int, read_block,
               recovered: Dict[Tuple[int, int], np.ndarray] = None
               ) -> np.ndarray:
    """Full state bytes from all data blocks (survivors + recovered)."""
    bs = block_size(total_bytes, n)
    recovered = recovered or {}
    full = np.zeros(n * (n - 1) * bs, np.uint8)
    for s in range(n):
        for j in range(n - 1):
            lo, hi = BlockRef(s, j).byte_range(bs, n)
            blk = recovered.get((s, j))
            if blk is None:
                blk = read_block(node_of_block(s, j, n), s, j)
            full[lo:hi] = blk
    return full[:total_bytes]
