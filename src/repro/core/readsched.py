"""Bandwidth-adaptive restore read scheduling (the straggler-aware loader).

`loader.execute_plan`'s legacy path hands each surviving member's ranged
reads to one first-come-first-served task, so one slow survivor (a
SIGSTOP'd SMP, a throttled NIC, a cold object-store shard) sets the
restore wall clock.  This module replaces that read side with a chunked
work-queue scheduler:

  * **Chunking + work stealing.**  Each member's reads are split into
    fixed-size, RAIM5-block-aligned chunks on per-source queues.  Workers
    have a home source (affinity keeps the streamed-CRC read pattern
    mostly sequential) but steal queued chunks from the source with the
    worst projected finish time instead of idling at the barrier.
  * **EWMA bandwidth model.**  `SourceBandwidth` folds live per-chunk
    timings into a per-source bandwidth estimate, seeded from priors the
    recovery ladder passes down (previous `LoadStats` / the supervisor's
    `FailureObserver`).
  * **Parity-alternative routing.**  RAIM5 parity today only serves
    *dead* members.  When a slow-but-alive member's projected finish
    exceeds `reroute_factor` x the cost of XOR-reconstructing its
    remaining plan bytes from siblings + parity, the scheduler converts
    those queued chunks into decode work mid-flight.  Single-parity
    budget: at most ONE member is ever rerouted, and only when the plan
    has no failed member.
  * **Hedged tail reads.**  A chunk running far past its bandwidth-model
    expectation gets a duplicate read; first finisher wins the claim,
    the loser is cooperatively cancelled between sub-reads.  Claims are
    CAS-style under the scheduler lock, so no byte range is ever written
    twice (the `LeafSink` per-leaf countdown depends on that).
  * **Pipelined decode.**  Planned decode (a failed member) and rerouted
    decode run as chunk-sized work items on the same worker pool, so XOR
    + parity reads overlap remaining direct I/O instead of serializing
    behind a read barrier.

Byte-identity with the FCFS oracle is the hard invariant: every direct
chunk carries exactly the plan's scatter pieces, rerouted chunks decode
exactly those piece ranges, and verification is preserved — fully-read
members fold per-chunk CRCs (``crc32_combine``) into the recorded
``crc_own``; a rerouted member's directly-read blocks are checked against
its per-stripe digest table instead (reroute requires the table).
"""
from __future__ import annotations

import threading
from repro.analyze.lockgraph import named_condition, named_lock
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from . import raim5
from .crcutil import crc32_combine

# chunk states
_PENDING, _RUNNING, _DONE, _REROUTED = 0, 1, 2, 3


@dataclass(frozen=True)
class SchedConfig:
    """Knobs for the adaptive read scheduler (see module docstring).

    mode: "fcfs" (legacy single-task-per-member path), "steal" (chunked
    queues + work stealing + pipelined decode), or "adaptive" (steal +
    parity-alternative routing + hedged tail reads)."""
    mode: str = "adaptive"
    chunk_bytes: int = 8 << 20
    ewma_alpha: float = 0.4          # weight of the newest chunk timing
    min_samples: int = 1             # live samples before reroute may fire
    reroute_factor: float = 2.0      # direct ETA must exceed this x decode ETA
    min_eta_s: float = 0.05          # ETA floor before reroute pays at all
    hedge_factor: float = 4.0        # chunk age vs expected before hedging
    max_hedges: int = 4              # duplicate reads per restore, total
    inflight_per_source: int = 2     # concurrent readers against one source
    restore_bw_limit: float = 0.0    # bytes/s token bucket (0 = unthrottled)
    workers: Optional[int] = None
    priors: Mapping[str, float] = field(default_factory=dict)  # "kind:node"


class SourceBandwidth:
    """Thread-safe per-source EWMA bandwidth estimates (bytes/second).

    Priors seed the estimate but count zero live samples — decisions
    gated on `min_samples` (parity reroute) wait for real chunk timings;
    steal/hedge heuristics may use the seeded value immediately."""

    def __init__(self, alpha: float = 0.4,
                 priors: Optional[Mapping[str, float]] = None):
        self.alpha = float(alpha)
        self._bw: Dict[str, float] = {}
        self._n: Dict[str, int] = {}
        self._dead: set = set()
        self._lock = named_lock("readsched.bw")
        for k, v in (priors or {}).items():
            if v and v > 0:
                self._bw[k] = float(v)
                self._n[k] = 0

    def observe(self, key: str, nbytes: int, seconds: float) -> None:
        if seconds <= 1e-9 or nbytes <= 0:
            return
        sample = nbytes / seconds
        with self._lock:
            prev = self._bw.get(key)
            self._bw[key] = sample if prev is None else (
                self.alpha * sample + (1.0 - self.alpha) * prev)
            self._n[key] = self._n.get(key, 0) + 1

    def bandwidth(self, key: str) -> Optional[float]:
        with self._lock:
            if key in self._dead:
                return None
            return self._bw.get(key)

    def samples(self, key: str) -> int:
        with self._lock:
            return self._n.get(key, 0)

    def mark_dead(self, key: str) -> None:
        with self._lock:
            self._dead.add(key)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return {k: v for k, v in self._bw.items() if k not in self._dead}


class CancelToken:
    """Cooperative cancellation flag, checked between sub-reads."""
    __slots__ = ("cancelled",)

    def __init__(self):
        self.cancelled = False


class SourceLost(RuntimeError):
    """A member's source died mid-read and its chunks could not be
    cleanly converted to parity decode.  The recovery ladder treats this
    like a digest mismatch: demote `node` and re-plan."""

    def __init__(self, node: int, reason: str = ""):
        self.node = node
        super().__init__(reason or f"node {node} source lost mid-restore")


class ThrottledSource:
    """Deterministic slow-source wrapper for tests and benchmarks.

    Serializes each node's reads behind a per-node lock and sleeps
    `nbytes / bw` after the inner read, so node `k`'s effective bandwidth
    is exactly `bw_bytes_s[k]` regardless of reader concurrency — the
    shape of a laggard SMP / throttled NIC.  Parity reads are charged to
    the stripe's holder.  Deliberately exposes no `read_local_ranges`,
    forcing the per-piece path so every byte is throttled."""

    def __init__(self, inner, bw_bytes_s: Mapping[int, float],
                 default_bw: float = float("inf")):
        self._inner = inner
        self._bw = dict(bw_bytes_s)
        self._default = float(default_bw)
        self._locks: Dict[int, threading.Lock] = {}
        self._guard = named_lock("readsched.throttle.guard")
        self.kind = f"slow+{getattr(inner, 'kind', '')}"

    def _charge(self, node: int, nbytes: int):
        bw = self._bw.get(node, self._default)
        with self._guard:
            lk = self._locks.setdefault(
                node, named_lock("readsched.throttle.src"))
        with lk:
            if bw != float("inf") and bw > 0 and nbytes > 0:
                time.sleep(nbytes / bw)

    def nodes(self):
        return self._inner.nodes()

    def meta(self, node: int) -> dict:
        return self._inner.meta(node)

    def read_local(self, node: int, lo: int, hi: int) -> np.ndarray:
        data = self._inner.read_local(node, lo, hi)
        self._charge(node, hi - lo)
        return data

    def read_block_range(self, node: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        data = self._inner.read_block_range(node, stripe, index, o1, o2)
        self._charge(node, o2 - o1)
        return data

    def read_parity_range(self, stripe: int, o1: int, o2: int) -> np.ndarray:
        data = self._inner.read_parity_range(stripe, o1, o2)
        self._charge(stripe, o2 - o1)
        return data

    def __getattr__(self, name):
        if name in ("read_local_ranges", "locate_spans"):
            raise AttributeError(name)    # force the throttled per-piece path
        return getattr(self._inner, name)


class BucketedSource:
    """Source wrapper charging every read against a shared token bucket —
    the read-side `restore_bw_limit` mirroring the SMP persist worker's
    `persist_bw_limit` (restore reads on a survivor otherwise compete
    unthrottled with its live training / persist traffic)."""

    def __init__(self, inner, bucket):
        self._inner = inner
        self.bucket = bucket
        self.kind = getattr(inner, "kind", "")
        batched = getattr(inner, "read_local_ranges", None)
        if batched is not None:
            def read_local_ranges(node, ranges, _b=batched):
                self.bucket.consume(sum(b - a for a, b in ranges))
                return _b(node, ranges)
            self.read_local_ranges = read_local_ranges

    def nodes(self):
        return self._inner.nodes()

    def meta(self, node: int) -> dict:
        return self._inner.meta(node)

    def read_local(self, node: int, lo: int, hi: int) -> np.ndarray:
        self.bucket.consume(hi - lo)
        return self._inner.read_local(node, lo, hi)

    def read_block_range(self, node: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        self.bucket.consume(o2 - o1)
        return self._inner.read_block_range(node, stripe, index, o1, o2)

    def read_parity_range(self, stripe: int, o1: int, o2: int) -> np.ndarray:
        self.bucket.consume(o2 - o1)
        return self._inner.read_parity_range(stripe, o1, o2)

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _Chunk:
    __slots__ = ("cid", "node", "lo", "hi", "pieces", "vfull", "block",
                 "state", "crc", "hedges", "t_start", "nbytes")

    def __init__(self, cid, node, lo, hi, pieces, vfull, block, nbytes):
        self.cid = cid
        self.node = node
        self.lo = lo                   # local span (verify chunks read all
        self.hi = hi                   # of it; gather chunks just bound it)
        self.pieces = pieces           # [(local_a, local_b, global_a)]
        self.vfull = vfull             # part of a full-region CRC stream
        self.block = block             # local RAIM5 block index (n > 1)
        self.state = _PENDING
        self.crc = 0
        self.hedges = 0
        self.t_start = 0.0
        self.nbytes = nbytes           # bytes a reader must pull


class ChunkScheduler:
    """Executes one `LoadPlan` through the chunked work-stealing path.
    Built per restore attempt; `run()` raises `CrcMismatch` / `SourceLost`
    exactly where the legacy executor raises `CrcMismatch`, so the
    recovery ladder's demote-and-replan loop drives both paths."""

    def __init__(self, plan, source, sink, *, verify: bool,
                 cfg: SchedConfig, stats) -> None:
        from .loader import LoadStats   # lazy: avoid import cycle
        self.plan = plan
        self.source = source
        self.sink = sink
        self.verify = verify
        self.cfg = cfg
        self.st = stats if stats is not None else LoadStats()
        self.n = plan.n
        self.bs = raim5.block_size(plan.total_bytes, plan.n) \
            if plan.n > 1 else 0
        self.own_bytes = (plan.total_bytes if plan.n == 1
                          else (plan.n - 1) * self.bs)
        self.kind = getattr(source, "kind", "")
        self.bw = SourceBandwidth(cfg.ewma_alpha, cfg.priors)

        self.cond = named_condition("readsched.sched")
        self.error: Optional[BaseException] = None
        self.chunks: List[_Chunk] = []
        self.queues: Dict[int, deque] = {}        # node -> deque of cids
        self.pending_bytes: Dict[int, int] = {}
        self.inflight: Dict[int, int] = {}
        self.direct_left = 0
        self.writes_out = 0
        self.decode_q: deque = deque()            # (ref, o1, o2, g, origin)
        self.decode_inflight = 0
        self.rerouted: Optional[int] = None
        self.hedges_issued = 0
        self._tokens: Dict[int, List[CancelToken]] = {}
        self._parity_ok: set = set()
        self._parity_lock = named_lock("readsched.parity")
        # timing attribution (perf_counter stamps)
        self.t0 = 0.0
        self.t_read_end = 0.0
        self.d_start = float("inf")
        self.d_end = 0.0
        # verify bookkeeping
        self.expected: Dict[int, Any] = {}        # node -> crc_own | None
        self.vfull_nodes: set = set()
        self.node_chunks: Dict[int, List[_Chunk]] = {}
        self.node_left: Dict[int, int] = {}       # direct chunks not DONE
        self.block_chunks: Dict[int, Dict[int, List[_Chunk]]] = {}
        self.block_left: Dict[int, Dict[int, int]] = {}
        self.stripe_crcs: Dict[int, List[int]] = {}   # rerouted-node tables

    def _bwkey(self, node: int) -> str:
        return f"{self.kind}:{node}"

    # ------------------------------------------------------------ prepare
    def _prepare(self) -> None:
        from .loader import CrcMismatch, _META_BAD, stripe_table
        plan = self.plan
        if self.verify:
            for node in plan.reads:
                try:
                    self.expected[node] = self.source.meta(node).get(
                        "crc_own")
                except Exception:
                    # unreadable meta = untrustworthy member: demote like a
                    # digest mismatch, same as the legacy read path
                    raise CrcMismatch(
                        node, reason=f"node {node} snapshot meta unreadable")
        cid = 0
        for node in sorted(plan.reads):
            reqs = plan.reads[node]
            expect = self.expected.get(node)
            vfull = (self.verify and expect is not None
                     and plan.member_covered(node))
            chunks: List[_Chunk] = []
            if vfull:
                self.vfull_nodes.add(node)
                chunks = self._tile_full(node, reqs, cid)
            else:
                chunks = self._tile_gather(node, reqs, cid)
            cid += len(chunks)
            self.chunks.extend(chunks)
            self.node_chunks[node] = chunks
            self.node_left[node] = len(chunks)
            self.queues[node] = deque(c.cid for c in chunks)
            self.pending_bytes[node] = sum(c.nbytes for c in chunks)
            self.inflight[node] = 0
            self.direct_left += len(chunks)
            if self.n > 1:
                per_blk: Dict[int, List[_Chunk]] = {}
                for c in chunks:
                    per_blk.setdefault(c.block, []).append(c)
                self.block_chunks[node] = per_blk
                self.block_left[node] = {b: len(cs)
                                         for b, cs in per_blk.items()}
        # planned decode (failed member) -> chunk-sized pipeline items
        step = max(1, self.cfg.chunk_bytes)
        for ref, subs in plan.decode:
            g_base = ref.byte_range(self.bs, self.n)[0]
            for o1, o2 in subs:
                for a in range(o1, o2, step):
                    b = min(a + step, o2)
                    self.decode_q.append((ref, a, b, g_base + a, "plan"))
        # parity-alternative routing preconditions (fixed for the run)
        self.can_reroute = (
            self.cfg.mode == "adaptive"
            and plan.failed is None
            and self.n > 1
            and set(plan.reads) == set(range(self.n))
            and not hasattr(self.source, "locate_spans"))  # chains overlay
        if self.can_reroute:
            for node in plan.reads:
                if node not in self.vfull_nodes:
                    continue
                try:
                    table = stripe_table(self.source.meta(node))
                except Exception:
                    table = None
                # the digest table (seg == block) is what lets a rerouted
                # member's directly-read blocks still be verified
                if table is not None and table[0] == self.bs:
                    self.stripe_crcs[node] = table[1]

    def _tile_full(self, node: int, reqs, cid0: int) -> List[_Chunk]:
        """Contiguous chunks tiling the FULL own region [0, own_bytes)
        (incl. tail padding the engine checksummed), block-aligned so a
        chunk never crosses a RAIM5 block boundary."""
        cb = max(1, self.cfg.chunk_bytes)
        out: List[_Chunk] = []
        ri = 0
        bounds = ([(0, self.own_bytes)] if self.n == 1 else
                  [(li * self.bs, (li + 1) * self.bs)
                   for li in range(self.n - 1)])
        for li, (blo, bhi) in enumerate(bounds):
            for lo in range(blo, bhi, cb):
                hi = min(lo + cb, bhi)
                pieces = []
                while ri < len(reqs) and reqs[ri].local_lo < hi:
                    r = reqs[ri]
                    a, b = max(r.local_lo, lo), min(r.local_hi, hi)
                    if b > a:
                        pieces.append((a, b, r.global_lo + (a - r.local_lo)))
                    if r.local_hi <= hi:
                        ri += 1
                    else:
                        break
                out.append(_Chunk(cid0 + len(out), node, lo, hi,
                                  tuple(pieces), True, li, hi - lo))
        return out

    def _tile_gather(self, node: int, reqs, cid0: int) -> List[_Chunk]:
        """Chunks over exactly the needed local ranges (partial plans /
        unverified members): block-aligned splits, packed up to
        chunk_bytes / 256 pieces per chunk within one block."""
        cb = max(1, self.cfg.chunk_bytes)
        segs: List[Tuple[int, int, int, int]] = []     # (a, b, g, block)
        for r in reqs:
            a = r.local_lo
            while a < r.local_hi:
                li = a // self.bs if self.n > 1 else 0
                cut = (li + 1) * self.bs if self.n > 1 else r.local_hi
                b = min(r.local_hi, cut, a + cb)
                segs.append((a, b, r.global_lo + (a - r.local_lo), li))
                a = b
        out: List[_Chunk] = []
        i = 0
        while i < len(segs):
            blk = segs[i][3]
            pieces = []
            acc = 0
            while (i < len(segs) and segs[i][3] == blk
                   and acc < cb and len(pieces) < 256):
                a, b, g, _ = segs[i]
                pieces.append((a, b, g))
                acc += b - a
                i += 1
            out.append(_Chunk(cid0 + len(out), node, pieces[0][0],
                              pieces[-1][1], tuple(pieces), False, blk, acc))
        return out

    # ---------------------------------------------------------- scheduling
    def _set_error(self, e: BaseException) -> None:
        from .loader import CrcMismatch
        # CrcMismatch beats secondaries: a concurrent member's transient
        # read error must not mask the demote-and-replan signal
        if self.error is None or (isinstance(e, CrcMismatch)
                                  and not isinstance(self.error,
                                                     CrcMismatch)):
            self.error = e

    def _pop_node(self, node: int) -> Optional[_Chunk]:
        q = self.queues.get(node)
        if not q or self.inflight[node] >= self.cfg.inflight_per_source:
            return None
        while q:
            c = self.chunks[q.popleft()]
            if c.state != _PENDING:
                continue                     # rerouted while queued
            c.state = _RUNNING
            c.t_start = time.perf_counter()
            self.inflight[node] += 1
            self.pending_bytes[node] -= c.nbytes
            return c
        return None

    def _estimate(self, node: int, fallback: float) -> float:
        bw = self.bw.bandwidth(self._bwkey(node))
        return bw if bw and bw > 0 else fallback

    def _median_bw(self) -> float:
        vals = sorted(v for v in self.bw.snapshot().values() if v > 0)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def _steal_victim(self, home: int) -> Optional[int]:
        fb = self._median_bw() or 1.0
        best, best_eta = None, 0.0
        for node, q in self.queues.items():
            if node == home or not q:
                continue
            if self.inflight[node] >= self.cfg.inflight_per_source:
                continue
            if self.pending_bytes[node] <= 0:
                continue
            eta = self.pending_bytes[node] / self._estimate(node, fb)
            if best is None or eta > best_eta:
                best, best_eta = node, eta
        return best

    def _all_done(self) -> bool:
        return (self.direct_left == 0 and not self.decode_q
                and self.decode_inflight == 0 and self.writes_out == 0)

    def _next(self, wid: int, home: int):
        with self.cond:
            while True:
                if self.error is not None:
                    return None
                if self.decode_q:
                    item = self.decode_q.popleft()
                    self.decode_inflight += 1
                    return ("decode", item)
                c = self._pop_node(home)
                if c is not None:
                    return ("chunk", c)
                victim = self._steal_victim(home)
                if victim is not None:
                    c = self._pop_node(victim)
                    if c is not None:
                        self.st.stolen_chunks += 1
                        return ("chunk", c)
                if self.cfg.mode == "adaptive":
                    self._maybe_reroute()
                    if self.decode_q:
                        continue
                    h = self._hedge_candidate()
                    if h is not None:
                        h.hedges += 1
                        self.hedges_issued += 1
                        self.st.hedged_reads += 1
                        return ("hedge", h)
                if self._all_done():
                    self.cond.notify_all()
                    return None
                self.cond.wait(0.05)

    def _hedge_candidate(self) -> Optional[_Chunk]:
        if self.hedges_issued >= self.cfg.max_hedges:
            return None
        fb = self._median_bw()
        if fb <= 0:
            return None
        now = time.perf_counter()
        for c in self.chunks:
            if c.state != _RUNNING or c.hedges:
                continue
            expect = c.nbytes / self._estimate(c.node, fb)
            if now - c.t_start > self.cfg.hedge_factor * max(expect, 1e-4):
                return c
        return None

    # ------------------------------------------------- parity reroute
    def _reroutable(self, node: int) -> bool:
        if not self.can_reroute or self.rerouted not in (None, node):
            return False
        if node in self.vfull_nodes and node not in self.stripe_crcs:
            return False
        return True

    def _maybe_reroute(self) -> None:
        """Cost model, evaluated under the lock: convert a slow-but-alive
        member's queued chunks to decode work when its direct ETA exceeds
        `reroute_factor` x the projected decode cost (max sibling direct
        ETA + amplified sibling/parity read time)."""
        if self.rerouted is not None or not self.can_reroute:
            return
        best, best_eta = None, 0.0
        for node in self.plan.reads:
            if not self._reroutable(node):
                continue
            if self.bw.samples(self._bwkey(node)) < self.cfg.min_samples:
                continue
            pend = self.pending_bytes[node]
            if pend <= 0:
                continue
            bwx = self.bw.bandwidth(self._bwkey(node))
            if not bwx or bwx <= 0:
                continue
            fb = self._median_bw() or bwx
            # noise guards: decode amplifies reads (n-1)x and spends the
            # single-parity budget, so only a member persistently well
            # below the fleet median AND with a macroscopic remaining ETA
            # is worth rerouting — one jittery microsecond-scale chunk
            # timing must never trigger it
            if bwx >= 0.5 * fb:
                continue
            eta_direct = pend / bwx
            if eta_direct < self.cfg.min_eta_s:
                continue
            others = [m for m in self.plan.reads if m != node]
            sum_bw = sum(self._estimate(m, fb) for m in others)
            if sum_bw <= 0:
                continue
            eta_others = max((self.pending_bytes[m]
                              / self._estimate(m, fb)) for m in others)
            # decode reads (n-1) bytes (siblings + parity) per rebuilt byte
            eta_reroute = eta_others + pend * (self.n - 1) / sum_bw
            if eta_direct > self.cfg.reroute_factor * max(eta_reroute, 1e-9):
                if best is None or eta_direct > best_eta:
                    best, best_eta = node, eta_direct
        if best is not None:
            self._do_reroute(best)

    def _do_reroute(self, node: int) -> bool:
        """Convert `node`'s PENDING chunks into decode items (under the
        lock).  Verify-streamed members convert at whole-block
        granularity: blocks with DONE/RUNNING chunks stay direct
        ("sticky") and are verified per-block against the stripe digest
        table; all-PENDING blocks become decode work.  Unverified members
        convert pending chunks piecewise.  Returns True if anything
        converted (or the member had nothing pending)."""
        self.rerouted = node
        self.st.rerouted_members = tuple(
            sorted(set(self.st.rerouted_members) | {node}))
        refs = raim5.data_blocks_of_node(node, self.n)
        converted = 0
        if node in self.vfull_nodes:
            for li, cs in self.block_chunks[node].items():
                states = {c.state for c in cs}
                if states <= {_PENDING, _REROUTED}:
                    for c in cs:
                        if c.state == _PENDING:
                            self._convert_chunk(c, refs[li])
                            converted += 1
                elif _DONE in states and self.block_left[node][li] == 0:
                    self._check_block_digest(node, li)
        else:
            for c in self.node_chunks[node]:
                if c.state == _PENDING:
                    self._convert_chunk(c, refs[c.block])
                    converted += 1
        self.cond.notify_all()
        return converted > 0

    def _convert_chunk(self, c: _Chunk, ref) -> None:
        """PENDING direct chunk -> decode items for exactly its pieces."""
        li = c.block
        c.state = _REROUTED
        self.direct_left -= 1
        self.node_left[c.node] -= 1
        self.pending_bytes[c.node] -= c.nbytes
        if self.n > 1:
            self.block_left[c.node][li] -= 1
        for a, b, g in c.pieces:
            o1, o2 = a - li * self.bs, b - li * self.bs
            self.decode_q.append((ref, o1, o2, g, "reroute"))

    def _check_block_digest(self, node: int, li: int) -> None:
        """Fold a completed sticky block's chunk CRCs against the member's
        per-stripe digest table (rerouted members can't fold the whole
        own-region crc_own — decoded blocks were never read)."""
        from .loader import CrcMismatch
        crcs = self.stripe_crcs.get(node)
        if crcs is None:
            return
        cs = sorted(self.block_chunks[node][li], key=lambda c: c.lo)
        crc = 0
        for c in cs:
            crc = crc32_combine(crc, c.crc, c.hi - c.lo)
        if li >= len(crcs) or (crc & 0xFFFFFFFF) != (crcs[li] & 0xFFFFFFFF):
            expect = crcs[li] if li < len(crcs) else 0
            self._set_error(CrcMismatch(
                node, expect, crc,
                reason=f"node {node} block {li} digest mismatch"))

    def _check_node_crc(self, node: int) -> None:
        """All direct chunks of a verify-streamed member landed: fold the
        per-chunk CRCs in offset order against the recorded crc_own."""
        from .loader import CrcMismatch
        expect = self.expected.get(node)
        cs = sorted(self.node_chunks[node], key=lambda c: c.lo)
        crc = 0
        for c in cs:
            crc = crc32_combine(crc, c.crc, c.hi - c.lo)
        if (crc & 0xFFFFFFFF) != (expect & 0xFFFFFFFF):
            self._set_error(CrcMismatch(node, expect, crc))
            return
        self.st.crc_members += (node,)

    # ------------------------------------------------------------- reading
    def _sub_bytes(self) -> int:
        return max(1, min(self.cfg.chunk_bytes,
                          max(self.cfg.chunk_bytes // 4, 1 << 18)))

    def _read_chunk(self, c: _Chunk, token: CancelToken):
        """Pull a chunk's bytes (cancellable between sub-reads).  Returns
        (writes, crc, nbytes, seconds) or None when cancelled."""
        t0 = time.perf_counter()
        writes: List[Tuple[int, np.ndarray]] = []
        crc = 0
        nbytes = 0
        if c.vfull:
            parts: List[Tuple[int, np.ndarray]] = []
            sub = self._sub_bytes()
            pos = c.lo
            while pos < c.hi:
                if token.cancelled:
                    return None
                e = min(pos + sub, c.hi)
                data = self.source.read_local(c.node, pos, e)
                crc = zlib.crc32(data, crc)
                nbytes += data.nbytes
                parts.append((pos, data))
                pos = e
            for a, b, g in c.pieces:
                for plo, arr in parts:
                    s, e = max(a, plo), min(b, plo + arr.nbytes)
                    if e > s:
                        writes.append((g + (s - a), arr[s - plo:e - plo]))
        else:
            batched = getattr(self.source, "read_local_ranges", None)
            if batched is not None:
                if token.cancelled:
                    return None
                datas = batched(c.node, [(a, b) for a, b, _ in c.pieces])
                for (a, b, g), data in zip(c.pieces, datas):
                    nbytes += data.nbytes
                    writes.append((g, data))
            else:
                for a, b, g in c.pieces:
                    if token.cancelled:
                        return None
                    data = self.source.read_local(c.node, a, b)
                    nbytes += data.nbytes
                    writes.append((g, data))
        dt = time.perf_counter() - t0
        self.bw.observe(self._bwkey(c.node), nbytes, dt)
        return writes, crc, nbytes, dt

    def _do_read(self, c: _Chunk, hedge: bool) -> None:
        token = CancelToken()
        with self.cond:
            if c.state != _RUNNING:
                return                       # resolved before we started
            self._tokens.setdefault(c.cid, []).append(token)
        try:
            res = self._read_chunk(c, token)
        except Exception as e:
            with self.cond:
                toks = self._tokens.get(c.cid)
                if toks and token in toks:
                    toks.remove(token)
                if not hedge:
                    self._on_read_error(c, e)
                self.cond.notify_all()
            return
        won = False
        with self.cond:
            toks = self._tokens.get(c.cid)
            if toks and token in toks:
                toks.remove(token)
            if res is not None:
                self.st.bytes_read += res[2]
            if res is not None and c.state == _RUNNING:
                c.state = _DONE
                c.crc = res[1]
                for t in self._tokens.pop(c.cid, ()):
                    t.cancelled = True
                self.direct_left -= 1
                self.inflight[c.node] -= 1
                self.writes_out += 1
                self.t_read_end = max(self.t_read_end,
                                      time.perf_counter())
                if hedge:
                    self.st.hedged_wins += 1
                won = True
        if not won:
            return
        for g, data in res[0]:
            self.sink.write(g, data)
        with self.cond:
            self.writes_out -= 1
            self._after_chunk_done(c)
            self.cond.notify_all()

    def _after_chunk_done(self, c: _Chunk) -> None:
        node = c.node
        self.node_left[node] -= 1
        if self.n > 1:
            self.block_left[node][c.block] -= 1
        if node in self.vfull_nodes:
            if self.rerouted == node:
                if self.block_left[node][c.block] == 0:
                    self._check_block_digest(node, c.block)
            elif self.node_left[node] == 0:
                self._check_node_crc(node)
        if self.cfg.mode == "adaptive" and self.error is None:
            self._maybe_reroute()

    def _on_read_error(self, c: _Chunk, e: Exception) -> None:
        """A direct read died (source gone mid-restore).  Under the lock:
        try to convert the member's remaining chunks to parity decode
        in place; if the conversion isn't clean (no parity budget, no
        digest table, or a partially-landed block that can no longer be
        verified), surface `SourceLost` so the ladder demotes + replans."""
        node = c.node
        if c.state != _RUNNING:
            return                     # a hedge already claimed the chunk
        # the erroring chunk leaves RUNNING either way
        c.state = _PENDING
        c.t_start = 0.0
        self.inflight[node] -= 1
        self.pending_bytes[node] += c.nbytes
        self.queues[node].appendleft(c.cid)
        for t in self._tokens.pop(c.cid, ()):
            t.cancelled = True
        if not self._reroutable(node):
            self._set_error(SourceLost(node, f"node {node} read failed "
                                             f"mid-restore: {e}"))
            return
        if node in self.vfull_nodes:
            # a block with landed-but-unverifiable bytes blocks conversion:
            # its DONE chunks' digests can only be checked once the whole
            # block is read, and the rest of it would now come from parity
            for li, cs in self.block_chunks[node].items():
                states = {x.state for x in cs}
                if _DONE in states and states != {_DONE}:
                    self._set_error(SourceLost(
                        node, f"node {node} died mid-block {li}: "
                              f"landed bytes unverifiable"))
                    return
        self.bw.mark_dead(self._bwkey(node))
        self._do_reroute(node)

    # -------------------------------------------------------------- decode
    def _ensure_parity_verified(self, stripe: int) -> None:
        """Verify the feeding stripe's parity digest once (a corrupt
        survivor parity block would XOR silently into the output)."""
        from .loader import CrcMismatch, stream_crc
        if not self.verify:
            return
        with self._parity_lock:
            if stripe in self._parity_ok:
                return
            try:
                expect = self.source.meta(stripe).get("crc_parity")
            except Exception:
                expect = None              # meta-bad members are demoted
            if expect is not None:         # by the read path / probe
                crc = stream_crc(
                    lambda lo, hi: self.source.read_parity_range(
                        stripe, lo, hi),
                    self.bs, self.cfg.chunk_bytes)
                with self.cond:
                    self.st.bytes_read += self.bs
                if (crc & 0xFFFFFFFF) != (expect & 0xFFFFFFFF):
                    raise CrcMismatch(
                        stripe,
                        reason=f"node {stripe} parity region CRC mismatch "
                               f"(expect {expect:#010x}, got {crc:#010x})")
            self._parity_ok.add(stripe)

    def _do_decode(self, item) -> None:
        ref, o1, o2, g, origin = item
        avoid = (self.plan.failed if origin == "plan" else self.rerouted)
        d0 = time.perf_counter()
        nread = 0
        cur: Optional[int] = None
        try:
            self._ensure_parity_verified(ref.stripe)
            parts = []
            for j in range(self.n - 1):
                if j == ref.index:
                    continue
                nd = raim5.node_of_block(ref.stripe, j, self.n)
                assert nd != avoid
                cur = nd
                t0 = time.perf_counter()
                data = self.source.read_block_range(nd, ref.stripe, j,
                                                    o1, o2)
                self.bw.observe(self._bwkey(nd), data.nbytes,
                                time.perf_counter() - t0)
                nread += data.nbytes
                parts.append(data)
            cur = ref.stripe
            t0 = time.perf_counter()
            parity = self.source.read_parity_range(ref.stripe, o1, o2)
            self.bw.observe(self._bwkey(ref.stripe), parity.nbytes,
                            time.perf_counter() - t0)
            nread += parity.nbytes
            parts.append(parity)
            cur = None
            out = raim5.xor_blocks(parts)
            self.sink.write(g, out)
        except Exception as e:
            from .loader import CrcMismatch
            with self.cond:
                self.decode_inflight -= 1
                self.st.bytes_read += nread
                if isinstance(e, CrcMismatch):
                    self._set_error(e)
                elif cur is not None:
                    self._set_error(SourceLost(
                        cur, f"decode input node {cur} read failed: {e}"))
                else:
                    self._set_error(e)
                self.cond.notify_all()
            return
        d1 = time.perf_counter()
        with self.cond:
            self.decode_inflight -= 1
            self.st.bytes_read += nread
            if origin == "plan":
                self.st.decoded_bytes += o2 - o1
            else:
                self.st.parity_rerouted_bytes += o2 - o1
            self.d_start = min(self.d_start, d0)
            self.d_end = max(self.d_end, d1)
            self.cond.notify_all()

    # ----------------------------------------------------------------- run
    def _worker(self, wid: int, home: int) -> None:
        try:
            while True:
                item = self._next(wid, home)
                if item is None:
                    return
                kind, obj = item
                if kind == "decode":
                    self._do_decode(obj)
                else:
                    self._do_read(obj, hedge=(kind == "hedge"))
        except BaseException as e:      # pragma: no cover - internal bug
            with self.cond:
                self._set_error(e)
                self.cond.notify_all()

    def run(self):
        from concurrent.futures import ThreadPoolExecutor

        st = self.st
        st.source = st.source or self.kind
        st.saved_n = self.plan.n
        st.bytes_needed = self.plan.bytes_needed
        st.members = tuple(sorted(self.plan.reads))
        st.sched = self.cfg.mode
        if self.verify:
            st.crc_members = ()
        t_wall = time.perf_counter()
        self._prepare()
        nodes = sorted(self.plan.reads) or [0]
        nw = self.cfg.workers or min(8, max(1, len(self.plan.reads) + 1))
        st.parallel_readers = nw
        self.t0 = time.perf_counter()
        if nw == 1:
            self._worker(0, nodes[0])
        else:
            with ThreadPoolExecutor(max_workers=nw) as pool:
                futs = [pool.submit(self._worker, i, nodes[i % len(nodes)])
                        for i in range(nw)]
                for f in futs:
                    f.result()
        if self.error is not None:
            raise self.error
        # consistent phase attribution: read span, decode span, overlap
        r_end = self.t_read_end if self.t_read_end else self.t0
        st.read_seconds += r_end - self.t0
        if self.d_end:
            st.decode_seconds += self.d_end - self.d_start
            st.overlap_seconds += max(
                0.0, min(r_end, self.d_end) - max(self.t0, self.d_start))
        st.crc_members = tuple(sorted(set(st.crc_members)))
        for k, v in self.bw.snapshot().items():
            st.source_bandwidth[k] = v
        st.wall_seconds += time.perf_counter() - t_wall
        return st


__all__ = [
    "SchedConfig", "SourceBandwidth", "CancelToken", "SourceLost",
    "ThrottledSource", "BucketedSource", "ChunkScheduler",
]
