"""REFT core: the paper's contribution (in-memory fault tolerance)."""
from repro.core.coordinator import NodeState, Reft, ReftGroup
from repro.core.policy import (
    FrequencyPlan, ckpt_survival, optimal_interval, plan_frequencies,
    reft_fail_rate, reft_survival, safe_horizon, weibull_survival,
)
from repro.core.snapshot import ReftConfig, SnapshotEngine
from repro.core.loader import LoadPlan, LoadStats, build_plan
from repro.core.recovery import (
    RecoveryError, restore_from_checkpoint, restore_state,
)

__all__ = [
    "NodeState", "Reft", "ReftGroup", "ReftConfig", "SnapshotEngine",
    "LoadPlan", "LoadStats", "build_plan",
    "RecoveryError", "restore_from_checkpoint", "restore_state",
    "FrequencyPlan", "ckpt_survival", "optimal_interval", "plan_frequencies",
    "reft_fail_rate", "reft_survival", "safe_horizon", "weibull_survival",
]
