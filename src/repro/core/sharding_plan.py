"""Sharding-group construction over the production mesh (paper §4.1,
generalized per DESIGN.md §2).

On the TPU mesh, parameters are sharded over the "model" axis and
replicated (or FSDP-sharded) over "data" (+"pod").  A *sharding group* is
the set of hosts that hold the same model-axis slice across the data axis
— the direct analogue of "one PP stage across all DP paths".  Each SG
member snapshots an orthogonal 1/n byte-shard of the slice plus its RAIM5
parity stripe; the pod axis multiplies the number of SGs, never their
size, so single-node protection holds at any scale.

Hosts are modeled as `chips_per_host` consecutive chips along the model
axis (a v5e tray holds 4 chips).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core import raim5


@dataclass(frozen=True)
class HostPlan:
    host: Tuple[int, ...]          # (pod, data, model_block) coordinates
    sg_id: Tuple[int, ...]         # (pod, model_block)
    member: int                    # rank within the SG (= data index)
    sg_size: int
    slice_lo: int                  # this SG's byte slice of the full state
    slice_hi: int
    snapshot_ranges: List[Tuple[int, int]]   # absolute byte ranges to save
    snapshot_bytes: int


def build_plan(total_state_bytes: int, *, data: int = 16, model: int = 16,
               pods: int = 1, chips_per_host: int = 4
               ) -> Dict[Tuple[int, ...], HostPlan]:
    """Host -> plan for the whole mesh.

    The state byte-stream is cut into `model_blocks` slices (one per
    model-axis host column); each slice is protected by one SG of `data`
    members per pod.
    """
    assert model % chips_per_host == 0
    model_blocks = model // chips_per_host
    per_slice = -(-total_state_bytes // model_blocks)
    plans = {}
    for pod in range(pods):
        for mb in range(model_blocks):
            lo = min(mb * per_slice, total_state_bytes)
            hi = min(lo + per_slice, total_state_bytes)
            for d in range(data):
                ranges = [(lo + a, lo + b) for a, b in
                          raim5.snapshot_ranges(d, data, hi - lo)]
                plans[(pod, d, mb)] = HostPlan(
                    host=(pod, d, mb),
                    sg_id=(pod, mb),
                    member=d,
                    sg_size=data,
                    slice_lo=lo, slice_hi=hi,
                    snapshot_ranges=ranges,
                    snapshot_bytes=sum(b - a for a, b in ranges),
                )
    return plans


def plan_summary(plans: Dict[Tuple[int, ...], HostPlan]) -> dict:
    sgs = {}
    for p in plans.values():
        sgs.setdefault(p.sg_id, []).append(p)
    per_host = [p.snapshot_bytes for p in plans.values()]
    return {
        "hosts": len(plans),
        "sgs": len(sgs),
        "sg_size": next(iter(plans.values())).sg_size,
        "max_snapshot_bytes_per_host": max(per_host),
        "mean_snapshot_bytes_per_host": sum(per_host) / len(per_host),
    }
