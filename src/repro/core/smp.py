"""Snapshot Management Process (paper §4.2).

The SMP is a real OS process whose lifecycle is independent of the training
process.  Data flow (Figure 6): the trainer writes tiny buckets into a
shared-memory staging ring; the SMP copies data buckets into the *dirty*
snapshot buffer and XOR-accumulates parity-stripe buckets straight into the
dirty buffer's parity area ("intermediary tensors are released after use").
On `end`, the dirty buffer becomes the new *clean* snapshot.  Three buffers
rotate (dirty / clean / previous-clean) — the paper's "at most 3x" memory
bound — so survivors always share at least one common consistent step even
if a node dies mid-snapshot.

Buffers live in *named* POSIX shared memory, so recovery can read a dead
trainer's clean snapshot without the trainer, and the coordinator can
RAIM5-decode across surviving nodes' segments.  Node failure is simulated
by killing the SMP and unlinking its segments.
"""
from __future__ import annotations

import os
import pickle
import queue
import struct
import threading
import time
import zlib
from collections import deque
from dataclasses import dataclass
from multiprocessing import get_context
from multiprocessing.shared_memory import SharedMemory
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analyze.lockgraph import named_condition, named_lock
from repro.analyze.protocol import (ProtocolViolation, ServerValidator,
                                    TraceValidator)
from repro.core import raim5
from repro.core.crcutil import crc32_concat

_MP = get_context("spawn")

NBUF = 3
CTL_SLOTS = 2 + 2 * NBUF      # [magic, latest_clean_idx, (step,state)*NBUF]
ST_FREE, ST_DIRTY, ST_CLEAN = 0, 1, 2
MAGIC = 0x5EF7
META_SLOT = 1 << 20           # per-buffer metadata slot (step-consistent)
PERSIST_CHUNK_BYTES = 8 << 20  # REFT-Ckpt streamed-write granularity


def _seg(run: str, node: int, what: str) -> str:
    return f"reft-{run}-n{node}-{what}"


import inspect as _inspect

_HAS_TRACK = "track" in _inspect.signature(SharedMemory.__init__).parameters

if not _HAS_TRACK:
    # Python < 3.13 has no SharedMemory(track=False): every process that
    # maps a segment registers it with the resource tracker, which then
    # unlinks it behind our back (and races other processes' messages into
    # noisy KeyErrors).  REFT segments must outlive any single process —
    # that is the whole point of the SMP design — and their lifetime is
    # managed explicitly via unlink_node(), so exempt exactly our
    # namespace from tracking in every process that imports this module.
    from multiprocessing import resource_tracker as _rt

    def _exempt(fn):
        def wrapped(name, rtype):
            if rtype == "shared_memory" and str(name).lstrip("/") \
                    .startswith("reft-"):
                return
            return fn(name, rtype)
        return wrapped

    if not getattr(_rt, "_reft_exempt", False):
        _rt.register = _exempt(_rt.register)
        _rt.unregister = _exempt(_rt.unregister)
        _rt._reft_exempt = True


class _Shm(SharedMemory):
    """SharedMemory that never registers with the resource tracker (see
    above / `track=False` on modern Pythons) and tolerates numpy views
    still alive at interpreter exit (close is always attempted explicitly
    first; this only silences the cosmetic late-GC BufferError)."""

    def __init__(self, name=None, create=False, size=0, track=False):
        if _HAS_TRACK:
            super().__init__(name=name, create=create, size=size, track=track)
        else:
            super().__init__(name=name, create=create, size=size)

    def __del__(self):
        try:
            super().__del__()
        except BufferError:
            pass


def _create(name: str, size: int) -> SharedMemory:
    try:
        old = _Shm(name=name, track=False)
        old.close()
        old.unlink()
    except FileNotFoundError:
        pass
    return _Shm(name=name, create=True, size=max(size, 1), track=False)


def _attach(name: str) -> SharedMemory:
    return _Shm(name=name, track=False)


@dataclass(frozen=True)
class NodeLayout:
    """Byte layout of one node's snapshot buffer for an SG of n nodes."""
    n: int
    total_bytes: int            # full state W of the SG

    @property
    def bs(self) -> int:
        return raim5.block_size(self.total_bytes, self.n) if self.n > 1 else \
            self.total_bytes

    @property
    def own_bytes(self) -> int:
        return (self.n - 1) * self.bs if self.n > 1 else self.total_bytes

    @property
    def parity_bytes(self) -> int:
        return self.bs if self.n > 1 else 0

    @property
    def buf_bytes(self) -> int:
        return self.own_bytes + self.parity_bytes


# ---------------------------------------------------------------- process
def _smp_main(conn, run: str, node: int, n: int, total_bytes: int,
              stage_slots: int, bucket_bytes: int, sem, pin_cpus=None,
              trace: bool = False):
    if pin_cpus:
        try:                       # best-effort NUMA/CPU pinning: keep the
            os.sched_setaffinity(0, pin_cpus)   # SMP off the trainer cores
        except (AttributeError, OSError):
            pass
    lay = NodeLayout(n, total_bytes)
    stage = _create(_seg(run, node, "stage"), stage_slots * bucket_bytes)
    bufs = [_create(_seg(run, node, f"buf{i}"), lay.buf_bytes)
            for i in range(NBUF)]
    ctl_shm = _create(_seg(run, node, "ctl"), CTL_SLOTS * 8)
    ctl = np.ndarray((CTL_SLOTS,), np.int64, ctl_shm.buf)
    ctl[:] = 0
    ctl[0] = MAGIC
    ctl[1] = -1                                    # no clean buffer yet
    meta_shm = _create(_seg(run, node, "meta"), NBUF * META_SLOT)

    stage_np = np.ndarray((stage_slots, bucket_bytes), np.uint8, stage.buf)
    buf_np = [np.ndarray((lay.buf_bytes,), np.uint8, b.buf) for b in bufs]

    # L3 readiness event: the trainer-side handle blocks on this message
    # instead of sleep-polling shm_open until the segments appear
    # analyze: ok ANZ003 — pre-thread: worker not started, sole sender
    conn.send(("ready",))

    # REFT-Ckpt runs on a background thread so the message loop keeps
    # draining bucket/end traffic during the disk write + fsync.  A buffer
    # being written carries a *persist pin*: `begin` never selects a
    # pinned buffer as dirty, so the shard on its way to disk can never be
    # re-dirtied mid-write.  The pin is taken HERE, in the message loop,
    # before the job is queued — synchronous with begin/end, no race.
    send_lock = named_lock("smp.server.send")   # loop thread + worker
    pin_cond = named_condition("smp.server.pin")
    # pin REFCOUNTS, not a set: two queued persists may select the SAME
    # buffer (e.g. two rounds at one common step) — the pin must hold
    # until the LAST job over that buffer finishes, or `begin` would
    # re-dirty it under the still-queued second write
    pinned: Dict[int, int] = {}
    persist_q: "queue.Queue" = queue.Queue()

    def _send(msg) -> None:
        with send_lock:
            conn.send(msg)

    def _persist_worker():
        while True:
            job = persist_q.get()
            if job is None:
                return
            seq, path, idx, step, delay_s, opts = job
            opts = opts or {}
            try:
                if delay_s:                  # simulated slow durable tier
                    # analyze: ok ANZ007 — injected latency, not polling
                    time.sleep(delay_s)      # (tests / interference bench)
                # one token bucket covers the local stream AND the remote
                # upload: persist_bw_limit bounds the SMP's total write
                # pressure against a co-located trainer
                bucket = (_TokenBucket(opts["bw_limit"])
                          if opts.get("bw_limit") else None)
                throttle = bucket.consume if bucket else None
                head_blob, digests = _head_and_meta(node, lay, idx, step,
                                                    meta_shm)
                delta = opts.get("delta")
                if delta is not None:
                    # dirty-delta persist: the shard object carries only
                    # the buffer-local extents rewritten since
                    # `base_step`, but the head keeps the FULL merged
                    # meta + per-stripe digest table, so a chain-resolved
                    # read verifies exactly like a full shard
                    extents = [(int(a), int(b))
                               for a, b in delta.get("extents", ())]
                    head = pickle.loads(head_blob)
                    head["base_step"] = int(delta["base_step"])
                    head["extents"] = extents
                    head_blob = pickle.dumps(head)
                    digests["base_step"] = int(delta["base_step"])
                    digests["extents"] = extents
                    _persist_delta_buffer(path, buf_np[idx], extents, seq,
                                          head_blob, throttle=throttle)
                else:
                    _persist_buffer(path, node, lay, idx, step, buf_np,
                                    meta_shm, seq, head_blob=head_blob,
                                    throttle=throttle)
                info = {}
                remote = opts.get("remote")
                if remote:
                    # tier-4: stream the same pinned buffer to the object
                    # store, one multipart part per RAIM5 stripe block —
                    # still on this worker thread, snapshots keep flowing
                    from repro.store import store_from_config
                    store = store_from_config(remote["store"])
                    if delta is not None:
                        from repro.store import upload_delta
                        up = upload_delta(store, remote["key"], head_blob,
                                          buf_np[idx], extents,
                                          retry=remote.get("retry"),
                                          throttle=throttle)
                    else:
                        from repro.store import upload_shard
                        seg = lay.bs if lay.n > 1 else lay.own_bytes
                        up = upload_shard(store, remote["key"], head_blob,
                                          buf_np[idx], seg, lay.own_bytes,
                                          retry=remote.get("retry"),
                                          throttle=throttle)
                    up.update(digests)
                    info["upload"] = up
                if bucket:
                    info["throttle_s"] = bucket.throttled_s
                if trace:
                    why = ServerValidator.on_persist_done(
                        idx, step, int(ctl[2 + 2 * idx]),
                        int(ctl[3 + 2 * idx]) == ST_CLEAN)
                    if why:
                        _send(("protocol-error", why))
                reply = ("persisted", seq, path, step, info)
            except Exception as e:
                reply = ("persist-error", seq, repr(e))
            finally:
                unpin_why = None
                with pin_cond:
                    if trace:
                        unpin_why = ServerValidator.on_unpin(
                            idx, pinned.get(idx, 0))
                    left = pinned.get(idx, 1) - 1
                    if left <= 0:
                        pinned.pop(idx, None)
                    else:
                        pinned[idx] = left
                    pin_cond.notify_all()
                if unpin_why:
                    try:
                        _send(("protocol-error", unpin_why))
                    except (BrokenPipeError, OSError):
                        pass                 # trainer gone
            try:
                _send(reply)
            except (BrokenPipeError, OSError):
                pass                         # trainer gone; keep serving

    worker = threading.Thread(target=_persist_worker, daemon=True,
                              name=f"smp-persist-n{node}")
    worker.start()

    dirty = -1
    try:
        while True:
            msg = conn.recv()
            op = msg[0]
            if op == "begin":
                step = msg[1]
                base_step = msg[2] if len(msg) > 2 else None
                # pick the oldest non-clean-latest, non-pinned buffer as
                # dirty; with one persist in flight at least one candidate
                # always exists (NBUF=3), but queued-up persists may pin
                # more — then wait for a pin release, never overwrite
                latest = int(ctl[1])
                with pin_cond:
                    while True:
                        cands = [(int(ctl[2 + 2 * i]), i)
                                 for i in range(NBUF)
                                 if i != latest and i not in pinned]
                        if cands:
                            break
                        pin_cond.wait(0.1)
                dirty = min(cands)[1]
                if trace:
                    why = ServerValidator.on_begin_select(
                        dirty, latest, pinned)
                    if why:
                        _send(("protocol-error", why))
                ctl[2 + 2 * dirty] = step
                ctl[3 + 2 * dirty] = ST_DIRTY
                if base_step is not None:
                    # delta flight: seed the new shard from the base
                    # (latest clean) buffer so unchanged bytes — own AND
                    # parity — carry over; only the delta buckets will be
                    # rewritten.  Copying (not writing the clean buffer in
                    # place) preserves the 3-buffer rotation invariant: an
                    # aborted delta never damages the published base.  A
                    # base miss is acked False — the trainer aborts the
                    # flight and takes a keyframe instead.
                    ok = (latest >= 0
                          and int(ctl[3 + 2 * latest]) == ST_CLEAN
                          and int(ctl[2 + 2 * latest]) == int(base_step))
                    if ok:
                        buf_np[dirty][:] = buf_np[latest]
                    _send(("base", step, bool(ok)))
                elif lay.parity_bytes:
                    buf_np[dirty][lay.own_bytes:] = 0
            elif op == "bucket":
                _, slot, kind, dst, nb = msg
                src = stage_np[slot, :nb]
                if kind == 0:                      # own data block bytes
                    buf_np[dirty][dst:dst + nb] = src
                elif kind == 2:                    # device-encoded parity:
                    buf_np[dirty][lay.own_bytes + dst:     # plain write, no
                                  lay.own_bytes + dst + nb] = src  # host XOR
                else:                              # parity-stripe bytes: XOR
                    dview = buf_np[dirty][lay.own_bytes + dst:
                                          lay.own_bytes + dst + nb]
                    np.bitwise_xor(dview, src, out=dview)
                sem.release()
            elif op == "end":
                _, step, meta_blob = msg[:3]
                want_crc = bool(msg[3]) if len(msg) > 3 else False
                crc_own = msg[4] if len(msg) > 4 else None
                crc_stripes = msg[5] if len(msg) > 5 else None
                if (crc_own is not None or want_crc or lay.parity_bytes
                        or crc_stripes):
                    meta = pickle.loads(meta_blob)
                    seg = lay.bs if lay.n > 1 else lay.own_bytes
                    if crc_own is not None:
                        # device encode path: the CRC was computed bucket-
                        # wise on the accelerator and combined on the
                        # trainer side — the SMP's own-region zlib pass
                        # drops to a meta rewrite (the per-stripe table
                        # arrives precombined the same way)
                        meta["crc_own"] = int(crc_own) & 0xFFFFFFFF
                        if crc_stripes:
                            meta["crc_stripes"] = {
                                "seg": seg,
                                "crcs": [int(c) & 0xFFFFFFFF
                                         for c in crc_stripes]}
                    elif want_crc:
                        # HASC L3: digests are computed here, inside the
                        # SMP, off every trainer-side critical path — one
                        # pass, segmented per RAIM5 block ("stripe"), so
                        # PARTIAL restore plans can verify only the
                        # stripes they read; the whole-region crc_own the
                        # loader's folded full-plan check recomputes is
                        # derived from the segments by GF(2) combine.
                        crcs = [zlib.crc32(buf_np[dirty][a:a + seg])
                                for a in range(0, lay.own_bytes, seg)]
                        meta["crc_stripes"] = {"seg": seg, "crcs": crcs}
                        meta["crc_own"] = crc32_concat(
                            (c, min(seg, lay.own_bytes - a))
                            for c, a in zip(crcs,
                                            range(0, lay.own_bytes, seg)))
                    if lay.parity_bytes:
                        # parity carries no digest in the bucket stream;
                        # checksum it at publish (still off the trainer's
                        # path) so restore can verify decode inputs —
                        # a corrupt survivor parity block would otherwise
                        # XOR silently into reconstructed bytes
                        meta["crc_parity"] = zlib.crc32(
                            buf_np[dirty][lay.own_bytes:])
                    meta_blob = pickle.dumps(meta)
                base = dirty * META_SLOT
                mb = memoryview(meta_shm.buf)
                mb[base:base + 8] = struct.pack("<q", len(meta_blob))
                mb[base + 8:base + 8 + len(meta_blob)] = meta_blob
                ctl[2 + 2 * dirty] = step
                ctl[3 + 2 * dirty] = ST_CLEAN
                ctl[1] = dirty                     # atomic-enough publish
                dirty = -1
                _send(("clean", step))
            elif op == "persist":
                # select + pin the buffer synchronously (no begin/end can
                # interleave), then hand the write to the worker — the
                # loop goes straight back to draining buckets while the
                # shard streams to disk
                _, seq, path, want_step, delay_s = msg[:5]
                opts = msg[5] if len(msg) > 5 else None
                latest = int(ctl[1])
                err = None
                if latest < 0:
                    err = "no clean snapshot to persist"
                idx = latest
                if err is None and want_step is not None:
                    # SG-consistent checkpoint: every member persists the
                    # SAME step
                    for i in range(NBUF):
                        if (int(ctl[3 + 2 * i]) == ST_CLEAN
                                and int(ctl[2 + 2 * i]) == want_step):
                            idx = i
                            break
                    else:
                        err = (f"step {want_step} no longer clean on "
                               f"node {node}")
                if err is not None:
                    _send(("persist-error", seq, err))
                else:
                    with pin_cond:
                        pinned[idx] = pinned.get(idx, 0) + 1
                    persist_q.put((seq, path, idx, int(ctl[2 + 2 * idx]),
                                   delay_s, opts))
            elif op == "ping":
                _send(("pong", time.time()))
            elif op == "stop":
                break
    except (EOFError, KeyboardInterrupt):
        # Training side vanished (software failure). The paper's SMP keeps
        # the clean snapshot alive; a reconnect signal is not possible over
        # a broken pipe, so park on a never-set event (interruptible, no
        # polling) holding the segments until killed.
        try:
            threading.Event().wait()
        except KeyboardInterrupt:
            pass
    finally:
        # drain queued persists before dropping the segments (a durable
        # write already accepted must not be torn by a clean stop)
        persist_q.put(None)
        worker.join(timeout=60)
        import gc
        del stage_np, buf_np, ctl
        gc.collect()
        for s in [stage, ctl_shm, meta_shm] + bufs:
            try:
                s.close()
            except Exception:
                pass


def _tmp_name(path: str, tag) -> str:
    """Unique scratch name per (process, persist seq): two persists
    targeting the same path — or a new persist racing a dead SMP's
    leftover — can never collide on one `.tmp`."""
    return f"{path}.{os.getpid()}.{tag}.tmp"


class _TokenBucket:
    """Byte-rate limiter for the SMP's background writes (the
    `persist_bw_limit` knob).  Charged per chunk/part BEFORE the write;
    when the bucket runs dry the persist worker sleeps until the deficit
    refills — trainer-side snapshots never block (the buffer is pinned,
    `begin` just picks another).  Burst is a quarter second of rate so
    small shards pass untouched.

    The restore side shares this class (`restore_bw_limit` via
    `readsched.BucketedSource`); pass `threadsafe=True` there — many
    reader threads charge one bucket, so the token arithmetic runs under
    a lock while the deficit sleep stays outside it."""

    def __init__(self, rate_bytes_s: float, threadsafe: bool = False):
        self.rate = float(rate_bytes_s)
        self.burst = max(self.rate * 0.25, float(1 << 20))
        self.tokens = self.burst
        self.t_last = time.perf_counter()
        self.throttled_s = 0.0
        self._lock = named_lock("smp.tokenbucket") if threadsafe else None

    def _tick(self, nbytes: int) -> float:
        now = time.perf_counter()
        self.tokens = min(self.burst,
                          self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        self.tokens -= nbytes
        if self.tokens < 0:
            wait = -self.tokens / self.rate
            self.throttled_s += wait
            return wait
        return 0.0

    def consume(self, nbytes: int) -> None:
        if self._lock is None:
            wait = self._tick(nbytes)
        else:
            with self._lock:
                wait = self._tick(nbytes)
        if wait > 0:
            time.sleep(wait)


def _stream_write(f, arr: np.ndarray,
                  chunk_bytes: int = PERSIST_CHUNK_BYTES,
                  throttle=None) -> int:
    """Write `arr` (a uint8 view over the snapshot buffer) in fixed
    chunks.  The old `arr.tobytes()` materialized a full second copy of
    the shard — doubling RSS exactly while a snapshot may be staging."""
    nb = arr.nbytes
    for off in range(0, nb, chunk_bytes):
        chunk = memoryview(arr[off:off + chunk_bytes])
        if throttle is not None:
            throttle(chunk.nbytes)
        f.write(chunk)
    return nb


def _head_and_meta(node, lay, idx, step, meta_shm):
    """Build the shard head blob for buffer `idx` plus the digest record
    the remote manifest wants.  One head serves both durable paths: the
    local `.reft` file is `head_blob + buffer`, and the uploaded shard
    object is byte-identical, so the loader's parse/verify code reads
    either through one format."""
    base = idx * META_SLOT
    mlen = struct.unpack("<q", bytes(meta_shm.buf[base:base + 8]))[0]
    meta = bytes(meta_shm.buf[base + 8:base + 8 + mlen])
    digests = {"crc_stripes": None, "crc_own": None, "crc_parity": None}
    try:                      # surface the digest table in the file head
        md = pickle.loads(meta)
        for k in digests:
            digests[k] = md.get(k)
    except Exception:
        pass
    head = {"node": node, "n": lay.n, "total_bytes": lay.total_bytes,
            "step": step, "meta": meta,
            "crc_stripes": digests["crc_stripes"]}
    return pickle.dumps(head), digests


def _persist_delta_buffer(path, buf, extents, tag, head_blob,
                          throttle=None):
    """Stream a `.reftd` delta shard atomically: head blob (which
    records `base_step` + `extents`) followed by the raw bytes of each
    buffer-local extent, concatenated in order."""
    tmp = _tmp_name(path, tag)
    try:
        with open(tmp, "wb") as f:
            if throttle is not None:
                throttle(len(head_blob))
            f.write(head_blob)
            for lo, hi in extents:
                _stream_write(f, buf[lo:hi], throttle=throttle)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        try:
            os.unlink(tmp)                 # no-op after a clean replace
        except FileNotFoundError:
            pass


def _persist_buffer(path, node, lay, idx, step, buf_np, meta_shm, tag,
                    head_blob=None, throttle=None):
    """Stream buffer `idx` (already persist-pinned by the caller) to
    `path` atomically.  The scratch file is unlinked on ANY failure —
    write or fsync errors no longer leak `.tmp` files into the family
    directory."""
    if head_blob is None:
        head_blob, _ = _head_and_meta(node, lay, idx, step, meta_shm)
    tmp = _tmp_name(path, tag)
    try:
        with open(tmp, "wb") as f:
            if throttle is not None:
                throttle(len(head_blob))
            f.write(head_blob)
            _stream_write(f, buf_np[idx], throttle=throttle)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        try:
            os.unlink(tmp)                 # no-op after a clean replace
        except FileNotFoundError:
            pass


# ---------------------------------------------------------------- handles
class SMPHandle:
    """Trainer-side handle to one node's SMP."""

    def __init__(self, run: str, node: int, n: int, total_bytes: int, *,
                 stage_slots: int = 8, bucket_bytes: int = 4 << 20,
                 pin_cpus=None, trace: bool = False):
        self.run, self.node, self.n = run, node, n
        # runtime protocol monitor (ReftConfig.trace_protocol): every
        # sent/received message is validated against the FLIGHT_FSM
        # table - a desync raises ProtocolViolation instead of wedging
        self._validator = (TraceValidator(f"smp-n{node}") if trace
                           else None)
        self._stopped = False
        self.layout = NodeLayout(n, total_bytes)
        self.stage_slots = stage_slots
        self.bucket_bytes = bucket_bytes
        self._sem = _MP.BoundedSemaphore(stage_slots)
        self._conn, child = _MP.Pipe()
        self.proc = _MP.Process(
            target=_smp_main,
            args=(child, run, node, n, total_bytes, stage_slots,
                  bucket_bytes, self._sem, tuple(pin_cpus) if pin_cpus
                  else None, trace),
            daemon=True, name=f"smp-{run}-n{node}")
        self.proc.start()
        child.close()
        self._stage = None
        self._slot = 0
        # Demultiplexed pipe protocol: persists complete asynchronously in
        # the SMP, so ("persisted"/"persist-error", seq, ...) replies can
        # interleave with ("clean", ...) and ("pong", ...) at any time.
        # Every receive routes messages to per-kind queues under one lock
        # (`_await`); sends take `_tx_lock` (the stager thread and an
        # async persist may hit the pipe concurrently).
        self._tx_lock = named_lock("smp.handle.tx")
        self._rx_lock = named_lock("smp.handle.rx")
        self._rx_clean: deque = deque()
        self._rx_pong: deque = deque()
        self._rx_base: deque = deque()
        self._rx_persist: Dict[int, tuple] = {}
        self._stale_persists: set = set()      # timed-out seqs: drop late
        self._pending_persists: List[int] = []  # fire order
        self._persist_seq = 0
        self._wait_ready()

    def _wait_ready(self, timeout=90.0):
        """Event-driven come-up: block on the SMP's `ready` message (sent
        after every segment is created and sized) instead of sleep-polling
        shm_open.  After `ready`, attach cannot race the SMP.  The budget
        is a liveness bound only — spawn + numpy import for several SMPs
        can take tens of seconds on a CPU-throttled host."""
        if not self._conn.poll(timeout):
            raise TimeoutError("SMP did not come up")
        try:
            msg = self._conn.recv()
        except EOFError:
            # child died before sending ready (e.g. shm creation failed);
            # keep the historical, diagnosable come-up error
            raise TimeoutError(
                f"SMP for node {self.node} died during startup") from None
        if msg[0] != "ready":
            raise RuntimeError(f"unexpected SMP hello {msg!r}")
        if self._validator is not None:
            self._validator.rx(msg)
        self._stage = _attach(_seg(self.run, self.node, "stage"))
        self._stage_np = np.ndarray(
            (self.stage_slots, self.bucket_bytes), np.uint8,
            self._stage.buf)

    # -- demultiplexed receive ---------------------------------------------
    def _dispatch(self, msg) -> None:
        """Route one SMP message to its queue (callers hold _rx_lock)."""
        tag = msg[0]
        if self._validator is not None:
            self._validator.rx(msg)       # raises on desync
        if tag == "protocol-error":
            # an SMP-side invariant check tripped (tracing off: never sent)
            raise ProtocolViolation(f"SMP node {self.node}: {msg[1]}")
        if tag == "clean":
            self._rx_clean.append(msg)
        elif tag == "pong":
            self._rx_pong.append(msg)
        elif tag == "base":
            self._rx_base.append(msg)
        elif tag in ("persisted", "persist-error"):
            seq = msg[1]
            if seq in self._stale_persists:
                # late reply of a timed-out persist: discard instead of
                # letting the next clean/pong recv consume it (the
                # protocol-desync bug this demux exists to fix)
                self._stale_persists.discard(seq)
                return
            self._rx_persist[seq] = msg
        # unknown tags are dropped defensively

    def _await(self, have, timeout: float, what: str):
        """Poll/recv under the rx lock, dispatching every message to its
        queue, until `have()` yields a value or `timeout` passes.  Any
        thread may be the reader; messages meant for other waiters are
        queued for them, never consumed by the wrong protocol exchange."""
        deadline = time.monotonic() + timeout
        while True:
            with self._rx_lock:
                got = have()
                if got is not None:
                    return got
                if self._conn.poll(0.05):
                    # demux by design: the rx lock IS the single-reader
                    # guarantee; recv follows a ready poll (bounded hold)
                    # analyze: ok ANZ002
                    self._dispatch(self._conn.recv())
                    continue
            if time.monotonic() >= deadline:
                raise TimeoutError(what)

    def _drain_rx(self) -> None:
        """Non-blocking: route everything currently in the pipe."""
        with self._rx_lock:
            while self._conn.poll(0):
                # analyze: ok ANZ002 — poll(0) guarantees a ready frame
                self._dispatch(self._conn.recv())

    def _send(self, msg) -> None:
        with self._tx_lock:
            if self._validator is not None:
                self._validator.tx(msg)   # raises on an off-table send
            self._conn.send(msg)

    # -- snapshot protocol -------------------------------------------------
    def begin(self, step: int, base_step: Optional[int] = None) -> bool:
        """Open a snapshot flight.  With `base_step`, open a *delta*
        flight: the SMP seeds the dirty buffer from the clean shard of
        `base_step` and acks whether that base is still its latest clean
        step — False means the caller must abort and take a keyframe."""
        if base_step is None:
            self._send(("begin", int(step)))
            return True
        self._send(("begin", int(step), int(base_step)))
        msg = self._await(
            lambda: self._rx_base.popleft() if self._rx_base else None,
            60.0, "SMP delta-begin ack timeout")
        return bool(msg[2])

    def send_bucket(self, kind: int, dst: int, payload: np.ndarray):
        # ring-slot credit: the cross-process BoundedSemaphore the SMP
        # releases per consumed bucket — the L2 stager blocks here (no
        # busy-wait) when the staging ring is full, which is exactly the
        # backpressure that stalls L1 through the scratch-credit queue.
        # A dead SMP can never release a credit, so poll liveness instead
        # of blocking forever: the raise routes the engine to degraded.
        while not self._sem.acquire(timeout=0.5):
            if not self.proc.is_alive():
                raise BrokenPipeError(
                    f"SMP for node {self.node} died mid-snapshot "
                    f"(ring credits lost)")
        slot = self._slot
        self._slot = (self._slot + 1) % self.stage_slots
        nb = payload.nbytes
        # local ref: kill()/release() nulls _stage_np concurrently with an
        # in-flight send; a closed handle must read as "SMP gone" (degrade),
        # not TypeError (fatal)
        stage = self._stage_np
        if stage is None:
            raise BrokenPipeError(
                f"SMP handle for node {self.node} closed mid-snapshot")
        stage[slot, :nb] = payload.reshape(-1).view(np.uint8)
        self._send(("bucket", slot, kind, int(dst), nb))

    def end(self, step: int, meta_blob: bytes, want_crc: bool = False,
            crc_own: Optional[int] = None,
            crc_stripes: Optional[List[int]] = None) -> None:
        """`want_crc=True` asks the SMP to compute the own-region digests
        (whole-region + per-stripe table) into the snapshot meta at
        publish time (off the trainer's hot path); `crc_own`/`crc_stripes`
        hand over precomputed digests (device encode path) so the SMP
        skips its zlib pass entirely."""
        self._send(("end", int(step), meta_blob, bool(want_crc),
                    None if crc_own is None else int(crc_own),
                    None if crc_stripes is None else
                    [int(c) for c in crc_stripes]))

    def wait_clean(self, timeout=60.0) -> int:
        msg = self._await(
            lambda: self._rx_clean.popleft() if self._rx_clean else None,
            timeout, "SMP ack timeout")
        return msg[1]

    def ping(self, timeout=10.0) -> float:
        self._send(("ping",))
        msg = self._await(
            lambda: self._rx_pong.popleft() if self._rx_pong else None,
            timeout, "SMP ping timeout")
        return msg[1]

    # -- REFT-Ckpt persist protocol ----------------------------------------
    def persist_send(self, path: str, step: Optional[int] = None,
                     delay_s: float = 0.0, opts: Optional[dict] = None
                     ) -> int:
        """Fire a persist request; returns its sequence id (the ticket
        `persist_wait`/`persist_poll` take).  The SMP services it on a
        background thread, so snapshots keep flowing while the shard
        streams to disk.  `delay_s` simulates a slow durable tier (tests
        and the interference benchmark).  `opts` is a plain picklable
        dict of worker knobs: `bw_limit` (token-bucket bytes/s for the
        write stream) and `remote` (`{store, key, retry}` — mirror the
        shard to an object store after the local write)."""
        with self._rx_lock:
            self._persist_seq += 1
            seq = self._persist_seq
            self._pending_persists.append(seq)
        self._send(("persist", seq, path, step,
                    float(delay_s) if delay_s else 0.0, opts))
        return seq

    def _take_persist(self, seq: int):
        msg = self._rx_persist.pop(seq, None)
        if msg is not None and seq in self._pending_persists:
            self._pending_persists.remove(seq)
        return msg

    def persist_result(self, seq: Optional[int] = None,
                       timeout: float = 120.0) -> tuple:
        """Blocking: the raw ("persisted", seq, path, step) or
        ("persist-error", seq, err) reply for `seq` (default: the oldest
        outstanding).  On timeout the seq is marked stale, so its late
        reply is discarded instead of desyncing the next clean/pong
        exchange."""
        if seq is None:
            with self._rx_lock:
                if not self._pending_persists:
                    raise RuntimeError("no persist in flight")
                seq = self._pending_persists[0]
        try:
            return self._await(lambda: self._take_persist(seq),
                               timeout, "persist timeout")
        except TimeoutError:
            with self._rx_lock:
                msg = self._take_persist(seq)   # landed since last check?
                if msg is None:
                    self._stale_persists.add(seq)
                    if self._validator is not None:
                        self._validator.mark_stale(seq)
                    if seq in self._pending_persists:
                        self._pending_persists.remove(seq)
                    raise
            return msg

    def persist_wait(self, seq: Optional[int] = None,
                     timeout: float = 120.0) -> str:
        msg = self.persist_result(seq, timeout)
        if msg[0] == "persist-error":
            raise RuntimeError(f"SMP persist failed: {msg[2]}")
        return msg[2]

    def persist_poll(self, seq: int) -> Optional[tuple]:
        """Non-blocking: the reply for `seq` if it has arrived (draining
        the pipe on the way), else None."""
        with self._rx_lock:
            while self._conn.poll(0):
                # analyze: ok ANZ002 — poll(0) guarantees a ready frame
                self._dispatch(self._conn.recv())
            return self._take_persist(seq)

    def persist(self, path: str, timeout=120.0, step: Optional[int] = None
                ) -> str:
        seq = self.persist_send(path, step)
        return self.persist_wait(seq, timeout)

    def alive(self) -> bool:
        return self.proc.is_alive()

    def stop(self):
        """Clean shutdown.  Idempotent: a second stop() (or close()) is a
        no-op — engine teardown, supervisor heal and user-level close()
        may all race onto the same handle.  Safe mid-persist: the SMP
        drains its persist queue before dropping the segments, so an
        accepted durable write still lands; its late reply is simply
        never read."""
        if self._stopped:
            return
        self._stopped = True
        try:
            self._send(("stop",))
        except (BrokenPipeError, OSError):
            pass
        self.proc.join(timeout=5)
        if self.proc.is_alive():
            self.proc.kill()
        self._stage_np = None
        import gc
        gc.collect()
        if self._stage is not None:
            self._stage.close()
            self._stage = None
        ReadOnlyNode.unlink_node(self.run, self.node)

    def close(self):
        """Alias for stop() (idempotent clean shutdown)."""
        self.stop()

    def kill(self):
        """Simulate an SMP software crash (segments survive).  A later
        stop() is still allowed (it reaps the proc and unlinks segments),
        so kill() does NOT mark the handle stopped."""
        self.proc.kill()
        self.proc.join()
        self.release()

    def release(self):
        """Drop this handle's shm mappings (no unlink, no proc changes)."""
        self._stage_np = None
        import gc
        gc.collect()
        if self._stage is not None:
            try:
                self._stage.close()
            except BufferError:
                pass
            self._stage = None


class ReadOnlyNode:
    """Recovery-side view of a node's SMP segments (attach by name)."""

    def __init__(self, run: str, node: int, n: int, total_bytes: int):
        self.run, self.node = run, node
        self.layout = NodeLayout(n, total_bytes)
        self._ctl_shm = _attach(_seg(run, node, "ctl"))
        if self._ctl(0) != MAGIC:
            self._ctl_shm.close()
            raise RuntimeError("bad ctl magic")
        self._bufs = [_attach(_seg(run, node, f"buf{i}")) for i in range(NBUF)]
        self._meta = _attach(_seg(run, node, "meta"))

    def _ctl(self, i: int) -> int:
        """Read one ctl slot without keeping exported pointers alive."""
        return struct.unpack_from("<q", self._ctl_shm.buf, i * 8)[0]

    def clean_steps(self) -> dict:
        """{step: buf_idx} of all CLEAN buffers."""
        out = {}
        for i in range(NBUF):
            if self._ctl(3 + 2 * i) == ST_CLEAN:
                out[self._ctl(2 + 2 * i)] = i
        return out

    def latest_clean(self) -> Optional[int]:
        idx = self._ctl(1)
        return None if idx < 0 else self._ctl(2 + 2 * idx)

    def _buf(self, step: int) -> np.ndarray:
        # copy: callers keep results after close(), and the segment may be
        # unlinked under us (simulated node failure)
        return self.read_range(step, 0, self.layout.buf_bytes)

    def meta(self, step: int) -> bytes:
        idx = self.clean_steps()[step]
        base = idx * META_SLOT
        mlen = struct.unpack("<q", bytes(self._meta.buf[base:base + 8]))[0]
        return bytes(self._meta.buf[base + 8:base + 8 + mlen])

    # ------------------------------------------------ scatter-gather reads
    def read_range(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Copy ONLY bytes [lo, hi) of the step's snapshot buffer (local
        own+parity coordinates) — the ranged primitive the distributed
        loader's `LoadPlan` executors use instead of whole-region copies."""
        idx = self.clean_steps()[step]
        shm = self._bufs[idx]
        view = np.ndarray((self.layout.buf_bytes,), np.uint8, shm.buf)
        out = view[lo:hi].copy()
        del view                     # no exported pointers past this call
        return out

    def read_ranges(self, step: int, ranges) -> list:
        """Scatter-gather: one buffer lookup, many range copies.
        `ranges` is a sequence of local (lo, hi) pairs."""
        idx = self.clean_steps()[step]
        shm = self._bufs[idx]
        view = np.ndarray((self.layout.buf_bytes,), np.uint8, shm.buf)
        out = [view[lo:hi].copy() for lo, hi in ranges]
        del view
        return out

    def read_own(self, step: int) -> np.ndarray:
        return self.read_range(step, 0, self.layout.own_bytes)

    def _block_local(self, stripe: int, index: int) -> int:
        return raim5.local_block_index(self.node, stripe, index,
                                       self.layout.n)

    def read_block(self, step: int, stripe: int, index: int) -> np.ndarray:
        """One of this node's data blocks, addressed by (stripe, index)."""
        lay = self.layout
        local = self._block_local(stripe, index)
        return self.read_range(step, local * lay.bs, (local + 1) * lay.bs)

    def read_block_range(self, step: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        """Bytes [o1, o2) *within* data block (stripe, index) — the
        range-limited RAIM5 decode primitive."""
        base = self._block_local(stripe, index) * self.layout.bs
        return self.read_range(step, base + o1, base + o2)

    def read_parity(self, step: int) -> np.ndarray:
        lay = self.layout
        return self.read_range(step, lay.own_bytes,
                               lay.own_bytes + lay.parity_bytes)

    def read_parity_range(self, step: int, o1: int, o2: int) -> np.ndarray:
        base = self.layout.own_bytes
        return self.read_range(step, base + o1, base + o2)

    def close(self):
        for s in [self._ctl_shm, self._meta] + self._bufs:
            try:
                s.close()
            except Exception:
                pass

    @staticmethod
    def unlink_node(run: str, node: int):
        """Simulated node failure / final cleanup: drop all segments."""
        for what in (["stage", "ctl", "meta"] +
                     [f"buf{i}" for i in range(NBUF)]):
            try:
                s = _Shm(name=_seg(run, node, what), track=False)
                s.close()
                s.unlink()
            except FileNotFoundError:
                pass
