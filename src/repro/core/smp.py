"""Snapshot Management Process (paper §4.2).

The SMP is a real OS process whose lifecycle is independent of the training
process.  Data flow (Figure 6): the trainer writes tiny buckets into a
shared-memory staging ring; the SMP copies data buckets into the *dirty*
snapshot buffer and XOR-accumulates parity-stripe buckets straight into the
dirty buffer's parity area ("intermediary tensors are released after use").
On `end`, the dirty buffer becomes the new *clean* snapshot.  Three buffers
rotate (dirty / clean / previous-clean) — the paper's "at most 3x" memory
bound — so survivors always share at least one common consistent step even
if a node dies mid-snapshot.

Buffers live in *named* POSIX shared memory, so recovery can read a dead
trainer's clean snapshot without the trainer, and the coordinator can
RAIM5-decode across surviving nodes' segments.  Node failure is simulated
by killing the SMP and unlinking its segments.
"""
from __future__ import annotations

import os
import pickle
import struct
import threading
import time
import zlib
from dataclasses import dataclass
from multiprocessing import get_context
from multiprocessing.shared_memory import SharedMemory
from typing import Optional

import numpy as np

from repro.core import raim5

_MP = get_context("spawn")

NBUF = 3
CTL_SLOTS = 2 + 2 * NBUF      # [magic, latest_clean_idx, (step,state)*NBUF]
ST_FREE, ST_DIRTY, ST_CLEAN = 0, 1, 2
MAGIC = 0x5EF7
META_SLOT = 1 << 20           # per-buffer metadata slot (step-consistent)


def _seg(run: str, node: int, what: str) -> str:
    return f"reft-{run}-n{node}-{what}"


import inspect as _inspect

_HAS_TRACK = "track" in _inspect.signature(SharedMemory.__init__).parameters

if not _HAS_TRACK:
    # Python < 3.13 has no SharedMemory(track=False): every process that
    # maps a segment registers it with the resource tracker, which then
    # unlinks it behind our back (and races other processes' messages into
    # noisy KeyErrors).  REFT segments must outlive any single process —
    # that is the whole point of the SMP design — and their lifetime is
    # managed explicitly via unlink_node(), so exempt exactly our
    # namespace from tracking in every process that imports this module.
    from multiprocessing import resource_tracker as _rt

    def _exempt(fn):
        def wrapped(name, rtype):
            if rtype == "shared_memory" and str(name).lstrip("/") \
                    .startswith("reft-"):
                return
            return fn(name, rtype)
        return wrapped

    if not getattr(_rt, "_reft_exempt", False):
        _rt.register = _exempt(_rt.register)
        _rt.unregister = _exempt(_rt.unregister)
        _rt._reft_exempt = True


class _Shm(SharedMemory):
    """SharedMemory that never registers with the resource tracker (see
    above / `track=False` on modern Pythons) and tolerates numpy views
    still alive at interpreter exit (close is always attempted explicitly
    first; this only silences the cosmetic late-GC BufferError)."""

    def __init__(self, name=None, create=False, size=0, track=False):
        if _HAS_TRACK:
            super().__init__(name=name, create=create, size=size, track=track)
        else:
            super().__init__(name=name, create=create, size=size)

    def __del__(self):
        try:
            super().__del__()
        except BufferError:
            pass


def _create(name: str, size: int) -> SharedMemory:
    try:
        old = _Shm(name=name, track=False)
        old.close()
        old.unlink()
    except FileNotFoundError:
        pass
    return _Shm(name=name, create=True, size=max(size, 1), track=False)


def _attach(name: str) -> SharedMemory:
    return _Shm(name=name, track=False)


@dataclass(frozen=True)
class NodeLayout:
    """Byte layout of one node's snapshot buffer for an SG of n nodes."""
    n: int
    total_bytes: int            # full state W of the SG

    @property
    def bs(self) -> int:
        return raim5.block_size(self.total_bytes, self.n) if self.n > 1 else \
            self.total_bytes

    @property
    def own_bytes(self) -> int:
        return (self.n - 1) * self.bs if self.n > 1 else self.total_bytes

    @property
    def parity_bytes(self) -> int:
        return self.bs if self.n > 1 else 0

    @property
    def buf_bytes(self) -> int:
        return self.own_bytes + self.parity_bytes


# ---------------------------------------------------------------- process
def _smp_main(conn, run: str, node: int, n: int, total_bytes: int,
              stage_slots: int, bucket_bytes: int, sem, pin_cpus=None):
    if pin_cpus:
        try:                       # best-effort NUMA/CPU pinning: keep the
            os.sched_setaffinity(0, pin_cpus)   # SMP off the trainer cores
        except (AttributeError, OSError):
            pass
    lay = NodeLayout(n, total_bytes)
    stage = _create(_seg(run, node, "stage"), stage_slots * bucket_bytes)
    bufs = [_create(_seg(run, node, f"buf{i}"), lay.buf_bytes)
            for i in range(NBUF)]
    ctl_shm = _create(_seg(run, node, "ctl"), CTL_SLOTS * 8)
    ctl = np.ndarray((CTL_SLOTS,), np.int64, ctl_shm.buf)
    ctl[:] = 0
    ctl[0] = MAGIC
    ctl[1] = -1                                    # no clean buffer yet
    meta_shm = _create(_seg(run, node, "meta"), NBUF * META_SLOT)

    stage_np = np.ndarray((stage_slots, bucket_bytes), np.uint8, stage.buf)
    buf_np = [np.ndarray((lay.buf_bytes,), np.uint8, b.buf) for b in bufs]

    # L3 readiness event: the trainer-side handle blocks on this message
    # instead of sleep-polling shm_open until the segments appear
    conn.send(("ready",))

    dirty = -1
    try:
        while True:
            msg = conn.recv()
            op = msg[0]
            if op == "begin":
                _, step = msg
                # pick the oldest non-clean-latest buffer as dirty
                latest = int(ctl[1])
                prev_steps = [(int(ctl[2 + 2 * i]), i) for i in range(NBUF)
                              if i != latest]
                dirty = min(prev_steps)[1]
                ctl[2 + 2 * dirty] = step
                ctl[3 + 2 * dirty] = ST_DIRTY
                if lay.parity_bytes:
                    buf_np[dirty][lay.own_bytes:] = 0
            elif op == "bucket":
                _, slot, kind, dst, nb = msg
                src = stage_np[slot, :nb]
                if kind == 0:                      # own data block bytes
                    buf_np[dirty][dst:dst + nb] = src
                elif kind == 2:                    # device-encoded parity:
                    buf_np[dirty][lay.own_bytes + dst:     # plain write, no
                                  lay.own_bytes + dst + nb] = src  # host XOR
                else:                              # parity-stripe bytes: XOR
                    dview = buf_np[dirty][lay.own_bytes + dst:
                                          lay.own_bytes + dst + nb]
                    np.bitwise_xor(dview, src, out=dview)
                sem.release()
            elif op == "end":
                _, step, meta_blob = msg[:3]
                want_crc = bool(msg[3]) if len(msg) > 3 else False
                crc_own = msg[4] if len(msg) > 4 else None
                if crc_own is not None or want_crc or lay.parity_bytes:
                    meta = pickle.loads(meta_blob)
                    if crc_own is not None:
                        # device encode path: the CRC was computed bucket-
                        # wise on the accelerator and combined on the
                        # trainer side — the SMP's own-region zlib pass
                        # drops to a meta rewrite
                        meta["crc_own"] = int(crc_own) & 0xFFFFFFFF
                    elif want_crc:
                        # HASC L3: the own-region CRC is computed here,
                        # inside the SMP, off every trainer-side critical
                        # path.  One contiguous pass matches what the
                        # restore loader's folded check recomputes (and
                        # what the serial engine streamed).
                        meta["crc_own"] = zlib.crc32(
                            buf_np[dirty][:lay.own_bytes])
                    if lay.parity_bytes:
                        # parity carries no digest in the bucket stream;
                        # checksum it at publish (still off the trainer's
                        # path) so restore can verify decode inputs —
                        # a corrupt survivor parity block would otherwise
                        # XOR silently into reconstructed bytes
                        meta["crc_parity"] = zlib.crc32(
                            buf_np[dirty][lay.own_bytes:])
                    meta_blob = pickle.dumps(meta)
                base = dirty * META_SLOT
                mb = memoryview(meta_shm.buf)
                mb[base:base + 8] = struct.pack("<q", len(meta_blob))
                mb[base + 8:base + 8 + len(meta_blob)] = meta_blob
                ctl[2 + 2 * dirty] = step
                ctl[3 + 2 * dirty] = ST_CLEAN
                ctl[1] = dirty                     # atomic-enough publish
                dirty = -1
                conn.send(("clean", step))
            elif op == "persist":
                _, path, want_step = msg
                try:
                    _persist(path, run, node, lay, ctl, buf_np, meta_shm,
                             want_step)
                    conn.send(("persisted", path))
                except Exception as e:   # keep serving snapshots regardless
                    conn.send(("persist-error", repr(e)))
            elif op == "ping":
                conn.send(("pong", time.time()))
            elif op == "stop":
                break
    except (EOFError, KeyboardInterrupt):
        # Training side vanished (software failure). The paper's SMP keeps
        # the clean snapshot alive; a reconnect signal is not possible over
        # a broken pipe, so park on a never-set event (interruptible, no
        # polling) holding the segments until killed.
        try:
            threading.Event().wait()
        except KeyboardInterrupt:
            pass
    finally:
        import gc
        del stage_np, buf_np, ctl
        gc.collect()
        for s in [stage, ctl_shm, meta_shm] + bufs:
            try:
                s.close()
            except Exception:
                pass


def _persist(path, run, node, lay, ctl, buf_np, meta_shm, want_step=None):
    latest = int(ctl[1])
    if latest < 0:
        raise RuntimeError("no clean snapshot to persist")
    if want_step is not None:
        # SG-consistent checkpoint: every member persists the SAME step
        for i in range(NBUF):
            if (int(ctl[3 + 2 * i]) == ST_CLEAN
                    and int(ctl[2 + 2 * i]) == want_step):
                latest = i
                break
        else:
            raise RuntimeError(
                f"step {want_step} no longer clean on node {node}")
    step = int(ctl[2 + 2 * latest])
    base = latest * META_SLOT
    mlen = struct.unpack("<q", bytes(meta_shm.buf[base:base + 8]))[0]
    meta = bytes(meta_shm.buf[base + 8:base + 8 + mlen])
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        head = {"node": node, "n": lay.n, "total_bytes": lay.total_bytes,
                "step": step, "meta": meta}
        pickle.dump(head, f)
        f.write(buf_np[latest].tobytes())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


# ---------------------------------------------------------------- handles
class SMPHandle:
    """Trainer-side handle to one node's SMP."""

    def __init__(self, run: str, node: int, n: int, total_bytes: int, *,
                 stage_slots: int = 8, bucket_bytes: int = 4 << 20,
                 pin_cpus=None):
        self.run, self.node, self.n = run, node, n
        self.layout = NodeLayout(n, total_bytes)
        self.stage_slots = stage_slots
        self.bucket_bytes = bucket_bytes
        self._sem = _MP.BoundedSemaphore(stage_slots)
        self._conn, child = _MP.Pipe()
        self.proc = _MP.Process(
            target=_smp_main,
            args=(child, run, node, n, total_bytes, stage_slots,
                  bucket_bytes, self._sem, tuple(pin_cpus) if pin_cpus
                  else None),
            daemon=True, name=f"smp-{run}-n{node}")
        self.proc.start()
        child.close()
        self._stage = None
        self._slot = 0
        self._wait_ready()

    def _wait_ready(self, timeout=90.0):
        """Event-driven come-up: block on the SMP's `ready` message (sent
        after every segment is created and sized) instead of sleep-polling
        shm_open.  After `ready`, attach cannot race the SMP.  The budget
        is a liveness bound only — spawn + numpy import for several SMPs
        can take tens of seconds on a CPU-throttled host."""
        if not self._conn.poll(timeout):
            raise TimeoutError("SMP did not come up")
        try:
            msg = self._conn.recv()
        except EOFError:
            # child died before sending ready (e.g. shm creation failed);
            # keep the historical, diagnosable come-up error
            raise TimeoutError(
                f"SMP for node {self.node} died during startup") from None
        if msg[0] != "ready":
            raise RuntimeError(f"unexpected SMP hello {msg!r}")
        self._stage = _attach(_seg(self.run, self.node, "stage"))
        self._stage_np = np.ndarray(
            (self.stage_slots, self.bucket_bytes), np.uint8,
            self._stage.buf)

    # -- snapshot protocol -------------------------------------------------
    def begin(self, step: int):
        self._conn.send(("begin", int(step)))

    def send_bucket(self, kind: int, dst: int, payload: np.ndarray):
        # ring-slot credit: the cross-process BoundedSemaphore the SMP
        # releases per consumed bucket — the L2 stager blocks here (no
        # busy-wait) when the staging ring is full, which is exactly the
        # backpressure that stalls L1 through the scratch-credit queue.
        # A dead SMP can never release a credit, so poll liveness instead
        # of blocking forever: the raise routes the engine to degraded.
        while not self._sem.acquire(timeout=0.5):
            if not self.proc.is_alive():
                raise BrokenPipeError(
                    f"SMP for node {self.node} died mid-snapshot "
                    f"(ring credits lost)")
        slot = self._slot
        self._slot = (self._slot + 1) % self.stage_slots
        nb = payload.nbytes
        self._stage_np[slot, :nb] = payload.reshape(-1).view(np.uint8)
        self._conn.send(("bucket", slot, kind, int(dst), nb))

    def end(self, step: int, meta_blob: bytes, want_crc: bool = False,
            crc_own: Optional[int] = None) -> None:
        """`want_crc=True` asks the SMP to compute the own-region CRC into
        the snapshot meta at publish time (off the trainer's hot path);
        `crc_own` hands over a precomputed digest (device encode path) so
        the SMP skips its zlib pass entirely."""
        self._conn.send(("end", int(step), meta_blob, bool(want_crc),
                         None if crc_own is None else int(crc_own)))

    def wait_clean(self, timeout=60.0) -> int:
        if not self._conn.poll(timeout):
            raise TimeoutError("SMP ack timeout")
        tag, step = self._conn.recv()
        assert tag == "clean", tag
        return step

    def persist_send(self, path: str, step: Optional[int] = None) -> None:
        """Fire the persist request without waiting (SMPs of an SG can
        then write their shards concurrently)."""
        self._conn.send(("persist", path, step))

    def persist_wait(self, timeout=120.0) -> str:
        if not self._conn.poll(timeout):
            raise TimeoutError("persist timeout")
        tag, p = self._conn.recv()
        if tag == "persist-error":
            raise RuntimeError(f"SMP persist failed: {p}")
        assert tag == "persisted", tag
        return p

    def persist(self, path: str, timeout=120.0, step: Optional[int] = None
                ) -> str:
        self.persist_send(path, step)
        return self.persist_wait(timeout)

    def alive(self) -> bool:
        return self.proc.is_alive()

    def stop(self):
        try:
            self._conn.send(("stop",))
        except (BrokenPipeError, OSError):
            pass
        self.proc.join(timeout=5)
        if self.proc.is_alive():
            self.proc.kill()
        self._stage_np = None
        import gc
        gc.collect()
        if self._stage is not None:
            self._stage.close()
            self._stage = None
        ReadOnlyNode.unlink_node(self.run, self.node)

    def kill(self):
        """Simulate an SMP software crash (segments survive)."""
        self.proc.kill()
        self.proc.join()
        self.release()

    def release(self):
        """Drop this handle's shm mappings (no unlink, no proc changes)."""
        self._stage_np = None
        import gc
        gc.collect()
        if self._stage is not None:
            try:
                self._stage.close()
            except BufferError:
                pass
            self._stage = None


class ReadOnlyNode:
    """Recovery-side view of a node's SMP segments (attach by name)."""

    def __init__(self, run: str, node: int, n: int, total_bytes: int):
        self.run, self.node = run, node
        self.layout = NodeLayout(n, total_bytes)
        self._ctl_shm = _attach(_seg(run, node, "ctl"))
        if self._ctl(0) != MAGIC:
            self._ctl_shm.close()
            raise RuntimeError("bad ctl magic")
        self._bufs = [_attach(_seg(run, node, f"buf{i}")) for i in range(NBUF)]
        self._meta = _attach(_seg(run, node, "meta"))

    def _ctl(self, i: int) -> int:
        """Read one ctl slot without keeping exported pointers alive."""
        return struct.unpack_from("<q", self._ctl_shm.buf, i * 8)[0]

    def clean_steps(self) -> dict:
        """{step: buf_idx} of all CLEAN buffers."""
        out = {}
        for i in range(NBUF):
            if self._ctl(3 + 2 * i) == ST_CLEAN:
                out[self._ctl(2 + 2 * i)] = i
        return out

    def latest_clean(self) -> Optional[int]:
        idx = self._ctl(1)
        return None if idx < 0 else self._ctl(2 + 2 * idx)

    def _buf(self, step: int) -> np.ndarray:
        # copy: callers keep results after close(), and the segment may be
        # unlinked under us (simulated node failure)
        return self.read_range(step, 0, self.layout.buf_bytes)

    def meta(self, step: int) -> bytes:
        idx = self.clean_steps()[step]
        base = idx * META_SLOT
        mlen = struct.unpack("<q", bytes(self._meta.buf[base:base + 8]))[0]
        return bytes(self._meta.buf[base + 8:base + 8 + mlen])

    # ------------------------------------------------ scatter-gather reads
    def read_range(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Copy ONLY bytes [lo, hi) of the step's snapshot buffer (local
        own+parity coordinates) — the ranged primitive the distributed
        loader's `LoadPlan` executors use instead of whole-region copies."""
        idx = self.clean_steps()[step]
        shm = self._bufs[idx]
        view = np.ndarray((self.layout.buf_bytes,), np.uint8, shm.buf)
        out = view[lo:hi].copy()
        del view                     # no exported pointers past this call
        return out

    def read_ranges(self, step: int, ranges) -> list:
        """Scatter-gather: one buffer lookup, many range copies.
        `ranges` is a sequence of local (lo, hi) pairs."""
        idx = self.clean_steps()[step]
        shm = self._bufs[idx]
        view = np.ndarray((self.layout.buf_bytes,), np.uint8, shm.buf)
        out = [view[lo:hi].copy() for lo, hi in ranges]
        del view
        return out

    def read_own(self, step: int) -> np.ndarray:
        return self.read_range(step, 0, self.layout.own_bytes)

    def _block_local(self, stripe: int, index: int) -> int:
        return raim5.local_block_index(self.node, stripe, index,
                                       self.layout.n)

    def read_block(self, step: int, stripe: int, index: int) -> np.ndarray:
        """One of this node's data blocks, addressed by (stripe, index)."""
        lay = self.layout
        local = self._block_local(stripe, index)
        return self.read_range(step, local * lay.bs, (local + 1) * lay.bs)

    def read_block_range(self, step: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        """Bytes [o1, o2) *within* data block (stripe, index) — the
        range-limited RAIM5 decode primitive."""
        base = self._block_local(stripe, index) * self.layout.bs
        return self.read_range(step, base + o1, base + o2)

    def read_parity(self, step: int) -> np.ndarray:
        lay = self.layout
        return self.read_range(step, lay.own_bytes,
                               lay.own_bytes + lay.parity_bytes)

    def read_parity_range(self, step: int, o1: int, o2: int) -> np.ndarray:
        base = self.layout.own_bytes
        return self.read_range(step, base + o1, base + o2)

    def close(self):
        for s in [self._ctl_shm, self._meta] + self._bufs:
            try:
                s.close()
            except Exception:
                pass

    @staticmethod
    def unlink_node(run: str, node: int):
        """Simulated node failure / final cleanup: drop all segments."""
        for what in (["stage", "ctl", "meta"] +
                     [f"buf{i}" for i in range(NBUF)]):
            try:
                s = _Shm(name=_seg(run, node, what), track=False)
                s.close()
                s.unlink()
            except FileNotFoundError:
                pass
