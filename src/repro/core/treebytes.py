"""Flat byte-stream view of a train-state pytree.

REFT shards, XOR-encodes, and snapshots *byte ranges*, not tensors: the whole
state (params + optimizer moments + step + RNG key) is laid out as one
contiguous logical byte stream so that (a) SG members get exactly-equal
orthogonal shards, (b) RAIM5 parity blocks line up across nodes, and
(c) restore is a single pass.  Leaf order is the deterministic pytree
flatten order; a JSON-able spec records (path, shape, dtype, offset).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Iterator, List, Tuple

import numpy as np

import jax


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


@dataclass(frozen=True)
class LeafSpec:
    path: str
    shape: Tuple[int, ...]
    dtype: str
    offset: int
    nbytes: int


@dataclass(frozen=True)
class FlatSpec:
    leaves: Tuple[LeafSpec, ...]
    total_bytes: int
    treedef_repr: str

    def to_json(self) -> str:
        return json.dumps({
            "total_bytes": self.total_bytes,
            "treedef": self.treedef_repr,
            "leaves": [[l.path, list(l.shape), l.dtype, l.offset, l.nbytes]
                       for l in self.leaves],
        })

    @classmethod
    def from_json(cls, s: str) -> "FlatSpec":
        d = json.loads(s)
        leaves = tuple(LeafSpec(p, tuple(sh), dt, off, nb)
                       for p, sh, dt, off, nb in d["leaves"])
        return cls(leaves=leaves, total_bytes=d["total_bytes"],
                   treedef_repr=d["treedef"])


def make_flat_spec(tree: Any) -> FlatSpec:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves: List[LeafSpec] = []
    off = 0
    for path, leaf in flat:
        arr = np.asarray(leaf) if not hasattr(leaf, "dtype") else leaf
        nbytes = int(np.prod(arr.shape)) * np.dtype(arr.dtype).itemsize \
            if arr.shape else np.dtype(arr.dtype).itemsize
        leaves.append(LeafSpec(_path_str(path), tuple(arr.shape),
                               str(np.dtype(arr.dtype)), off, nbytes))
        off += nbytes
    return FlatSpec(tuple(leaves), off, str(treedef))


def leaf_arrays(tree: Any):
    """Leaves in the same order as the spec, as host-transferable arrays."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [leaf for _, leaf in flat]


def tree_to_buffer(tree: Any, spec: FlatSpec, out: np.ndarray,
                   lo: int = 0, hi: int = None) -> None:
    """Copy the byte range [lo, hi) of the flat stream into `out` (uint8,
    length hi-lo). Device->host transfer happens leaf-slice by leaf-slice."""
    hi = spec.total_bytes if hi is None else hi
    assert out.nbytes >= hi - lo
    leaves = leaf_arrays(tree)
    for ls, leaf in zip(spec.leaves, leaves):
        a, b = max(lo, ls.offset), min(hi, ls.offset + ls.nbytes)
        if a >= b:
            continue
        host = np.asarray(leaf)                 # d2h for jax arrays
        raw = host.reshape(-1).view(np.uint8)[a - ls.offset:b - ls.offset]
        out[a - lo:b - lo] = raw


def buffer_to_tree(template: Any, spec: FlatSpec, buf: np.ndarray) -> Any:
    """Rebuild a pytree (host numpy leaves) from the full flat buffer."""
    assert buf.nbytes >= spec.total_bytes
    flat, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for ls in spec.leaves:
        raw = buf[ls.offset:ls.offset + ls.nbytes]
        arr = raw.view(np.dtype(ls.dtype))
        out.append(arr.reshape(ls.shape).copy())
    return jax.tree_util.tree_unflatten(treedef, out)


def iter_buckets(lo: int, hi: int, bucket_bytes: int
                 ) -> Iterator[Tuple[int, int]]:
    """Tiny-bucket ranges covering [lo, hi) (paper §4.1)."""
    a = lo
    while a < hi:
        b = min(a + bucket_bytes, hi)
        yield a, b
        a = b


def crc32_of(buf: np.ndarray) -> int:
    import zlib
    return zlib.crc32(buf.tobytes()) & 0xFFFFFFFF
