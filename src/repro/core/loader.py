"""Distributed in-memory checkpoint loading (paper §4.2 "Loading").

The seed-era restore path reassembled the ENTIRE state into one
contiguous host buffer on a single caller, decoded a failed member's
whole shard even when only a few stripes were needed, and read tier-3
`.reft` files whole.  This module replaces all of that with a planned,
ranged, parallel loader:

  LoadPlan      the minimal per-member byte ranges each restoring rank
                actually needs — `FlatSpec` leaf extents intersected with
                a target sharding (elastic `sg_size`, member shard,
                leaf filter, or a `repro.dist` PartitionSpec tree) and
                mapped through the saved RAIM5 block layout;
  sources       scatter-gather range readers over survivor SMP segments
                (`ShmSource` -> `smp.ReadOnlyNode.read_range`) or over
                persisted REFT-Ckpt files (`FileSource`, seek+read — so
                NFS-style disk restores are ranged and per-member-
                parallel too);
  executors     parallel per-member ranged reads, range-limited RAIM5
                decode (`raim5.decode_node_ranges`: a lost member costs
                only the plan-intersecting stripe sub-ranges), incremental
                CRC folded into the read pass (a member's own-region
                digest is verified WHILE its bytes stream, no separate
                probe pass), and streamed per-leaf assembly with
                overlapped `jax.device_put` (h2d of leaf k while leaf
                k+1's ranges are still being read);
  LoadStats     per-phase accounting (`bytes_read`, `decoded_bytes`,
                read/decode/h2d seconds) surfaced through
                `RestoreResult.load`.

Reshard-on-restore: `resolve_need` maps a `RestoreTarget` (different
`sg_size`/mesh than the one that saved — elastic n->m restart) to global
byte ranges via `FlatSpec`, so the plan reads old-layout blocks for
new-layout shards without materialising the full state anywhere.
"""
from __future__ import annotations

import bisect
import pickle
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analyze.lockgraph import named_lock
from repro.core import raim5
from repro.core.treebytes import FlatSpec

CHUNK_BYTES = 8 << 20           # streaming read/CRC granularity
MAX_SLAB_RANGES = 4096          # strided-shard fallback: whole leaf beyond


class CrcMismatch(RuntimeError):
    """A member's own-region bytes do not match its recorded digest (or
    its snapshot meta is unreadable — equally untrustworthy)."""

    def __init__(self, node: int, expect: int = 0, got: int = 0,
                 reason: str = None):
        super().__init__(reason or
                         f"node {node} own-region CRC mismatch "
                         f"(expect {expect:#010x}, got {got:#010x})")
        self.node = node


_META_BAD = object()          # sentinel: meta unreadable -> demote member


# ----------------------------------------------------------------- ranges
def normalize_ranges(ranges: Sequence[Tuple[int, int]], total_bytes: int
                     ) -> Tuple[Tuple[int, int], ...]:
    """Sort, clip to [0, total), drop empties, merge overlaps/adjacency."""
    out: List[Tuple[int, int]] = []
    for lo, hi in sorted((max(0, int(a)), min(int(b), total_bytes))
                         for a, b in ranges):
        if hi <= lo:
            continue
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return tuple(out)


def _intersect(need: Sequence[Tuple[int, int]], lo: int, hi: int
               ) -> List[Tuple[int, int]]:
    """Sub-ranges of sorted disjoint `need` falling inside [lo, hi)."""
    out = []
    i = bisect.bisect_right([a for a, _ in need], lo) - 1
    i = max(i, 0)
    while i < len(need):
        a, b = need[i]
        if a >= hi:
            break
        a2, b2 = max(a, lo), min(b, hi)
        if b2 > a2:
            out.append((a2, b2))
        i += 1
    return out


# ------------------------------------------------------------------- plan
@dataclass(frozen=True)
class RangeReq:
    """One contiguous read from a member's own region (local coords) and
    where its bytes land in the global flat stream."""
    local_lo: int
    local_hi: int
    global_lo: int

    @property
    def nbytes(self) -> int:
        return self.local_hi - self.local_lo


@dataclass(frozen=True)
class LoadPlan:
    """Minimal per-member byte ranges for one restore."""
    n: int                                   # saved SG size (RAIM5 layout)
    total_bytes: int
    need: Tuple[Tuple[int, int], ...]        # normalized global ranges
    reads: Dict[int, Tuple[RangeReq, ...]]   # per surviving member
    decode: Tuple[Tuple[raim5.BlockRef, Tuple[Tuple[int, int], ...]], ...]
    failed: Optional[int]

    @property
    def bytes_needed(self) -> int:
        return sum(b - a for a, b in self.need)

    @property
    def read_bytes(self) -> int:
        """Bytes served by direct survivor reads (excl. decode traffic)."""
        return sum(r.nbytes for reqs in self.reads.values() for r in reqs)

    @property
    def decode_bytes(self) -> int:
        """Failed-member bytes the plan reconstructs from parity."""
        return sum(o2 - o1 for _, subs in self.decode for o1, o2 in subs)

    def member_covered(self, node: int) -> bool:
        """True iff the plan reads every real byte of `node`'s shard —
        the precondition for folding its own-region CRC into the read."""
        real = _member_real_bytes(node, self.n, self.total_bytes)
        return sum(r.nbytes for r in self.reads.get(node, ())) >= real

    @property
    def touched_members(self) -> Tuple[int, ...]:
        """Every member the executor will read bytes from: direct reads
        PLUS the stripe siblings / parity holders feeding the failed
        member's decode — the set a CRC probe must cover."""
        nodes = set(self.reads)
        for ref, _ in self.decode:
            nodes.add(ref.stripe)                       # parity holder
            for j in range(self.n - 1):
                if j != ref.index:
                    nodes.add(raim5.node_of_block(ref.stripe, j, self.n))
        nodes.discard(self.failed)
        return tuple(sorted(nodes))


def _member_real_bytes(node: int, n: int, total_bytes: int) -> int:
    if n == 1:
        return total_bytes
    bs = raim5.block_size(total_bytes, n)
    real = 0
    for ref in raim5.data_blocks_of_node(node, n):
        lo, hi = ref.byte_range(bs, n)
        real += max(0, min(hi, total_bytes) - min(lo, total_bytes))
    return real


def build_plan(n: int, total_bytes: int,
               need: Optional[Sequence[Tuple[int, int]]] = None,
               failed: Optional[int] = None) -> LoadPlan:
    """Map global byte `need` (default: everything) through the n-way
    RAIM5 block layout into per-member local reads + the failed member's
    decode sub-ranges."""
    need_n = normalize_ranges(need if need is not None
                              else [(0, total_bytes)], total_bytes)
    if n == 1:
        assert failed is None, "n==1 has no parity to decode from"
        reqs = tuple(RangeReq(a, b, a) for a, b in need_n)
        return LoadPlan(1, total_bytes, need_n, {0: reqs}, (), None)
    bs = raim5.block_size(total_bytes, n)
    reads: Dict[int, List[RangeReq]] = {}
    for node in range(n):
        if node == failed:
            continue
        reqs: List[RangeReq] = []
        for li, ref in enumerate(raim5.data_blocks_of_node(node, n)):
            g_lo, g_hi = ref.byte_range(bs, n)
            for a, b in _intersect(need_n, g_lo, min(g_hi, total_bytes)):
                local = li * bs + (a - g_lo)
                reqs.append(RangeReq(local, local + (b - a), a))
        if reqs:
            reqs.sort(key=lambda r: r.local_lo)
            reads[node] = reqs
    decode: Tuple = ()
    if failed is not None:
        decode = tuple((ref, tuple(subs)) for ref, subs in
                       raim5.blocks_intersecting(failed, n, total_bytes,
                                                 need_n))
    return LoadPlan(n, total_bytes, need_n,
                    {k: tuple(v) for k, v in reads.items()}, decode, failed)


# ---------------------------------------------------------------- sources
class ShmSource:
    """Ranged reads over survivor SMP shared-memory segments at one step
    (`smp.ReadOnlyNode.read_range` — no whole-region copies)."""

    kind = "shm"

    def __init__(self, views: Dict[int, Any], step: int):
        self.views = views
        self.step = step

    @property
    def nodes(self) -> List[int]:
        return sorted(self.views)

    def read_local(self, node: int, lo: int, hi: int) -> np.ndarray:
        return self.views[node].read_range(self.step, lo, hi)

    def read_local_ranges(self, node: int, ranges) -> List[np.ndarray]:
        """Scatter-gather fast path: one clean-buffer lookup for many
        range copies (`ReadOnlyNode.read_ranges`) — what partial plans
        with many small block slices ride on."""
        return self.views[node].read_ranges(self.step, ranges)

    def read_block_range(self, node: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        return self.views[node].read_block_range(self.step, stripe, index,
                                                 o1, o2)

    def read_parity_range(self, stripe: int, o1: int, o2: int) -> np.ndarray:
        return self.views[stripe].read_parity_range(self.step, o1, o2)

    def meta(self, node: int) -> dict:
        return pickle.loads(self.views[node].meta(self.step))


class FileSource:
    """Ranged reads over a persisted REFT-Ckpt family (`.reft` files):
    one positioned read (`os.pread`) per range instead of reading every
    member file whole.  pread carries its own offset, so the executor's
    member-read threads and the decode task can hit the same file handle
    concurrently without a seek race.  Discovers the family's own layout
    (saved n, total bytes) from the pickled heads, which is what makes
    elastic n->m disk restores work."""

    kind = "file"

    def __init__(self, paths: Dict[int, str]):
        import os
        from repro.core.smp import NodeLayout
        self._files: Dict[int, Any] = {}
        self._data_off: Dict[int, int] = {}
        self.heads: Dict[int, dict] = {}
        try:
            for node, path in sorted(paths.items()):
                f = open(path, "rb")
                self._files[node] = f          # owned even if the head is
                self.heads[node] = pickle.load(f)   # garbage (see except)
                self._data_off[node] = f.tell()
        except BaseException:
            self.close()                       # junk/torn family: no fd leak
            raise
        any_head = next(iter(self.heads.values()))
        self.n = any_head["n"]
        self.total_bytes = any_head["total_bytes"]
        self.step = any_head["step"]
        self.layout = NodeLayout(self.n, self.total_bytes)
        self._pread = os.pread

    @property
    def nodes(self) -> List[int]:
        return sorted(self._files)

    def read_local(self, node: int, lo: int, hi: int) -> np.ndarray:
        fd = self._files[node].fileno()
        return np.frombuffer(
            self._pread(fd, hi - lo, self._data_off[node] + lo), np.uint8)

    def read_block_range(self, node: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        base = raim5.local_block_index(node, stripe, index, self.n) \
            * self.layout.bs
        return self.read_local(node, base + o1, base + o2)

    def read_parity_range(self, stripe: int, o1: int, o2: int) -> np.ndarray:
        base = self.layout.own_bytes
        return self.read_local(stripe, base + o1, base + o2)

    def meta(self, node: int) -> dict:
        return pickle.loads(self.heads[node]["meta"])

    def close(self) -> None:
        for f in self._files.values():
            try:
                f.close()
            except Exception:
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class ObjectSource:
    """Ranged reads over a remote REFT-Ckpt family (tier 4): shard
    objects in an object store, addressed by the family MANIFEST instead
    of pickled file heads — no local staging copy, every `LoadPlan`
    range becomes one `read_range` straight into plan assembly, and the
    saved topology comes from the manifest so elastic n->m restores work
    against remote families exactly like local ones.

    Deliberately store-agnostic: takes any object with
    `read_range(key, lo, hi)` plus a plain manifest dict, and an
    optional `retry` wrapper (`callable -> result`) recovery builds from
    the configured backoff policy — this module never imports
    `repro.store` (the store package sits above the loader)."""

    kind = "object"

    def __init__(self, store, manifest: dict, retry=None):
        from repro.core.smp import NodeLayout
        self._store = store
        self._retry = retry if retry is not None else (lambda fn: fn())
        self.manifest = manifest
        self.n = int(manifest["n"])
        self.total_bytes = int(manifest["total_bytes"])
        self.step = int(manifest["step"])
        self.layout = NodeLayout(self.n, self.total_bytes)
        self._nodes = {int(k): v for k, v in manifest["nodes"].items()}
        self._meta: Dict[int, dict] = {}

    @property
    def nodes(self) -> List[int]:
        return sorted(self._nodes)

    def read_local(self, node: int, lo: int, hi: int) -> np.ndarray:
        ent = self._nodes[node]
        off = int(ent["data_off"])
        return self._retry(lambda: self._store.read_range(
            ent["key"], off + lo, off + hi))

    def read_block_range(self, node: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        base = raim5.local_block_index(node, stripe, index, self.n) \
            * self.layout.bs
        return self.read_local(node, base + o1, base + o2)

    def read_parity_range(self, stripe: int, o1: int, o2: int) -> np.ndarray:
        base = self.layout.own_bytes
        return self.read_local(stripe, base + o1, base + o2)

    def meta(self, node: int) -> dict:
        if node not in self._meta:
            ent = self._nodes[node]
            head_blob = self._retry(lambda: self._store.read_range(
                ent["key"], 0, int(ent["data_off"])))
            head = pickle.loads(bytes(head_blob))
            self._meta[node] = pickle.loads(head["meta"])
        return self._meta[node]

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class DeltaLayer:
    """One `.reftd` delta family as an overlay layer: per node, the
    buffer-local extents its flight span rewrote plus a reader over the
    concatenated payload bytes.  The head carries the FULL merged
    snapshot meta + per-stripe digest table of its step, so the newest
    layer alone answers every verification question about the chain."""

    def __init__(self, step: int, base_step: int):
        self.step = int(step)
        self.base_step = int(base_step)
        self.extents: Dict[int, List[Tuple[int, int]]] = {}
        self.prefix: Dict[int, List[int]] = {}   # payload offset per extent
        self._payload: Dict[int, Callable] = {}  # node -> read(lo, hi)
        self._head: Dict[int, Any] = {}          # dict, or lazy loader
        self._files: Dict[int, Any] = {}

    def add_node(self, node: int, extents, read_payload, head) -> None:
        ext = [(int(a), int(b)) for a, b in extents]
        pre: List[int] = []
        acc = 0
        for a, b in ext:
            pre.append(acc)
            acc += b - a
        self.extents[node] = ext
        self.prefix[node] = pre
        self._payload[node] = read_payload
        self._head[node] = head

    @property
    def nodes(self) -> List[int]:
        return sorted(self.extents)

    def head(self, node: int) -> dict:
        h = self._head[node]
        if callable(h):
            h = self._head[node] = h()
        return h

    def read(self, node: int, off_lo: int, off_hi: int) -> np.ndarray:
        """Payload bytes [off_lo, off_hi) of `node`'s delta object."""
        return self._payload[node](off_lo, off_hi)

    def close(self) -> None:
        for f in self._files.values():
            try:
                f.close()
            except Exception:
                pass

    @classmethod
    def from_files(cls, paths: Dict[int, str]) -> "DeltaLayer":
        """Open one local `.reftd` family ({node: path})."""
        import os
        layer = None
        files: Dict[int, Any] = {}
        try:
            for node, path in sorted(paths.items()):
                f = open(path, "rb")
                files[node] = f
                head = pickle.load(f)
                data_off = f.tell()
                if layer is None:
                    layer = cls(head["step"], head["base_step"])
                fd = f.fileno()
                layer.add_node(
                    node, head["extents"],
                    lambda lo, hi, fd=fd, off=data_off: np.frombuffer(
                        os.pread(fd, hi - lo, off + lo), np.uint8),
                    head)
        except BaseException:
            for f in files.values():
                try:
                    f.close()
                except Exception:
                    pass
            raise
        layer._files = files
        return layer

    @classmethod
    def from_objects(cls, store, manifest: dict, retry=None) -> "DeltaLayer":
        """Open one remote delta family from its manifest (node records
        carry `base_step`/`extents`/`data_off`, so only a node's head —
        needed for `meta()` — is fetched lazily)."""
        rt = retry if retry is not None else (lambda fn: fn())
        nodes = {int(k): v for k, v in manifest["nodes"].items()}
        any_ent = next(iter(nodes.values()))
        layer = cls(manifest["step"],
                    manifest.get("base_step", any_ent.get("base_step")))
        for node, ent in sorted(nodes.items()):
            off = int(ent["data_off"])
            key = ent["key"]

            def read_payload(lo, hi, key=key, off=off):
                return rt(lambda: store.read_range(key, off + lo, off + hi))

            def load_head(key=key, off=off):
                blob = rt(lambda: store.read_range(key, 0, off))
                return pickle.loads(bytes(blob))

            layer.add_node(node, ent["extents"], read_payload, load_head)
        return layer


class ChainSource:
    """Keyframe + delta-chain resolver presenting the standard source
    interface, so `LoadPlan` executors, RAIM5 decode, and per-stripe
    verification run unchanged over a delta family.

    `base` is a full-family source (`FileSource`/`ObjectSource`/shm
    views); `layers` are the `.reftd` deltas oldest -> newest, each
    linking to its predecessor's step.  A buffer-local read resolves
    newest layer first (its extents override), falls through older
    layers, and bottoms out at the keyframe.  `meta()` serves the NEWEST
    layer's merged table — the digests of the resolved step — which is
    exactly what makes chain reads verify like full-shard reads."""

    kind = "chain"

    def __init__(self, base, layers: Sequence[DeltaLayer]):
        from repro.core.smp import NodeLayout
        self.base = base
        self.layers = list(layers)
        prev = int(base.step)
        for ly in self.layers:
            if ly.base_step != prev:
                raise ValueError(
                    f"broken delta chain: layer for step {ly.step} links "
                    f"to base {ly.base_step}, expected {prev}")
            prev = ly.step
        self.n = base.n
        self.total_bytes = base.total_bytes
        self.layout = NodeLayout(self.n, self.total_bytes)
        self.step = self.layers[-1].step if self.layers else int(base.step)
        self._meta: Dict[int, dict] = {}

    @property
    def nodes(self) -> List[int]:
        return self.base.nodes

    # ----------------------------------------------- overlay resolution
    def locate_spans(self, node: int, lo: int, hi: int
                     ) -> List[Tuple[int, int, int, int]]:
        """Resolve buffer-local [lo, hi) newest-first into
        `(layer_idx, payload_off, lo2, hi2)` spans sorted by `lo2`;
        `layer_idx == -1` means the keyframe serves it (and
        `payload_off == lo2`).  Exposed for the scrubber, which must
        route repair WRITES to the same layer that serves the bytes."""
        spans: List[Tuple[int, int, int, int]] = []
        self._locate(node, lo, hi, len(self.layers) - 1, spans)
        spans.sort(key=lambda s: s[2])
        return spans

    def _locate(self, node, lo, hi, li, out) -> None:
        if lo >= hi:
            return
        if li < 0:
            out.append((-1, lo, lo, hi))
            return
        layer = self.layers[li]
        ext = layer.extents.get(node, [])
        pos = lo
        i = bisect.bisect_right([a for a, _ in ext], pos) - 1
        if i < 0 or ext[i][1] <= pos:
            i += 1
        while pos < hi and i < len(ext):
            a, b = ext[i]
            if a >= hi:
                break
            if a > pos:                       # hole: older layers serve it
                self._locate(node, pos, min(a, hi), li - 1, out)
                pos = min(a, hi)
            c = min(b, hi)
            if c > pos:
                off = layer.prefix[node][i] + (pos - a)
                out.append((li, off, pos, c))
                pos = c
            i += 1
        if pos < hi:
            self._locate(node, pos, hi, li - 1, out)

    def _read_span(self, node: int, span) -> np.ndarray:
        li, off, a, b = span
        if li < 0:
            return self.base.read_local(node, a, b)
        return self.layers[li].read(node, off, off + (b - a))

    # ------------------------------------------------- source interface
    def read_local(self, node: int, lo: int, hi: int) -> np.ndarray:
        spans = self.locate_spans(node, lo, hi)
        if len(spans) == 1:
            return self._read_span(node, spans[0])
        out = np.empty(hi - lo, np.uint8)
        for span in spans:
            out[span[2] - lo:span[3] - lo] = self._read_span(node, span)
        return out

    def read_block_range(self, node: int, stripe: int, index: int,
                         o1: int, o2: int) -> np.ndarray:
        base = raim5.local_block_index(node, stripe, index, self.n) \
            * self.layout.bs
        return self.read_local(node, base + o1, base + o2)

    def read_parity_range(self, stripe: int, o1: int, o2: int) -> np.ndarray:
        base = self.layout.own_bytes
        return self.read_local(stripe, base + o1, base + o2)

    def meta(self, node: int) -> dict:
        if node not in self._meta:
            if self.layers:
                self._meta[node] = pickle.loads(
                    self.layers[-1].head(node)["meta"])
            else:
                self._meta[node] = self.base.meta(node)
        return self._meta[node]

    def close(self) -> None:
        for ly in self.layers:
            ly.close()
        close = getattr(self.base, "close", None)
        if close is not None:
            close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ------------------------------------------------------------------ stats
@dataclass
class LoadStats:
    """Per-phase restore accounting (surfaced as `RestoreResult.load`).

    Counters measure the TOTAL work the restore performed — including
    CRC probe traffic, demotion retries, and candidate steps that were
    abandoned — not just the final successful plan's footprint; that is
    what restart latency is made of.  `crc_members` reflects only the
    attempt that produced the result."""
    tier: str = ""                 # ladder rung (filled by the caller)
    source: str = ""               # shm | file | object
    saved_n: int = 0               # layout the snapshot was saved with
    target_n: int = 0              # restoring group size (0 = unspecified)
    resharded: bool = False        # saved_n != target_n (elastic restart)
    bytes_needed: int = 0          # plan coverage of the flat stream
    bytes_read: int = 0            # bytes copied out of sources
    decoded_bytes: int = 0         # failed-member bytes rebuilt from parity
    read_seconds: float = 0.0      # direct-read span: first read start to
                                   # last read completion (plus CRC probe
                                   # traffic, which precedes the plan)
    decode_seconds: float = 0.0    # decode span: first decode start to
                                   # last decode end (overlaps reads)
    overlap_seconds: float = 0.0   # intersection of the two spans, so
                                   # read + decode - overlap never
                                   # double-counts concurrent phases
    h2d_seconds: float = 0.0       # overlapped jax.device_put drain
    wall_seconds: float = 0.0
    members: Tuple[int, ...] = ()  # members actually read
    crc_members: Tuple[int, ...] = ()  # members CRC-verified in-pass
    probe_segments: int = 0        # per-stripe digests verified (partial
                                   # plans: segments read, not whole shards)
    parallel_readers: int = 0
    # adaptive scheduler accounting (readsched.ChunkScheduler)
    sched: str = ""                # "" = legacy FCFS executor
    stolen_chunks: int = 0         # chunks run off their home affinity
    parity_rerouted_bytes: int = 0  # live-member bytes served via parity
    rerouted_members: Tuple[int, ...] = ()
    hedged_reads: int = 0          # duplicate tail reads issued
    hedged_wins: int = 0           # duplicates that beat the original
    source_bandwidth: Dict[str, float] = field(
        default_factory=dict)      # "kind:node" -> EWMA bytes/s

    def to_dict(self) -> dict:
        return {k: (list(v) if isinstance(v, tuple) else v)
                for k, v in self.__dict__.items()}


# ------------------------------------------------------------------ sinks
class FlatSink:
    """Scatter into one contiguous buffer (the compat/monolithic shape).
    Plan writes land in provably disjoint ranges (each global byte is
    served by exactly one block or decode piece), so the parallel reader
    threads scatter without a lock."""

    def __init__(self, total_bytes: int):
        self.buf = np.zeros(total_bytes, np.uint8)

    def write(self, global_lo: int, data: np.ndarray) -> None:
        self.buf[global_lo:global_lo + data.nbytes] = data


class LeafSink:
    """Scatter straight into per-leaf arrays (no full-state intermediate
    buffer).  Tracks per-leaf remaining bytes from the plan's coverage;
    a leaf whose covered bytes have all arrived is handed to `on_leaf`
    immediately — the hook the overlapped-h2d drain rides on.

    A PARTIALLY covered leaf (a member shard or mesh slab boundary cuts
    through it) starts from `template_bytes(i)` so its uncovered bytes
    keep the template's values — consistent with leaves the plan does
    not touch at all."""

    def __init__(self, spec: FlatSpec, need: Sequence[Tuple[int, int]],
                 on_leaf: Optional[Callable[[int, np.ndarray], None]] = None,
                 template_bytes: Optional[
                     Callable[[int], np.ndarray]] = None):
        self.spec = spec
        self.offsets = [l.offset for l in spec.leaves]
        self.on_leaf = on_leaf
        self._template = template_bytes
        self._arrs: Dict[int, np.ndarray] = {}
        self._left: Dict[int, int] = {}
        self._lock = named_lock("loader.assembler")
        for lo, hi in need:
            l0 = max(0, bisect.bisect_right(self.offsets, lo) - 1)
            for i in range(l0, len(spec.leaves)):
                ls = spec.leaves[i]
                if ls.offset >= hi:
                    break
                a, b = max(lo, ls.offset), min(hi, ls.offset + ls.nbytes)
                if b > a:
                    self._left[i] = self._left.get(i, 0) + (b - a)
        self._covered0 = dict(self._left)

    @property
    def covered(self) -> Tuple[int, ...]:
        return tuple(sorted(self._left))

    def _leaf_arr(self, i: int) -> np.ndarray:
        arr = self._arrs.get(i)
        if arr is None:
            nb = self.spec.leaves[i].nbytes
            if self._template is not None and self._covered0[i] < nb:
                arr = np.array(self._template(i), np.uint8, copy=True)
            else:
                arr = np.zeros(nb, np.uint8)
            self._arrs[i] = arr
        return arr

    def write(self, global_lo: int, data: np.ndarray) -> None:
        lo, hi = global_lo, global_lo + data.nbytes
        i = max(0, bisect.bisect_right(self.offsets, lo) - 1)
        segs: List[Tuple[int, np.ndarray, int, int]] = []
        with self._lock:                   # allocation only
            pos = lo
            while pos < hi and i < len(self.spec.leaves):
                ls = self.spec.leaves[i]
                a, b = max(pos, ls.offset), min(hi, ls.offset + ls.nbytes)
                if b > a:
                    segs.append((i, self._leaf_arr(i), a, b))
                pos = b
                i += 1
        # plan writes are disjoint: the memcpys need no lock
        for i, arr, a, b in segs:
            off = self.spec.leaves[i].offset
            arr[a - off:b - off] = data[a - lo:b - lo]
        done: List[Tuple[int, np.ndarray]] = []
        with self._lock:                   # completion bookkeeping AFTER
            for i, arr, a, b in segs:      # the bytes actually landed
                left = self._left[i] - (b - a)
                self._left[i] = left
                if left <= 0:
                    done.append((i, arr))
        if self.on_leaf is not None:
            for i, arr in done:
                self.on_leaf(i, arr)

    def leaf_bytes(self, i: int) -> Optional[np.ndarray]:
        return self._arrs.get(i)


# --------------------------------------------------------------- executor
def stream_crc(read: Callable[[int, int], np.ndarray], span: int,
               chunk_bytes: int = CHUNK_BYTES) -> int:
    """zlib CRC32 of bytes [0, span) served by `read(lo, hi)`, streamed in
    fixed chunks (never holds more than one chunk)."""
    crc = 0
    for lo in range(0, span, chunk_bytes):
        crc = zlib.crc32(read(lo, min(lo + chunk_bytes, span)), crc)
    return crc


def stripe_table(meta: dict) -> Optional[Tuple[int, List[int]]]:
    """(segment_bytes, per-segment digests) from a snapshot meta, or None
    when the snapshot predates per-stripe digests (legacy / serial
    engine).  Segments are the member's local RAIM5 blocks (the whole own
    region for n == 1), recorded by the SMP at publish time."""
    table = meta.get("crc_stripes")
    if not isinstance(table, dict):
        return None
    seg, crcs = table.get("seg"), table.get("crcs")
    if not seg or not crcs:
        return None
    return int(seg), list(crcs)


def has_stripe_digests(source, node: int) -> bool:
    try:
        return stripe_table(source.meta(node)) is not None
    except Exception:
        return False


def plan_local_ranges(plan: LoadPlan) -> Dict[int, List[Tuple[int, int]]]:
    """Per-member LOCAL own-region byte ranges the executor will read:
    the plan's direct reads PLUS the stripe-sibling block sub-ranges
    feeding the failed member's decode (parity inputs are covered
    separately by `crc_parity`).  This is the footprint a per-stripe
    digest probe must cover — and nothing more."""
    out: Dict[int, List[Tuple[int, int]]] = {}
    for node, reqs in plan.reads.items():
        out.setdefault(node, []).extend(
            (r.local_lo, r.local_hi) for r in reqs)
    if plan.failed is not None and plan.decode:
        bs = raim5.block_size(plan.total_bytes, plan.n)
        for ref, subs in plan.decode:
            for j in range(plan.n - 1):
                if j == ref.index:
                    continue
                nd = raim5.node_of_block(ref.stripe, j, plan.n)
                if nd == plan.failed:
                    continue
                base = raim5.local_block_index(nd, ref.stripe, j,
                                               plan.n) * bs
                out.setdefault(nd, []).extend(
                    (base + o1, base + o2) for o1, o2 in subs)
    return out


def probe_crc(plan: LoadPlan, source, *,
              chunk_bytes: int = CHUNK_BYTES,
              workers: Optional[int] = None,
              skip: Optional[set] = None,
              stats: Optional[LoadStats] = None,
              full_verified: Optional[set] = None) -> List[int]:
    """CRC probe of every member the plan reads — including the stripe
    siblings and parity holders feeding a failed member's decode
    (`plan.touched_members`), since corrupt decode inputs would XOR into
    silently wrong reconstructed bytes.

    Members whose snapshot meta carries a per-stripe digest table verify
    ONLY the stripe segments the plan actually touches (read + crc per
    segment) — the whole point of publishing the table.  Members without
    one (legacy / serial-engine snapshots) fall back to streaming the
    full own region against the whole-region `crc_own`.  Returns the
    corrupt members; probe traffic is counted into `stats`.  `skip` names
    members already verified in a previous round (a demotion retry must
    not re-stream their shards).  `full_verified` (a set, filled in
    place) receives the members verified against the WHOLE-region digest
    — the only ones a retry may safely skip, since a stripe probe covers
    just the current plan's segments."""
    st = stats if stats is not None else LoadStats()
    bs = raim5.block_size(plan.total_bytes, plan.n) if plan.n > 1 else 0
    own_bytes = (plan.total_bytes if plan.n == 1 else (plan.n - 1) * bs)
    decode_stripes = {ref.stripe for ref, _ in plan.decode}
    local = plan_local_ranges(plan)
    lock = named_lock("loader.probe")
    t0 = time.perf_counter()

    def probe_segments(node: int, seg: int, crcs: List[int]) -> bool:
        """Verify the touched segments of `node` against its table."""
        idxs = sorted({i for lo, hi in local.get(node, ())
                       for i in range(lo // seg,
                                      (max(hi, lo + 1) - 1) // seg + 1)})
        for i in idxs:
            if i >= len(crcs):
                return False               # malformed table: distrust
            a, b = i * seg, min((i + 1) * seg, own_bytes)
            crc = stream_crc(
                lambda lo, hi, a=a: source.read_local(node, a + lo, a + hi),
                b - a, chunk_bytes)
            with lock:
                st.bytes_read += b - a
                st.probe_segments += 1
            if (crc & 0xFFFFFFFF) != (crcs[i] & 0xFFFFFFFF):
                return False
        return True

    def probe(node: int) -> Optional[int]:
        try:
            meta = source.meta(node)
        except Exception:
            return node
        expect = meta.get("crc_own")
        table = stripe_table(meta)
        if table is not None:
            seg, crcs = table
            if not probe_segments(node, seg, crcs):
                return node
        elif expect is not None:
            crc = stream_crc(lambda lo, hi: source.read_local(node, lo, hi),
                             own_bytes, chunk_bytes)
            with lock:
                st.bytes_read += own_bytes
            if (crc & 0xFFFFFFFF) != (expect & 0xFFFFFFFF):
                return node
            if full_verified is not None:
                with lock:
                    full_verified.add(node)
        if node in decode_stripes:           # its parity feeds the decode
            exp_p = meta.get("crc_parity")
            if exp_p is not None:
                crc = stream_crc(
                    lambda lo, hi: source.read_parity_range(node, lo, hi),
                    bs, chunk_bytes)
                with lock:
                    st.bytes_read += bs
                if (crc & 0xFFFFFFFF) != (exp_p & 0xFFFFFFFF):
                    return node
        if table is None and expect is None:   # legacy: nothing to verify
            return None
        with lock:
            st.crc_members += (node,)
        return None

    nodes = [nd for nd in plan.touched_members
             if not skip or nd not in skip]
    nw = workers or min(8, max(1, len(nodes)))
    if nw == 1 or len(nodes) <= 1:
        bad = [probe(nd) for nd in nodes]
    else:
        with ThreadPoolExecutor(max_workers=nw) as pool:
            bad = list(pool.map(probe, nodes))
    st.crc_members = tuple(sorted(set(st.crc_members)))
    st.read_seconds += time.perf_counter() - t0
    return sorted(nd for nd in bad if nd is not None)


def execute_plan(plan: LoadPlan, source, sink, *,
                 verify: bool = True,
                 workers: Optional[int] = None,
                 chunk_bytes: int = CHUNK_BYTES,
                 stats: Optional[LoadStats] = None,
                 sched=None) -> LoadStats:
    """Run the plan: parallel per-member ranged reads (with the member's
    own-region CRC folded into the pass when the plan covers its full
    shard), plus range-limited RAIM5 decode of the failed member.

    `sched` (a `readsched.SchedConfig`) selects the executor: None or
    mode "fcfs" runs the legacy one-task-per-member path below; "steal" /
    "adaptive" route through `readsched.ChunkScheduler` (chunked work
    stealing, EWMA bandwidth model, parity-alternative routing, hedged
    tail reads, pipelined decode).  A non-zero `sched.restore_bw_limit`
    throttles EITHER path through a shared token bucket, mirroring the
    persist side's `persist_bw_limit`.

    Raises `CrcMismatch` when a fully-read member's streamed digest does
    not match its recorded `crc_own` — callers demote that member and
    re-plan (RAIM5's single-member budget permitting).  The adaptive
    path may also raise `readsched.SourceLost` (a member died mid-read
    and could not be cleanly rerouted to parity); the ladder demotes it
    the same way."""
    st = stats if stats is not None else LoadStats()
    if sched is not None and getattr(sched, "restore_bw_limit", 0.0) > 0:
        from .readsched import BucketedSource
        from .smp import _TokenBucket
        if not isinstance(source, BucketedSource):
            source = BucketedSource(
                source, _TokenBucket(sched.restore_bw_limit,
                                     threadsafe=True))
    if sched is not None and sched.mode != "fcfs":
        from .readsched import ChunkScheduler
        return ChunkScheduler(plan, source, sink, verify=verify,
                              cfg=sched, stats=st).run()
    st.source = getattr(source, "kind", "")
    st.saved_n = plan.n
    st.bytes_needed = plan.bytes_needed
    st.members = tuple(sorted(plan.reads))
    st.sched = "fcfs"
    if verify:
        st.crc_members = ()    # only the attempt that produced the result
                               # counts (a CrcMismatch retry re-enters here);
                               # verify=False keeps a prior probe's record
    lock = named_lock("loader.gather")
    t_wall = time.perf_counter()
    marks = {"read_end": 0.0, "d0": 0.0, "d1": 0.0}

    expected: Dict[int, Any] = {}
    if verify:
        for node in plan.reads:
            try:
                expected[node] = source.meta(node).get("crc_own")
            except Exception:
                # unreadable meta = untrustworthy member: demote it like a
                # digest mismatch (the pre-loader verify_crc did the same)
                expected[node] = _META_BAD

    own_bytes = (plan.total_bytes if plan.n == 1 else
                 (plan.n - 1) * raim5.block_size(plan.total_bytes, plan.n))

    def read_member(node: int):
        reqs = plan.reads[node]
        nread = 0
        expect = expected.get(node)
        if expect is _META_BAD:
            raise CrcMismatch(
                node, reason=f"node {node} snapshot meta unreadable")
        if verify and expect is not None and plan.member_covered(node):
            # incremental CRC folded into the read pass: stream the FULL
            # local own region (incl. the tail block's zero padding the
            # engine checksummed) in fixed chunks, fold crc32, and scatter
            # the pieces the plan needs as they fly by — one pass over the
            # bytes instead of probe-then-read.
            crc = 0
            ri = 0
            for lo in range(0, own_bytes, chunk_bytes):
                hi = min(lo + chunk_bytes, own_bytes)
                data = source.read_local(node, lo, hi)
                nread += data.nbytes
                crc = zlib.crc32(data, crc)
                while ri < len(reqs) and reqs[ri].local_lo < hi:
                    r = reqs[ri]
                    a, b = max(r.local_lo, lo), min(r.local_hi, hi)
                    if b > a:
                        sink.write(r.global_lo + (a - r.local_lo),
                                   data[a - lo:b - lo])
                    if r.local_hi <= hi:
                        ri += 1
                    else:
                        break
            if (crc & 0xFFFFFFFF) != (expect & 0xFFFFFFFF):
                raise CrcMismatch(node, expect, crc)
            with lock:
                st.crc_members += (node,)
        else:
            pieces = [(a, min(a + chunk_bytes, r.local_hi),
                       r.global_lo + (a - r.local_lo))
                      for r in reqs
                      for a in range(r.local_lo, r.local_hi, chunk_bytes)]
            batched = getattr(source, "read_local_ranges", None)
            if batched is None:
                for a, b, g in pieces:
                    data = source.read_local(node, a, b)
                    nread += data.nbytes
                    sink.write(g, data)
            else:
                # scatter-gather: batch pieces per source lookup, bounded
                # to ~one chunk of live bytes
                i = 0
                while i < len(pieces):
                    group = []
                    acc = 0
                    while i < len(pieces) and acc < chunk_bytes \
                            and len(group) < 256:
                        group.append(pieces[i])
                        acc += pieces[i][1] - pieces[i][0]
                        i += 1
                    datas = batched(node, [(a, b) for a, b, _ in group])
                    for (a, b, g), data in zip(group, datas):
                        nread += data.nbytes
                        sink.write(g, data)
        with lock:
            st.bytes_read += nread
            marks["read_end"] = max(marks["read_end"],
                                    time.perf_counter())

    def run_decode():
        if plan.failed is None or not plan.decode:
            return
        t0 = time.perf_counter()
        nread = [0]
        if verify:
            # decode inputs: a corrupt survivor PARITY block would XOR
            # silently into the reconstructed bytes — verify each feeding
            # stripe's parity digest (recorded at publish) before decoding
            bs = raim5.block_size(plan.total_bytes, plan.n)
            for s in sorted({ref.stripe for ref, _ in plan.decode}):
                try:
                    expect = source.meta(s).get("crc_parity")
                except Exception:
                    expect = None          # meta-bad members are demoted
                if expect is None:         # by the read path / probe
                    continue               # (legacy snapshot: no digest)
                crc = stream_crc(
                    lambda lo, hi: source.read_parity_range(s, lo, hi),
                    bs, chunk_bytes)
                nread[0] += bs
                if (crc & 0xFFFFFFFF) != (expect & 0xFFFFFFFF):
                    raise CrcMismatch(
                        s, reason=f"node {s} parity region CRC mismatch "
                                  f"(expect {expect:#010x}, got "
                                  f"{crc:#010x})")

        def read_block_range(nd, s, j, o1, o2):
            data = source.read_block_range(nd, s, j, o1, o2)
            nread[0] += data.nbytes
            return data

        def read_parity_range(s, o1, o2):
            data = source.read_parity_range(s, o1, o2)
            nread[0] += data.nbytes
            return data

        bs = raim5.block_size(plan.total_bytes, plan.n)
        rec = raim5.decode_node_ranges(plan.failed, plan.n,
                                       plan.total_bytes, plan.need,
                                       read_block_range, read_parity_range)
        for (s, j), pieces in rec.items():
            g_lo, _ = raim5.BlockRef(s, j).byte_range(bs, plan.n)
            for o1, o2, data in pieces:
                sink.write(g_lo + o1, data)
                with lock:
                    st.decoded_bytes += o2 - o1
        with lock:
            st.bytes_read += nread[0]
            marks["d0"], marks["d1"] = t0, time.perf_counter()

    tasks: List[Callable[[], None]] = [
        (lambda nd=node: read_member(nd)) for node in plan.reads]
    tasks.append(run_decode)
    nw = workers or min(8, max(1, len(tasks)))
    st.parallel_readers = min(nw, len(tasks))
    t0 = time.perf_counter()
    if nw == 1 or len(tasks) == 1:
        for t in tasks:
            t()
    else:
        with ThreadPoolExecutor(max_workers=nw) as pool:
            futs = [pool.submit(t) for t in tasks]
            err = None
            for f in futs:
                try:
                    f.result()
                except BaseException as e:
                    # CrcMismatch beats secondaries: a concurrent member's
                    # transient read error must not mask the demote-and-
                    # replan signal the ladder acts on
                    if err is None or (isinstance(e, CrcMismatch)
                                       and not isinstance(err, CrcMismatch)):
                        err = e
            if err is not None:
                raise err
    st.crc_members = tuple(sorted(st.crc_members))
    # consistent phase attribution: read_seconds is the direct-read span,
    # decode_seconds the decode task's span, overlap_seconds their
    # intersection — read + decode - overlap never double-counts the
    # decode work that ran inside the read window
    if marks["read_end"]:
        st.read_seconds += marks["read_end"] - t0
    if marks["d1"]:
        st.decode_seconds += marks["d1"] - marks["d0"]
        r_end = marks["read_end"] or t0
        st.overlap_seconds += max(
            0.0, min(r_end, marks["d1"]) - max(t0, marks["d0"]))
    st.wall_seconds += time.perf_counter() - t_wall
    return st


def load_bytes(plan: LoadPlan, source, *, verify: bool = True,
               workers: Optional[int] = None,
               stats: Optional[LoadStats] = None,
               sched=None) -> Tuple[np.ndarray, LoadStats]:
    """Plan -> one contiguous flat buffer (zeros outside `plan.need`)."""
    sink = FlatSink(plan.total_bytes)
    st = execute_plan(plan, source, sink, verify=verify, workers=workers,
                      stats=stats, sched=sched)
    return sink.buf, st


def load_tree(plan: LoadPlan, source, template: Any, spec: FlatSpec, *,
              verify: bool = True, device_put: bool = False,
              workers: Optional[int] = None,
              stats: Optional[LoadStats] = None,
              sched=None) -> Tuple[Any, LoadStats]:
    """Plan -> pytree, assembled leaf-streamed: each leaf's array is
    built directly from its ranged reads (no full-state buffer), and with
    `device_put=True` finished leaves start their h2d transfer while
    later leaves' ranges are still being read.

    Leaves (or parts of leaves) the plan does not cover keep the
    template's values (partial restores: a leaf filter / member shard /
    mesh slice)."""
    import jax

    st = stats if stats is not None else LoadStats()
    flat, treedef = jax.tree_util.tree_flatten(template)
    done: Dict[int, Any] = {}
    h2d_lock = named_lock("loader.h2d")

    def finish(i: int, raw: np.ndarray):
        ls = spec.leaves[i]
        arr = raw.view(np.dtype(ls.dtype)).reshape(ls.shape)
        if device_put:
            t0 = time.perf_counter()
            arr = jax.device_put(arr)     # async under the remaining reads
            with h2d_lock:
                st.h2d_seconds += time.perf_counter() - t0
        done[i] = arr

    def template_bytes(i: int) -> np.ndarray:
        return np.ascontiguousarray(
            np.asarray(flat[i])).reshape(-1).view(np.uint8)

    sink = LeafSink(spec, plan.need, on_leaf=finish,
                    template_bytes=template_bytes)
    execute_plan(plan, source, sink, verify=verify, workers=workers,
                 stats=st, sched=sched)
    out = []
    for i, ls in enumerate(spec.leaves):
        arr = done.get(i)
        if arr is None:
            raw = sink.leaf_bytes(i)
            if raw is None:               # uncovered leaf: template value
                out.append(np.asarray(flat[i]))
                continue
            arr = raw.view(np.dtype(ls.dtype)).reshape(ls.shape)
        out.append(arr)
    if device_put:
        t0 = time.perf_counter()
        for a in out:
            if hasattr(a, "block_until_ready"):
                a.block_until_ready()
        st.h2d_seconds += time.perf_counter() - t0
    return jax.tree_util.tree_unflatten(treedef, out), st


# ------------------------------------------------- target -> need ranges
def need_for_leaves(spec: FlatSpec, select) -> List[Tuple[int, int]]:
    """Global ranges of the leaves whose path matches `select` (a callable
    path -> bool, or an iterable of substrings)."""
    if not callable(select):
        subs = tuple(select)
        select = lambda p: any(s in p for s in subs)   # noqa: E731
    return [(ls.offset, ls.offset + ls.nbytes)
            for ls in spec.leaves if select(ls.path)]


def member_shard_need(m: int, member: int, total_bytes: int
                      ) -> List[Tuple[int, int]]:
    """Global ranges of `member`'s own data blocks under an m-way RAIM5
    layout — what one rank of the NEW (restoring) group must load when an
    n-member snapshot is resharded onto m members."""
    if m == 1:
        return [(0, total_bytes)]
    bs = raim5.block_size(total_bytes, m)
    out = []
    for ref in raim5.data_blocks_of_node(member, m):
        lo, hi = ref.byte_range(bs, m)
        out.append((min(lo, total_bytes), min(hi, total_bytes)))
    return out


def _leaf_slab_ranges(ls, dim: int, idx: int, k: int
                      ) -> Optional[List[Tuple[int, int]]]:
    """Byte ranges of slab `idx`/`k` along `dim` of one leaf (evenly
    divisible dims only; None = not representable within the range cap)."""
    shape = ls.shape
    if not shape or shape[dim] % k:
        return None
    per = shape[dim] // k
    item = np.dtype(ls.dtype).itemsize
    inner = item
    for d in range(dim + 1, len(shape)):
        inner *= shape[d]
    lead = 1
    for d in range(dim):
        lead *= shape[d]
    if lead > MAX_SLAB_RANGES:
        return None
    stride = shape[dim] * inner
    out = []
    for li in range(lead):
        a = ls.offset + li * stride + idx * per * inner
        out.append((a, a + per * inner))
    return out


def need_for_sharding(spec: FlatSpec, shardings: Any, mesh: Any,
                      coord: Dict[str, int]) -> List[Tuple[int, int]]:
    """Global ranges of THIS rank's slice under a `repro.dist` sharding:
    `shardings` is a PartitionSpec pytree leaf-aligned with the state,
    adapted to `mesh` by the same rules training uses (`adapt_spec`), and
    `coord` gives the rank's index on each mesh axis.  Dims the adapted
    spec leaves unsharded (or slabs too strided to enumerate) fall back
    to the whole leaf."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.dist.api import adapt_spec

    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    flat_specs = jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_specs) == len(spec.leaves), \
        f"sharding tree has {len(flat_specs)} leaves, state has " \
        f"{len(spec.leaves)}"
    need: List[Tuple[int, int]] = []
    for ls, sp in zip(spec.leaves, flat_specs):
        adapted = adapt_spec(sp, ls.shape, mesh) if len(ls.shape) else P()
        picked = None
        for dim, entry in enumerate(adapted):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            k = 1
            idx = 0
            for nm in names:
                idx = idx * sizes[nm] + coord.get(nm, 0)
                k *= sizes[nm]
            if k > 1:
                picked = (dim, idx, k)
                break                    # first sharded dim bounds the slab
        if picked is None:
            need.append((ls.offset, ls.offset + ls.nbytes))
            continue
        slab = _leaf_slab_ranges(ls, *picked)
        if slab is None:
            need.append((ls.offset, ls.offset + ls.nbytes))
        else:
            need.extend(slab)
    return need


def resolve_need(spec: FlatSpec, target) -> Optional[List[Tuple[int, int]]]:
    """`RestoreTarget` -> global byte ranges (None = full state).

    Filters compose by intersection: a leaf filter restricted to a new
    member's byte shard loads exactly the overlap."""
    if target is None:
        return None
    needs: List[Tuple[Tuple[int, int], ...]] = []
    if getattr(target, "leaves", None):
        needs.append(normalize_ranges(need_for_leaves(spec, target.leaves),
                                      spec.total_bytes))
    if getattr(target, "member", None) is not None:
        m = target.sg_size
        if not m:
            raise ValueError(
                "RestoreTarget.member needs sg_size (the restoring "
                "group's size) to define the member's byte shard")
        if not 0 <= target.member < m:
            raise ValueError(
                f"RestoreTarget.member {target.member} out of range for "
                f"sg_size {m}")
        needs.append(normalize_ranges(
            member_shard_need(m, target.member, spec.total_bytes),
            spec.total_bytes))
    if getattr(target, "shardings", None) is not None \
            and getattr(target, "mesh", None) is not None:
        needs.append(normalize_ranges(
            need_for_sharding(spec, target.shardings, target.mesh,
                              target.coord or {}), spec.total_bytes))
    if not needs:
        return None
    out = needs[0]
    for nxt in needs[1:]:
        acc: List[Tuple[int, int]] = []
        for lo, hi in out:
            acc.extend(_intersect(nxt, lo, hi))
        out = normalize_ranges(acc, spec.total_bytes)
    return list(out)


__all__ = [
    "CHUNK_BYTES", "CrcMismatch", "RangeReq", "LoadPlan", "LoadStats",
    "ShmSource", "FileSource", "ObjectSource", "ChainSource", "DeltaLayer",
    "FlatSink", "LeafSink",
    "normalize_ranges",
    "build_plan", "execute_plan", "load_bytes", "load_tree",
    "need_for_leaves", "member_shard_need", "need_for_sharding",
    "resolve_need", "stripe_table", "has_stripe_digests",
    "plan_local_ranges", "probe_crc", "stream_crc",
]
