"""Hierarchical asynchronous snapshot pipeline (HASC, paper §4.1's
"three-level asynchronous on-device scheduling").

The monolithic snapshot thread (read -> CRC -> blocking ring-send per
bucket) is replaced by three cooperating levels, each with its own
backpressure signal, so saving and training contend as little as the
hardware allows:

  L1 device pump    windowed ``copy_to_host_async`` prefetch over the
                    upcoming buckets, double-buffered scratch fills, a
                    bucket schedule that drains optimizer-moment leaves
                    first, and cooperative yields at training step
                    boundaries (`StepBoundaryGate`).
  L2 host stager    moves ready buckets into the SMP staging ring under
                    credit-based flow control: scratch-buffer credits
                    upstream (to L1), ring-slot semaphore credits
                    downstream (from the SMP's bucket consumption).
  L3 SMP            event-driven begin/bucket/end over the pipe; the
                    own-region CRC is computed inside the SMP at ``end``
                    (off every trainer-side critical path); the clean-ack
                    completes the flight.

The flight keeps `snapshot_async`/`snapshot_sync`/`wait` semantics and the
dirty-never-visible invariant: an aborted flight never sends ``end``, so
the dirty buffer is never published.
"""
from __future__ import annotations

import bisect
import pickle
import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.treebytes import FlatSpec, iter_buckets

__all__ = [
    "StepBoundaryGate", "step_boundary", "BucketTask", "build_schedule",
    "leaf_budget", "LeafReader", "PipelineResult", "PipelineFlight",
    "SnapshotPipeline",
]


# ------------------------------------------------------------ L1 yield gate
class StepBoundaryGate:
    """Condition-variable gate the training loop ticks once per step.

    The L1 pump periodically waits for the *next* tick so its bucket bursts
    align with step boundaries instead of racing the forward/backward pass
    for host bandwidth.  The gate only throttles while a trainer is
    actually ticking (`ACTIVE_WINDOW`); a standalone snapshot (benchmarks,
    tests, recovery drills) runs unthrottled.
    """

    ACTIVE_WINDOW = 2.0          # seconds since last tick that count as live

    def __init__(self):
        self._cond = threading.Condition()
        self._tick = 0
        self._last = float("-inf")

    def notify(self) -> None:
        with self._cond:
            self._tick += 1
            self._last = time.monotonic()
            self._cond.notify_all()

    def active(self) -> bool:
        return (time.monotonic() - self._last) < self.ACTIVE_WINDOW

    def wait_boundary(self, timeout: float) -> bool:
        """Wait for the next step boundary; no-op when no trainer is live.
        Returns True if a boundary arrived within `timeout`."""
        if timeout <= 0 or not self.active():
            return False
        with self._cond:
            t = self._tick
            return self._cond.wait_for(lambda: self._tick > t,
                                       timeout=timeout)


GATE = StepBoundaryGate()


def step_boundary() -> None:
    """Signal a training step boundary to every in-flight snapshot pipeline
    (the hook `train.steps.with_step_boundary` and
    `CheckpointSession.after_step` call)."""
    GATE.notify()


# ------------------------------------------------------------- scheduling
_OPT_MARKERS = ("opt", "mu", "nu", "moment", "adam", "exp_avg")


def _is_opt_path(path: str) -> bool:
    p = path.lower()
    return any(m in p for m in _OPT_MARKERS)


@dataclass(frozen=True)
class BucketTask:
    """One staging-ring bucket: bytes [lo, hi) of the flat stream, written
    at `dst` of the own region (kind 0) or XORed into parity (kind 1)."""
    kind: int                    # 0 = own data block bytes, 1 = parity
    dst: int                     # destination offset within the region
    lo: int                      # global flat-stream byte range
    hi: int
    leaf_lo: int                 # first/last+1 spec-leaf index overlapped
    leaf_hi: int
    opt: bool                    # bucket starts inside an optimizer leaf


def _leaf_span(offsets: Sequence[int], spec: FlatSpec,
               lo: int, hi: int) -> Tuple[int, int]:
    l0 = max(0, bisect.bisect_right(offsets, lo) - 1)
    l1 = bisect.bisect_left(offsets, hi)
    return l0, min(l1, len(spec.leaves))


def build_schedule(spec: FlatSpec,
                   own_plan: Sequence[Tuple[int, int, int]],
                   stripe_plan: Sequence[Tuple[int, int]],
                   bucket_bytes: int, *,
                   opt_first: bool = True) -> List[BucketTask]:
    """Bucket-split both plans into `BucketTask`s.  With `opt_first`, the
    buckets that start inside optimizer-moment leaves drain first: the
    moments are dead weights until the next optimizer update, so saving
    them first maximises the window in which training may already mutate
    (rebind) the parameter leaves it is about to need."""
    offsets = [l.offset for l in spec.leaves]
    tasks: List[BucketTask] = []
    for dst0, lo, hi in own_plan:
        for a, b in iter_buckets(lo, hi, bucket_bytes):
            l0, l1 = _leaf_span(offsets, spec, a, b)
            opt = l0 < len(spec.leaves) and _is_opt_path(spec.leaves[l0].path)
            tasks.append(BucketTask(0, dst0 + (a - lo), a, b, l0, l1, opt))
    for lo, hi in stripe_plan:
        for a, b in iter_buckets(lo, hi, bucket_bytes):
            l0, l1 = _leaf_span(offsets, spec, a, b)
            opt = l0 < len(spec.leaves) and _is_opt_path(spec.leaves[l0].path)
            tasks.append(BucketTask(1, a - lo, a, b, l0, l1, opt))
    if opt_first:
        tasks.sort(key=lambda t: 0 if t.opt else 1)      # stable
    return tasks


def leaf_budget(spec: FlatSpec,
                ranges: Sequence[Tuple[int, int]]) -> Dict[int, int]:
    """Bytes of each leaf this node will ever read, over all plan ranges —
    the eviction budget for `LeafReader` (drop a leaf's host copy the
    moment its last byte is consumed, instead of caching the whole state
    per snapshot)."""
    offsets = [l.offset for l in spec.leaves]
    out: Dict[int, int] = {}
    for lo, hi in ranges:
        l0, l1 = _leaf_span(offsets, spec, lo, min(hi, spec.total_bytes))
        for i in range(l0, l1):
            ls = spec.leaves[i]
            a, b = max(lo, ls.offset), min(hi, ls.offset + ls.nbytes)
            if b > a:
                out[i] = out.get(i, 0) + (b - a)
    return out


class LeafReader:
    """Random byte-range access over the flat stream with per-snapshot host
    caching (each leaf is device_get at most once per snapshot).  With a
    `budget` ({leaf_idx: bytes that will be read}), a leaf's host copy is
    evicted as soon as its byte ranges are fully consumed, bounding the
    host-cache footprint to the live working set instead of the entire
    state."""

    def __init__(self, spec: FlatSpec, leaves: List[Any],
                 budget: Optional[Dict[int, int]] = None):
        self.spec = spec
        self.leaves = leaves
        self.offsets = [l.offset for l in spec.leaves]
        self._host: Dict[int, np.ndarray] = {}
        self._budget = budget
        self._consumed: Dict[int, int] = {}

    def _leaf_bytes(self, i: int) -> np.ndarray:
        if i not in self._host:
            arr = np.asarray(self.leaves[i])          # d2h happens here
            self._host[i] = np.ascontiguousarray(arr).reshape(-1) \
                .view(np.uint8)
        return self._host[i]

    def read(self, lo: int, hi: int, out: np.ndarray) -> None:
        i = bisect.bisect_right(self.offsets, lo) - 1
        pos = lo
        while pos < hi and i < len(self.spec.leaves):
            ls = self.spec.leaves[i]
            a = max(pos, ls.offset)
            b = min(hi, ls.offset + ls.nbytes)
            if b > a:
                out[a - lo:b - lo] = self._leaf_bytes(i)[a - ls.offset:
                                                         b - ls.offset]
                if self._budget is not None:
                    got = self._consumed.get(i, 0) + (b - a)
                    self._consumed[i] = got
                    if got >= self._budget.get(i, float("inf")):
                        self._host.pop(i, None)
            pos = b
            i += 1
        if pos < hi:                                   # zero-pad past end
            out[pos - lo:hi - lo] = 0

    def cached_leaves(self) -> int:
        return len(self._host)


# --------------------------------------------------------------- flights
@dataclass(frozen=True)
class PipelineResult:
    """Per-flight outcome with the per-level timing decomposition."""
    step: int
    clean_step: int
    bytes_sent: int
    l1_seconds: float            # device->host reads (+ prefetch issue)
    l1_stall_seconds: float      # waiting for a scratch-buffer credit
    l2_seconds: float            # staging-ring writes incl. slot waits
    l3_seconds: float            # begin/end signaling + SMP clean-ack
    wall_seconds: float


_STOP = object()


class PipelineFlight:
    """One in-flight snapshot: an L1 pump thread and an L2 stager thread
    joined by credit queues.  `wait` never drops a live flight (a timeout
    raises and the flight stays current), and an aborted flight never
    sends `end`, so a half-written dirty buffer is never published."""

    def __init__(self, smp, spec: FlatSpec, cfg, schedule: List[BucketTask],
                 budget: Dict[int, int], leaves: List[Any], step: int,
                 extra_meta: dict):
        self.smp, self.spec, self.cfg = smp, spec, cfg
        self.schedule, self.budget = schedule, budget
        self.leaves, self.step, self.extra_meta = leaves, step, extra_meta
        self.result: Optional[PipelineResult] = None
        self.error: Optional[BaseException] = None
        self.done = threading.Event()
        self._abort = threading.Event()
        # set while a caller is blocked in wait(): the trainer cannot tick
        # step boundaries then, so the pump must not wait for them
        self._draining = threading.Event()
        self._free: "queue.Queue" = queue.Queue()
        self._ready: "queue.Queue" = queue.Queue()
        # honor the knob down to 1 (a single credit fully serializes L1/L2,
        # useful for debugging and minimal host footprint)
        for _ in range(max(1, getattr(cfg, "scratch_buffers", 2))):
            self._free.put(np.empty(cfg.bucket_bytes, np.uint8))
        self._l1_read = 0.0
        self._l1_stall = 0.0
        self._t0 = time.perf_counter()
        self._pump_t = threading.Thread(target=self._pump, daemon=True,
                                        name=f"hasc-l1-s{step}")
        self._stage_t = threading.Thread(target=self._stage, daemon=True,
                                         name=f"hasc-l2-s{step}")

    def launch(self) -> "PipelineFlight":
        self._stage_t.start()
        self._pump_t.start()
        return self

    # ------------------------------------------------------------- L1
    def _get_credit(self) -> np.ndarray:
        while True:
            try:
                t0 = time.perf_counter()
                buf = self._free.get(timeout=0.5)
                self._l1_stall += time.perf_counter() - t0
                return buf
            except queue.Empty:
                self._l1_stall += 0.5
                if self._abort.is_set():
                    raise RuntimeError("snapshot pipeline aborted") from None

    def _pump(self):
        try:
            reader = LeafReader(self.spec, self.leaves, self.budget)
            issued: set = set()
            window = max(1, getattr(self.cfg, "prefetch_window", 4))
            yield_every = max(0, getattr(self.cfg, "yield_every_buckets", 4))
            yield_timeout = getattr(self.cfg, "boundary_timeout_s", 0.005)
            sched = self.schedule
            for i, task in enumerate(sched):
                if self._abort.is_set():
                    raise RuntimeError("snapshot pipeline aborted")
                t0 = time.perf_counter()
                for nxt in sched[i:i + window]:        # windowed prefetch
                    for li in range(nxt.leaf_lo, nxt.leaf_hi):
                        if li not in issued:
                            issued.add(li)
                            try:
                                self.leaves[li].copy_to_host_async()
                            except AttributeError:
                                pass
                self._l1_read += time.perf_counter() - t0
                if yield_every and i and i % yield_every == 0 \
                        and not self._draining.is_set():
                    GATE.wait_boundary(yield_timeout)  # yield to training
                buf = self._get_credit()
                nb = task.hi - task.lo
                t0 = time.perf_counter()
                reader.read(task.lo, task.hi, buf[:nb])
                self._l1_read += time.perf_counter() - t0
                self._ready.put((task, buf, nb))
        except BaseException as e:
            if self.error is None:
                self.error = e
            self._abort.set()
        finally:
            self._ready.put(_STOP)

    # ------------------------------------------------------------- L2
    def _stage(self):
        try:
            t_l2 = 0.0
            sent = 0
            t0 = time.perf_counter()
            self.smp.begin(self.step)
            t_l3 = time.perf_counter() - t0
            while True:
                item = self._ready.get()
                if item is _STOP:
                    break
                task, buf, nb = item
                t0 = time.perf_counter()
                self.smp.send_bucket(task.kind, task.dst, buf[:nb])
                t_l2 += time.perf_counter() - t0
                sent += nb
                self._free.put(buf)                    # return the credit
            if self._abort.is_set():                   # no `end`: dirty
                return                                 # buffer stays unseen
            meta = {"spec": self.spec.to_json(), "step": self.step,
                    "extra": self.extra_meta}
            t0 = time.perf_counter()
            self.smp.end(self.step, pickle.dumps(meta), want_crc=True)
            clean = self.smp.wait_clean()
            t_l3 += time.perf_counter() - t0
            self.result = PipelineResult(
                step=self.step, clean_step=clean, bytes_sent=sent,
                l1_seconds=self._l1_read, l1_stall_seconds=self._l1_stall,
                l2_seconds=t_l2, l3_seconds=t_l3,
                wall_seconds=time.perf_counter() - self._t0)
        except BaseException as e:
            if self.error is None:
                self.error = e
            self._abort.set()
        finally:
            self.done.set()

    # ----------------------------------------------------------- public
    def in_flight(self) -> bool:
        return not self.done.is_set()

    def wait(self, timeout: float = 300.0) -> PipelineResult:
        """Idempotent: a finished flight re-raises its stored error (or
        returns its result) on every call, so callers can distinguish
        'still live' (the wait-timeout below) from 'failed with an internal
        TimeoutError like an SMP ack timeout' by re-collecting after
        checking `in_flight()`."""
        self._draining.set()
        try:
            if not self.done.wait(timeout):
                raise TimeoutError(
                    f"snapshot pipeline for step {self.step} still in "
                    f"flight after {timeout:.1f}s")
        finally:
            if not self.done.is_set():     # timed out: trainer resumes,
                self._draining.clear()     # boundary yields matter again
        self._pump_t.join(timeout=5.0)
        self._stage_t.join(timeout=5.0)
        if self.error is not None:
            raise self.error
        assert self.result is not None
        return self.result


class SnapshotPipeline:
    """Per-engine HASC driver: owns the (static) bucket schedule and leaf
    budget; `start` launches one `PipelineFlight` at a time."""

    def __init__(self, smp, spec: FlatSpec, cfg,
                 own_plan: Sequence[Tuple[int, int, int]],
                 stripe_plan: Sequence[Tuple[int, int]]):
        self.smp, self.spec, self.cfg = smp, spec, cfg
        self.schedule = build_schedule(
            spec, own_plan, stripe_plan, cfg.bucket_bytes,
            opt_first=getattr(cfg, "opt_first", True))
        self.budget = leaf_budget(
            spec, [(lo, hi) for _, lo, hi in own_plan] + list(stripe_plan))

    def start(self, leaves: List[Any], step: int,
              extra_meta: dict) -> PipelineFlight:
        return PipelineFlight(self.smp, self.spec, self.cfg, self.schedule,
                              self.budget, leaves, step, extra_meta).launch()
