"""Hierarchical asynchronous snapshot pipeline (HASC, paper §4.1's
"three-level asynchronous on-device scheduling").

The monolithic snapshot thread (read -> CRC -> blocking ring-send per
bucket) is replaced by three cooperating levels, each with its own
backpressure signal, so saving and training contend as little as the
hardware allows:

  L1 device pump    windowed ``copy_to_host_async`` prefetch over the
                    upcoming buckets (batched ``jax.device_get`` per
                    prefetch window), double-buffered scratch fills, a
                    bucket schedule that drains optimizer-moment leaves
                    first, and cooperative yields at training step
                    boundaries (`StepBoundaryGate`).  With
                    ``device_encode`` the pump instead gathers each
                    bucket's leaf byte-ranges on the accelerator and runs
                    the fused Pallas encode kernel (XOR parity + CRC32,
                    `repro.kernels.stage`) *before* the d2h copy.
  L2 host stager    moves ready buckets into the SMP staging ring under
                    credit-based flow control: scratch-buffer credits
                    upstream (to L1), ring-slot semaphore credits
                    downstream (from the SMP's bucket consumption).
                    Best-effort pinned to the saving-path CPU set
                    (`ReftConfig.pin_cpus`).
  L3 SMP            event-driven begin/bucket/end over the pipe; the
                    own-region CRC is computed inside the SMP at ``end``
                    (off every trainer-side critical path) — or handed
                    over precombined when the device encode path already
                    produced per-bucket digests; the clean-ack completes
                    the flight.

Multi-flight overlap: with ``max_flights > 1`` snapshot N+1's L1 pump may
start while snapshot N drains L2/L3.  Flights chain on two events —
N+1's pump waits for N's *pump* to finish (so the shared scratch-credit
pool is drained oldest-first, deadlock-free), and N+1's stager waits for
N's clean-ack before ``begin`` (so the SMP never holds two dirty
buffers).  The scratch pool is owned by the pipeline, not the flight, so
scratch memory stays fixed at ``scratch_buffers`` buckets no matter how
many flights are in the air.

The flight keeps `snapshot_async`/`snapshot_sync`/`wait` semantics and the
dirty-never-visible invariant: an aborted flight never sends ``end``, so
the dirty buffer is never published.
"""
from __future__ import annotations

import bisect
import os
import pickle
import queue
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analyze.lockgraph import named_condition
from repro.core.crcutil import crc32_concat
from repro.core.delta import FlightDelta, merge_ranges, task_dirty
from repro.core.treebytes import FlatSpec, iter_buckets

__all__ = [
    "StepBoundaryGate", "step_boundary", "BucketTask", "build_schedule",
    "leaf_budget", "leaf_extents", "LeafReader", "DeviceEncoder", "PipelineResult",
    "PipelineFlight", "SnapshotPipeline", "resolve_device_encode",
    "resolve_ranged_fetch",
    "resolve_affinity", "pin_current_thread", "task_local_extent",
    "DeltaBaseMismatch",
]


class DeltaBaseMismatch(RuntimeError):
    """The SMP's latest clean buffer is not the delta flight's base step:
    the flight aborts (nothing published) and the tracker must take a
    keyframe next."""


# ------------------------------------------------------------ L1 yield gate
class StepBoundaryGate:
    """Condition-variable gate the training loop ticks once per step.

    The L1 pump periodically waits for the *next* tick so its bucket bursts
    align with step boundaries instead of racing the forward/backward pass
    for host bandwidth.  The gate only throttles while a trainer is
    actually ticking (`ACTIVE_WINDOW`); a standalone snapshot (benchmarks,
    tests, recovery drills) runs unthrottled.
    """

    ACTIVE_WINDOW = 2.0          # seconds since last tick that count as live

    def __init__(self):
        self._cond = named_condition("pipeline.gate")
        self._tick = 0
        self._last = float("-inf")

    def notify(self) -> None:
        with self._cond:
            self._tick += 1
            self._last = time.monotonic()
            self._cond.notify_all()

    def active(self) -> bool:
        return (time.monotonic() - self._last) < self.ACTIVE_WINDOW

    def wait_boundary(self, timeout: float) -> bool:
        """Wait for the next step boundary; no-op when no trainer is live.
        Returns True if a boundary arrived within `timeout`."""
        if timeout <= 0 or not self.active():
            return False
        with self._cond:
            t = self._tick
            return self._cond.wait_for(lambda: self._tick > t,
                                       timeout=timeout)


GATE = StepBoundaryGate()


def step_boundary() -> None:
    """Signal a training step boundary to every in-flight snapshot pipeline
    (the hook `train.steps.with_step_boundary` and
    `CheckpointSession.after_step` call)."""
    GATE.notify()


# --------------------------------------------------------- mode resolution
def resolve_device_encode(cfg) -> bool:
    """`ReftConfig.device_encode`: "on" forces the device encode path
    (interpret-mode kernels on CPU — what CI exercises), "off" forces the
    host path, "auto" enables it exactly when a real accelerator backs
    the default JAX backend."""
    mode = str(getattr(cfg, "device_encode", "auto")).lower()
    if mode in ("on", "true", "1"):
        return True
    if mode in ("off", "false", "0"):
        return False
    import jax
    return jax.default_backend() != "cpu"


def resolve_ranged_fetch(cfg) -> bool:
    """`ReftConfig.ranged_fetch`: slice each leaf down to the byte extent
    a sparse delta flight actually reads *on the device* before the d2h
    copy.  "on"/"off" force it; "auto" enables it exactly when a real
    accelerator backs the default JAX backend — on the CPU backend
    `np.asarray` of a leaf is already zero-copy, so device-side slicing
    is pure dispatch overhead there."""
    mode = str(getattr(cfg, "ranged_fetch", "auto")).lower()
    if mode in ("on", "true", "1"):
        return True
    if mode in ("off", "false", "0"):
        return False
    import jax
    return jax.default_backend() != "cpu"


def resolve_affinity(pin) -> Optional[Tuple[int, ...]]:
    """Saving-path CPU set for the L2 stager thread + SMP process.

    `None`/"off" disables pinning; "auto" reserves the trailing eighth of
    the allowed CPUs on hosts big enough for it to help (>= 8 allowed
    cores — tiny CI runners are left alone); an explicit sequence is
    intersected with the allowed set.  Best-effort: unsupported platforms
    resolve to None."""
    if pin is None or pin is False or pin == "off":   # NB: identity, not
        return None                                   # ==: cpu id 0 != False
    if pin is True:
        pin = "auto"
    if not hasattr(os, "sched_getaffinity"):
        return None
    try:
        avail = sorted(os.sched_getaffinity(0))
    except OSError:
        return None
    if pin == "auto":
        if len(avail) < 8:
            return None
        k = max(1, len(avail) // 8)
        return tuple(avail[-k:])
    try:                                 # best-effort: a malformed knob
        if isinstance(pin, int):         # (bare int, "0,1" string, junk)
            pin = (pin,)                 # must never fail engine setup
        elif isinstance(pin, str):
            pin = pin.replace(",", " ").split()
        cpus = tuple(c for c in (int(x) for x in pin) if c in avail)
    except (TypeError, ValueError):
        return None
    return cpus or None


def pin_current_thread(cpus) -> Optional[Tuple[int, ...]]:
    """Pin the calling thread (Linux: per-thread affinity) to `cpus`.
    Returns the applied set, or None where unsupported/denied."""
    if not cpus or not hasattr(os, "sched_setaffinity"):
        return None
    try:
        os.sched_setaffinity(0, cpus)
        return tuple(sorted(os.sched_getaffinity(0)))
    except (AttributeError, OSError):
        return None


# ------------------------------------------------------------- scheduling
_OPT_MARKERS = ("opt", "mu", "nu", "moment", "adam", "exp_avg")


def _is_opt_path(path: str) -> bool:
    p = path.lower()
    return any(m in p for m in _OPT_MARKERS)


@dataclass(frozen=True)
class BucketTask:
    """One staging-ring bucket: bytes [lo, hi) of the flat stream, written
    at `dst` of the own region (kind 0), XORed into parity (kind 1), or —
    device encode path — the XOR of the stripe's `sources` ranges written
    straight into parity (kind 2, one d2h'd block instead of n-1)."""
    kind: int                    # 0 = own data, 1 = host parity XOR,
                                 # 2 = device-encoded parity write
    dst: int                     # destination offset within the region
    lo: int                      # global flat-stream byte range (kind 2:
    hi: int                      # the first source range)
    leaf_lo: int                 # first/last+1 spec-leaf index overlapped
    leaf_hi: int
    opt: bool                    # bucket starts inside an optimizer leaf
    sources: Tuple[Tuple[int, int], ...] = ()   # kind 2: stripe ranges


def _leaf_span(offsets: Sequence[int], spec: FlatSpec,
               lo: int, hi: int) -> Tuple[int, int]:
    l0 = max(0, bisect.bisect_right(offsets, lo) - 1)
    l1 = bisect.bisect_left(offsets, hi)
    return l0, min(l1, len(spec.leaves))


def task_local_extent(task: BucketTask, own_bytes: int) -> Tuple[int, int]:
    """Buffer-local byte extent a task writes: own-region offset for
    kind 0, parity-region offset (past `own_bytes`) for kinds 1/2."""
    nb = task.hi - task.lo
    if task.kind == 0:
        return (task.dst, task.dst + nb)
    return (own_bytes + task.dst, own_bytes + task.dst + nb)


def build_schedule(spec: FlatSpec,
                   own_plan: Sequence[Tuple[int, int, int]],
                   stripe_plan: Sequence[Tuple[int, int]],
                   bucket_bytes: int, *,
                   opt_first: bool = True,
                   fuse_parity: bool = False,
                   dirty: Optional[Sequence[Tuple[int, int]]] = None):
    """Bucket-split both plans into `BucketTask`s.  With `opt_first`, the
    buckets that start inside optimizer-moment leaves drain first: the
    moments are dead weights until the next optimizer update, so saving
    them first maximises the window in which training may already mutate
    (rebind) the parameter leaves it is about to need.

    With `fuse_parity` (device encode path) the stripe plan becomes one
    kind-2 task per *parity-region* bucket, carrying the n-1 source
    ranges the device kernel XOR-folds — the parity leaves the device
    already encoded, cutting parity d2h traffic by (n-1)x.

    Delta mode: with `dirty` (merged global byte ranges that may have
    changed since the base snapshot) the return value becomes
    ``(tasks, delta_map)`` where `delta_map` maps the index of each
    DIRTY task in the (full) schedule to the buffer-local extent it
    rewrites — tasks absent from the map are clean and a delta flight
    skips them before any read or d2h."""
    offsets = [l.offset for l in spec.leaves]
    tasks: List[BucketTask] = []
    for dst0, lo, hi in own_plan:
        for a, b in iter_buckets(lo, hi, bucket_bytes):
            l0, l1 = _leaf_span(offsets, spec, a, b)
            opt = l0 < len(spec.leaves) and _is_opt_path(spec.leaves[l0].path)
            tasks.append(BucketTask(0, dst0 + (a - lo), a, b, l0, l1, opt))
    if fuse_parity and stripe_plan:
        bases = [lo for lo, _ in stripe_plan]
        bs = stripe_plan[0][1] - stripe_plan[0][0]
        for a, b in iter_buckets(0, bs, bucket_bytes):
            srcs = tuple((base + a, base + b) for base in bases)
            l0, l1 = _leaf_span(offsets, spec, srcs[0][0], srcs[0][1])
            opt = l0 < len(spec.leaves) and _is_opt_path(spec.leaves[l0].path)
            tasks.append(BucketTask(2, a, srcs[0][0], srcs[0][1], l0, l1,
                                    opt, srcs))
    else:
        for lo, hi in stripe_plan:
            for a, b in iter_buckets(lo, hi, bucket_bytes):
                l0, l1 = _leaf_span(offsets, spec, a, b)
                opt = l0 < len(spec.leaves) \
                    and _is_opt_path(spec.leaves[l0].path)
                tasks.append(BucketTask(1, a - lo, a, b, l0, l1, opt))
    if opt_first:
        tasks.sort(key=lambda t: 0 if t.opt else 1)      # stable
    if dirty is None:
        return tasks
    own_bytes = sum(hi - lo for _, lo, hi in own_plan)
    ranges = merge_ranges(dirty)
    delta_map = {i: task_local_extent(t, own_bytes)
                 for i, t in enumerate(tasks) if task_dirty(t, ranges)}
    return tasks, delta_map


def leaf_budget(spec: FlatSpec,
                ranges: Sequence[Tuple[int, int]]) -> Dict[int, int]:
    """Bytes of each leaf this node will ever read, over all plan ranges —
    the eviction budget for `LeafReader` (drop a leaf's host copy the
    moment its last byte is consumed, instead of caching the whole state
    per snapshot)."""
    offsets = [l.offset for l in spec.leaves]
    out: Dict[int, int] = {}
    for lo, hi in ranges:
        l0, l1 = _leaf_span(offsets, spec, lo, min(hi, spec.total_bytes))
        for i in range(l0, l1):
            ls = spec.leaves[i]
            a, b = max(lo, ls.offset), min(hi, ls.offset + ls.nbytes)
            if b > a:
                out[i] = out.get(i, 0) + (b - a)
    return out


def leaf_extents(spec: FlatSpec,
                 ranges: Sequence[Tuple[int, int]]) -> Dict[int, Tuple[int,
                                                                       int]]:
    """Per-leaf [lo, hi) byte extent (relative to the leaf start, aligned
    down/up to the leaf's element size) that covers every plan range — a
    `LeafReader` given extents d2h-transfers only that flat slice of each
    leaf instead of the whole array, so a sparse delta flight pays d2h
    for what changed, not for model size."""
    offsets = [l.offset for l in spec.leaves]
    out: Dict[int, Tuple[int, int]] = {}
    for lo, hi in ranges:
        l0, l1 = _leaf_span(offsets, spec, lo, min(hi, spec.total_bytes))
        for i in range(l0, l1):
            ls = spec.leaves[i]
            a, b = max(lo, ls.offset) - ls.offset, \
                min(hi, ls.offset + ls.nbytes) - ls.offset
            if b <= a:
                continue
            cur = out.get(i)
            out[i] = (a, b) if cur is None else (min(cur[0], a),
                                                 max(cur[1], b))
    for i, (a, b) in out.items():
        ls = spec.leaves[i]
        isz = max(1, np.dtype(ls.dtype).itemsize)
        out[i] = ((a // isz) * isz, min(-(-b // isz) * isz, ls.nbytes))
    return out


class LeafReader:
    """Random byte-range access over the flat stream with per-snapshot host
    caching (each leaf is device_get at most once per snapshot).  With a
    `budget` ({leaf_idx: bytes that will be read}), a leaf's host copy is
    evicted as soon as its byte ranges are fully consumed, bounding the
    host-cache footprint to the live working set instead of the entire
    state.  With `extents` ({leaf_idx: (rel_lo, rel_hi)}), only that flat
    byte slice of a leaf crosses the d2h link — sparse delta flights hand
    the per-flight extents of their surviving work items here.  `fetch`
    batch-transfers a prefetch window's leaves in one
    `jax.device_get(list)` instead of a synchronous per-leaf read."""

    def __init__(self, spec: FlatSpec, leaves: List[Any],
                 budget: Optional[Dict[int, int]] = None,
                 extents: Optional[Dict[int, Tuple[int, int]]] = None):
        self.spec = spec
        self.leaves = leaves
        self.offsets = [l.offset for l in spec.leaves]
        self._host: Dict[int, np.ndarray] = {}
        self._base: Dict[int, int] = {}
        self._budget = budget
        self._extents = extents
        self._consumed: Dict[int, int] = {}
        self.batched_fetches = 0

    @staticmethod
    def _as_bytes(arr) -> np.ndarray:
        return np.ascontiguousarray(arr).reshape(-1).view(np.uint8)

    def _device_slice(self, i: int):
        """The device array (or flat sub-slice) to transfer for leaf `i`,
        plus the byte offset of that slice within the leaf."""
        leaf = self.leaves[i]
        ext = self._extents.get(i) if self._extents else None
        if ext is None:
            return leaf, 0
        ls = self.spec.leaves[i]
        lo, hi = ext
        if lo <= 0 and hi >= ls.nbytes:
            return leaf, 0
        isz = max(1, np.dtype(ls.dtype).itemsize)
        # reshape(-1) is free (row-major); the slice stays on device so
        # only ext bytes cross the d2h link
        return leaf.reshape(-1)[lo // isz:hi // isz], lo

    def fetch(self, idxs: Sequence[int]) -> None:
        """Batched d2h for every listed leaf not yet cached: pre-warm with
        `copy_to_host_async`, then ONE `jax.device_get(list)` — the L1
        pump calls this per prefetch-window advance instead of paying a
        synchronous `np.asarray` per leaf at first touch."""
        missing = [i for i in idxs if i not in self._host]
        if not missing:
            return
        slices = []
        for i in missing:
            dev, base = self._device_slice(i)
            slices.append(dev)
            self._base[i] = base
            try:
                dev.copy_to_host_async()
            except AttributeError:
                pass
        import jax
        got = jax.device_get(slices)
        for i, arr in zip(missing, got):
            self._host[i] = self._as_bytes(arr)
        self.batched_fetches += 1

    def _leaf_bytes(self, i: int) -> np.ndarray:
        if i not in self._host:
            dev, base = self._device_slice(i)
            self._base[i] = base
            self._host[i] = self._as_bytes(np.asarray(dev))
        return self._host[i]

    def read(self, lo: int, hi: int, out: np.ndarray) -> None:
        i = bisect.bisect_right(self.offsets, lo) - 1
        pos = lo
        while pos < hi and i < len(self.spec.leaves):
            ls = self.spec.leaves[i]
            a = max(pos, ls.offset)
            b = min(hi, ls.offset + ls.nbytes)
            if b > a:
                hb = self._leaf_bytes(i)
                base = self._base.get(i, 0)
                out[a - lo:b - lo] = hb[a - ls.offset - base:
                                        b - ls.offset - base]
                if self._budget is not None:
                    got = self._consumed.get(i, 0) + (b - a)
                    self._consumed[i] = got
                    if got >= self._budget.get(i, float("inf")):
                        self._host.pop(i, None)
                        self._base.pop(i, None)
            pos = b
            i += 1
        if pos < hi:                                   # zero-pad past end
            out[pos - lo:hi - lo] = 0

    def cached_leaves(self) -> int:
        return len(self._host)


# --------------------------------------------------------- device encoder
class DeviceEncoder:
    """Device-side bucket encode for one flight: gathers a `BucketTask`'s
    scattered leaf byte-ranges into a contiguous uint32 lane buffer *on
    the accelerator* (uint8 bitcast views of the pinned leaves, sliced and
    concatenated device-side), then runs the fused Pallas kernel
    (`repro.kernels.stage.encode_bucket`) — XOR parity fold for kind-2
    buckets, CRC32 for own-data buckets — and pre-warms the d2h copy.
    The host receives ready-to-publish bytes + digest; no per-leaf host
    gather, no host XOR, no host zlib."""

    def __init__(self, spec: FlatSpec, leaves: List[Any], *,
                 interpret: Optional[bool] = None,
                 crc_impl: str = "pallas"):
        import jax  # noqa: F401  (device path requires jax at runtime)
        import jax.numpy as jnp
        from repro.kernels.stage import (LANE_BYTES, bucket_crc,
                                         encode_bucket, pack_lanes)
        self._jnp = jnp
        self._lane_bytes = LANE_BYTES
        self._encode = encode_bucket
        self._bucket_crc = bucket_crc
        self._pack = pack_lanes
        self.spec = spec
        self.leaves = leaves
        self.offsets = [l.offset for l in spec.leaves]
        self.interpret = interpret
        self.crc_impl = crc_impl
        self._u8cache: Dict[int, Any] = {}

    def _u8(self, i: int):
        got = self._u8cache.get(i)
        if got is None:
            import jax
            jnp = self._jnp
            arr = jnp.asarray(self.leaves[i])
            if arr.dtype == jnp.bool_:
                arr = arr.astype(jnp.uint8)
            if arr.dtype != jnp.uint8:
                arr = jax.lax.bitcast_convert_type(arr, jnp.uint8)
            got = self._u8cache[i] = arr.reshape(-1)
        return got

    def gather_lanes(self, lo: int, hi: int):
        """Bytes [lo, hi) of the flat stream as (n_lanes,) uint32 on
        device, zero-padded past `total_bytes` and up to whole lanes."""
        jnp = self._jnp
        nb = hi - lo
        parts = []
        i = bisect.bisect_right(self.offsets, lo) - 1
        pos = lo
        while pos < hi and i < len(self.spec.leaves):
            ls = self.spec.leaves[i]
            a, b = max(pos, ls.offset), min(hi, ls.offset + ls.nbytes)
            if b > a:
                parts.append(self._u8(i)[a - ls.offset:b - ls.offset])
            pos = b
            i += 1
        pad = (hi - pos) + ((-nb) % self._lane_bytes)
        if pad:
            parts.append(jnp.zeros(pad, jnp.uint8))
        u8 = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        return self._pack(u8)

    def encode(self, task: BucketTask, *, want_crc: Optional[bool] = None,
               prewarm_payload: bool = True):
        """Dispatch the fused encode for `task`; returns (lanes, crc,
        nbytes) device arrays with the d2h copy already warming.  The
        delta path forces `want_crc=True` even for parity buckets (the
        digest of the XOR fold is the skip signal) and defers the
        payload pre-warm until the digest compare rules the bucket
        dirty — a clean bucket then d2h's 4 bytes, not the bucket."""
        jnp = self._jnp
        nb = task.hi - task.lo
        if task.kind == 2:
            rows = jnp.stack([self.gather_lanes(lo, hi)
                              for lo, hi in task.sources])
            if want_crc is None:
                want_crc = False             # parity carries no checksum
        else:
            rows = self.gather_lanes(task.lo, task.hi)[None]
            want_crc = True
        lanes, crc = self._encode(rows, nbytes=nb, want_crc=want_crc,
                                  interpret=self.interpret,
                                  crc_impl=self.crc_impl)
        warm = (lanes, crc) if prewarm_payload else (crc,)
        for a in warm:
            try:
                a.copy_to_host_async()
            except AttributeError:
                pass
        return lanes, crc, nb

    def bucket_crc(self, crc, nbytes: int) -> int:
        """Digest array (single-cell or per-tile, already on host) -> the
        bucket's zlib-compatible CRC32 (crc32_combine fold for tiles)."""
        return self._bucket_crc(crc, nbytes)


# --------------------------------------------------------------- flights
@dataclass(frozen=True)
class PipelineResult:
    """Per-flight outcome with the per-level timing decomposition."""
    step: int
    clean_step: int
    bytes_sent: int
    l1_seconds: float            # device->host reads (+ prefetch issue)
    l1_stall_seconds: float      # waiting for a scratch-buffer credit
    l2_seconds: float            # staging-ring writes incl. slot waits
    l3_seconds: float            # begin/end signaling + SMP clean-ack
    wall_seconds: float
    # ---- dirty-delta bookkeeping (delta-enabled pipelines only)
    skipped_buckets: int = 0     # buckets never sent (provider or digest)
    delta_base: Optional[int] = None    # base step of a delta flight
    digests: Optional[Dict[int, int]] = None   # task idx -> bucket CRC32
    sent_extents: Tuple[Tuple[int, int], ...] = ()   # buffer-local, merged


_STOP = object()


class PipelineFlight:
    """One in-flight snapshot: an L1 pump thread and an L2 stager thread
    joined by credit queues.  `wait` never drops a live flight (a timeout
    raises and the flight stays current), and an aborted flight never
    sends `end`, so a half-written dirty buffer is never published.

    Scratch credits come from the owning pipeline's SHARED pool; `prev`
    chains multi-flight overlap (see module docstring): this flight's
    pump starts after `prev`'s pump finished, its stager `begin`s after
    `prev`'s clean-ack."""

    def __init__(self, smp, spec: FlatSpec, cfg, schedule: List[BucketTask],
                 budget: Dict[int, int], leaves: List[Any], step: int,
                 extra_meta: dict, *, free: "queue.Queue",
                 prev: "Optional[PipelineFlight]" = None,
                 encoder: Optional[DeviceEncoder] = None,
                 affinity: Optional[Tuple[int, ...]] = None,
                 pipeline: "Optional[SnapshotPipeline]" = None,
                 delta: Optional[FlightDelta] = None,
                 want_digests: bool = False):
        self.smp, self.spec, self.cfg = smp, spec, cfg
        self.schedule, self.budget = schedule, budget
        self.leaves, self.step, self.extra_meta = leaves, step, extra_meta
        self.prev = prev
        self.encoder = encoder
        self.affinity = affinity
        self.pipeline = pipeline
        self.delta = delta
        # keyframe flights of a delta-enabled pipeline still digest every
        # bucket: their table is the next delta's compare base
        self.want_digests = want_digests or delta is not None
        self._digests: Dict[int, int] = {}   # full-schedule idx -> CRC32
        self._skipped = 0
        self.result: Optional[PipelineResult] = None
        self.error: Optional[BaseException] = None
        self.done = threading.Event()
        self.pump_done = threading.Event()
        self._abort = threading.Event()
        # set while a caller is blocked in wait(): the trainer cannot tick
        # step boundaries then, so the pump must not wait for them
        self._draining = threading.Event()
        self._free = free                       # SHARED scratch-credit pool
        self._ready: "queue.Queue" = queue.Queue()
        self._l1_read = 0.0
        self._l1_stall = 0.0
        self._t0 = time.perf_counter()
        self._pump_t = threading.Thread(target=self._pump, daemon=True,
                                        name=f"hasc-l1-s{step}")
        self._stage_t = threading.Thread(target=self._stage, daemon=True,
                                         name=f"hasc-l2-s{step}")

    def launch(self) -> "PipelineFlight":
        self._stage_t.start()
        self._pump_t.start()
        return self

    # ------------------------------------------------------------- L1
    def _get_credit(self):
        while True:
            try:
                t0 = time.perf_counter()
                buf = self._free.get(timeout=0.5)
                self._l1_stall += time.perf_counter() - t0
                return buf
            except queue.Empty:
                self._l1_stall += 0.5
                if self._abort.is_set():
                    raise RuntimeError("snapshot pipeline aborted") from None

    def _wait_event(self, ev: threading.Event, what: str) -> None:
        while not ev.wait(0.5):
            if self._abort.is_set():
                raise RuntimeError(
                    f"snapshot pipeline aborted while waiting for {what}")

    def _pump(self):
        try:
            prev = self.prev               # local: the stager clears the
            if prev is not None:           # attr once this flight is done
                # multi-flight: consume shared scratch credits strictly
                # oldest-flight-first (no two pumps compete for the pool,
                # so the older flight can always finish draining)
                self._wait_event(prev.pump_done, "predecessor pump")
            if self.encoder is not None:
                self._pump_device()
            else:
                self._pump_host()
        except BaseException as e:
            if self.error is None:
                self.error = e
            self._abort.set()
        finally:
            self.pump_done.set()
            self._ready.put(_STOP)

    def _work_items(self) -> List[Tuple[int, BucketTask]]:
        """(full-schedule idx, task) pairs the pump must actually read —
        provider-skipped buckets are dropped HERE, before any prefetch
        or `device_get`, and inherit the base flight's digest."""
        delta = self.delta
        if delta is None or not delta.skip:
            return list(enumerate(self.schedule))
        out = []
        for i, task in enumerate(self.schedule):
            if i in delta.skip:
                self._digests[i] = delta.prev.get(i, 0)
                self._skipped += 1
            else:
                out.append((i, task))
        return out

    def _pump_host(self):
        window = max(1, getattr(self.cfg, "prefetch_window", 4))
        yield_every = max(0, getattr(self.cfg, "yield_every_buckets", 4))
        yield_timeout = getattr(self.cfg, "boundary_timeout_s", 0.005)
        work = self._work_items()
        budget, extents = self.budget, None
        if self.delta is not None and len(work) < len(self.schedule):
            # sparse flight: rebuild the read plan from the SURVIVING
            # work items so (a) eviction matches what is actually read
            # and (b) only the touched byte extents of each leaf cross
            # the d2h link — pay for what changed, not for model size
            spans: List[Tuple[int, int]] = []
            for _, t in work:
                if t.kind == 2 and t.sources:
                    spans.extend(t.sources)
                else:
                    spans.append((t.lo, t.hi))
            spans = merge_ranges(spans)
            budget = leaf_budget(self.spec, spans)
            if self.pipeline is not None and self.pipeline.ranged_fetch:
                extents = leaf_extents(self.spec, spans)
        reader = LeafReader(self.spec, self.leaves, budget, extents)
        issued: set = set()
        fold = None               # host XOR scratch for fused kind-2 tasks
        for w, (i, task) in enumerate(work):
            if self._abort.is_set():
                raise RuntimeError("snapshot pipeline aborted")
            t0 = time.perf_counter()
            fresh = []
            for _, nxt in work[w:w + window]:      # windowed prefetch
                spans = [(nxt.leaf_lo, nxt.leaf_hi)]
                if nxt.kind == 2 and nxt.sources:
                    # fused parity reads every stripe source range, not
                    # just the first one the task's leaf span covers —
                    # prefetch them all or each falls back to a
                    # synchronous per-leaf device_get mid-read
                    spans = [_leaf_span(reader.offsets, self.spec, lo, hi)
                             for lo, hi in nxt.sources]
                for l0, l1 in spans:
                    for li in range(l0, l1):
                        if li not in issued:
                            issued.add(li)
                            fresh.append(li)
            if fresh:
                reader.fetch(fresh)     # one batched d2h for the window
            self._l1_read += time.perf_counter() - t0
            if yield_every and w and w % yield_every == 0 \
                    and not self._draining.is_set():
                GATE.wait_boundary(yield_timeout)  # yield to training
            buf = self._get_credit()
            nb = task.hi - task.lo
            t0 = time.perf_counter()
            try:
                if task.kind == 2 and task.sources:
                    # host-side fused parity: fold the n-1 stripe source
                    # ranges so the ring carries ONE pre-encoded block
                    reader.read(task.sources[0][0], task.sources[0][1],
                                buf[:nb])
                    if fold is None:
                        fold = np.empty(self.cfg.bucket_bytes, np.uint8)
                    for lo, hi in task.sources[1:]:
                        reader.read(lo, hi, fold[:nb])
                        np.bitwise_xor(buf[:nb], fold[:nb], out=buf[:nb])
                else:
                    reader.read(task.lo, task.hi, buf[:nb])
            except BaseException:
                self._free.put(buf)                # never leak a credit
                raise
            self._l1_read += time.perf_counter() - t0
            # host digests (and the digest-compare skip) run in the L2
            # stager, not here: L1 is the device-read level and stays
            # read-only — the device path keeps CRC on the accelerator
            # for the same reason
            self._ready.put((task, buf, buf[:nb], nb, None, i))

    def _pump_device(self):
        enc = self.encoder
        window = max(1, getattr(self.cfg, "prefetch_window", 4))
        yield_every = max(0, getattr(self.cfg, "yield_every_buckets", 4))
        yield_timeout = getattr(self.cfg, "boundary_timeout_s", 0.005)
        delta = self.delta
        digesting = self.want_digests
        # digest compare pending: hold the payload d2h until the 4-byte
        # digest ruled the bucket dirty
        defer = delta is not None and delta.digest
        work = self._work_items()
        pending: Dict[int, tuple] = {}
        for w, (i, task) in enumerate(work):
            if self._abort.is_set():
                raise RuntimeError("snapshot pipeline aborted")
            t0 = time.perf_counter()
            for x in range(w, min(w + window, len(work))):
                j, tj = work[x]
                if j not in pending:       # encode a window ahead; the
                    pending[j] = enc.encode(  # kernels + d2h run async
                        tj, want_crc=True if digesting else None,
                        prewarm_payload=not defer)
            self._l1_read += time.perf_counter() - t0   # under this loop
            if yield_every and w and w % yield_every == 0 \
                    and not self._draining.is_set():
                GATE.wait_boundary(yield_timeout)
            lanes, crc, nb = pending.pop(i)
            t0 = time.perf_counter()
            crc_val = enc.bucket_crc(np.asarray(crc), nb) \
                if digesting or task.kind == 0 else None
            if digesting:
                self._digests[i] = crc_val
            if defer and delta.prev.get(i) == crc_val:
                self._skipped += 1         # clean: only the digest d2h'd
                self._l1_read += time.perf_counter() - t0
                continue
            self._l1_read += time.perf_counter() - t0
            buf = self._get_credit()       # token: bounds queued buckets
            t0 = time.perf_counter()
            try:
                if defer:                  # dirty after all: warm it now
                    try:
                        lanes.copy_to_host_async()
                    except AttributeError:
                        pass
                host = np.asarray(lanes)               # d2h (pre-warmed)
                payload = host.view(np.uint8)[:nb]
            except BaseException:
                self._free.put(buf)
                raise
            self._l1_read += time.perf_counter() - t0
            self._ready.put((task, buf, payload, nb,
                             crc_val if task.kind == 0 else None, i))

    # ------------------------------------------------------------- L2
    def _stage(self):
        try:
            applied = pin_current_thread(self.affinity)
            if self.pipeline is not None and applied is not None:
                self.pipeline.applied_affinity = applied
            t_l2 = 0.0
            sent = 0
            crcs: List[Tuple[int, int, int]] = []      # (dst, nbytes, crc)
            extents: List[Tuple[int, int]] = []        # buffer-local, sent
            own_bytes = self.smp.layout.own_bytes
            delta = self.delta
            prev = self.prev
            if prev is not None:
                # the SMP holds at most one dirty buffer: begin only after
                # the predecessor's clean-ack (its stager is done with the
                # pipe, so the conn is ours alone from here)
                self._wait_event(prev.done, "predecessor clean-ack")
            t0 = time.perf_counter()
            if delta is not None:
                # confirmed exchange: the SMP seeds the new shard buffer
                # by copying the base (latest clean) buffer — if the base
                # rotated away the delta would publish garbage, so a miss
                # aborts the flight (nothing published)
                if not self.smp.begin(self.step, base_step=delta.base_step):
                    raise DeltaBaseMismatch(
                        f"delta base step {delta.base_step} is not the "
                        f"SMP's latest clean buffer")
            else:
                self.smp.begin(self.step)
            t_l3 = time.perf_counter() - t0
            host_digesting = self.want_digests and self.encoder is None
            while True:
                item = self._ready.get()
                if item is _STOP:
                    break
                task, buf, payload, nb, crc_val, idx = item
                t0 = time.perf_counter()
                if host_digesting:
                    # host digests (and the bit-identical skip) happen at
                    # this level: the pump hands raw reads over and never
                    # pays the CRC pass on the device-read path
                    crc_val = zlib.crc32(payload) & 0xFFFFFFFF
                    self._digests[idx] = crc_val
                    if delta is not None and delta.digest \
                            and delta.prev.get(idx) == crc_val:
                        self._skipped += 1     # bit-identical: skip send
                        self._free.put(buf)
                        t_l2 += time.perf_counter() - t0
                        continue
                    if task.kind != 0:
                        crc_val = None
                try:
                    self.smp.send_bucket(task.kind, task.dst, payload)
                finally:
                    self._free.put(buf)                # return the credit
                t_l2 += time.perf_counter() - t0
                sent += nb
                if crc_val is not None:
                    crcs.append((task.dst, nb, crc_val))
                if self.want_digests:
                    extents.append(task_local_extent(task, own_bytes))
            if self._abort.is_set():                   # no `end`: dirty
                return                                 # buffer stays unseen
            meta = {"spec": self.spec.to_json(), "step": self.step,
                    "extra": self.extra_meta}
            t0 = time.perf_counter()
            if self.want_digests:
                # delta-enabled pipeline: the full-schedule digest table
                # covers every own-data bucket (fresh for read buckets,
                # inherited for skipped ones), so the own-region CRC and
                # the per-stripe table are derived trainer-side even when
                # only a handful of buckets were re-sent
                crcs = [(t.dst, t.hi - t.lo, self._digests[i])
                        for i, t in enumerate(self.schedule) if t.kind == 0]
            if crcs:
                # device encode path: per-bucket digests -> one combined
                # own-region CRC plus the per-stripe table (one digest per
                # local RAIM5 block; buckets never cross block boundaries,
                # so grouping by dst // bs folds exactly); the SMP skips
                # its zlib pass on both
                crcs.sort()
                crc_own = crc32_concat((c, nb) for _, nb, c in crcs)
                lay = self.smp.layout
                seg = lay.bs if lay.n > 1 else lay.own_bytes
                per_block: Dict[int, List[Tuple[int, int]]] = {}
                for dst, nb, c in crcs:
                    per_block.setdefault(dst // seg, []).append((c, nb))
                stripes = [crc32_concat(per_block[k])
                           for k in sorted(per_block)]
                self.smp.end(self.step, pickle.dumps(meta), crc_own=crc_own,
                             crc_stripes=stripes)
            else:
                self.smp.end(self.step, pickle.dumps(meta), want_crc=True)
            clean = self.smp.wait_clean()
            t_l3 += time.perf_counter() - t0
            self.result = PipelineResult(
                step=self.step, clean_step=clean, bytes_sent=sent,
                l1_seconds=self._l1_read, l1_stall_seconds=self._l1_stall,
                l2_seconds=t_l2, l3_seconds=t_l3,
                wall_seconds=time.perf_counter() - self._t0,
                skipped_buckets=self._skipped,
                delta_base=None if delta is None else delta.base_step,
                digests=dict(self._digests) if self.want_digests else None,
                sent_extents=tuple(merge_ranges(extents))
                if self.want_digests else ())
        except BaseException as e:
            if self.error is None:
                self.error = e
            self._abort.set()
        finally:
            self._drain_ready()            # return credits of unsent items
            self.done.set()
            self.prev = None               # release the predecessor (and
                                           # its pinned leaves) promptly

    def _drain_ready(self) -> None:
        while True:
            try:
                item = self._ready.get_nowait()
            except queue.Empty:
                return
            if item is not _STOP:
                self._free.put(item[1])

    # ----------------------------------------------------------- public
    def in_flight(self) -> bool:
        return not self.done.is_set()

    def wait(self, timeout: float = 300.0) -> PipelineResult:
        """Idempotent: a finished flight re-raises its stored error (or
        returns its result) on every call, so callers can distinguish
        'still live' (the wait-timeout below) from 'failed with an internal
        TimeoutError like an SMP ack timeout' by re-collecting after
        checking `in_flight()`."""
        self._draining.set()
        try:
            if not self.done.wait(timeout):
                raise TimeoutError(
                    f"snapshot pipeline for step {self.step} still in "
                    f"flight after {timeout:.1f}s")
        finally:
            if not self.done.is_set():     # timed out: trainer resumes,
                self._draining.clear()     # boundary yields matter again
        self._pump_t.join(timeout=5.0)
        self._stage_t.join(timeout=5.0)
        self._drain_ready()                # pump items raced past the stager
        if self.error is not None:
            raise self.error
        assert self.result is not None
        return self.result


class SnapshotPipeline:
    """Per-engine HASC driver: owns the (static) bucket schedule, leaf
    budget, the SHARED scratch-credit pool, and the flight chain.
    `start` launches a `PipelineFlight`; with `cfg.max_flights > 1` a new
    flight may launch while predecessors drain (overlap), chained so
    credits drain oldest-first and the SMP sees one dirty buffer."""

    def __init__(self, smp, spec: FlatSpec, cfg,
                 own_plan: Sequence[Tuple[int, int, int]],
                 stripe_plan: Sequence[Tuple[int, int]]):
        self.smp, self.spec, self.cfg = smp, spec, cfg
        self.device_encode = resolve_device_encode(cfg)
        self.ranged_fetch = resolve_ranged_fetch(cfg)
        self.crc_impl = getattr(cfg, "crc_impl", "pallas")
        self.max_flights = max(1, int(getattr(cfg, "max_flights", 1)))
        self.delta_enabled = bool(getattr(cfg, "delta", False))
        # delta mode always fuses parity (host path included): a delta
        # flight refreshes affected parity extents with fully-folded plain
        # writes — XOR-accumulate (kind 1) would need the base parity
        # zeroed first, which the base-copy begin precisely must not do
        self.schedule = build_schedule(
            spec, own_plan, stripe_plan, cfg.bucket_bytes,
            opt_first=getattr(cfg, "opt_first", True),
            fuse_parity=self.device_encode or self.delta_enabled)
        self.budget = leaf_budget(
            spec, [(lo, hi) for _, lo, hi in own_plan] + list(stripe_plan))
        self.scratch_buffers = max(1, getattr(cfg, "scratch_buffers", 2))
        self._free: "queue.Queue" = queue.Queue()
        for _ in range(self.scratch_buffers):
            self._free.put(self._new_credit())
        self.affinity = resolve_affinity(getattr(cfg, "pin_cpus", None))
        self.applied_affinity: Optional[Tuple[int, ...]] = None
        self._last: Optional[PipelineFlight] = None

    def _new_credit(self):
        # host path: a real scratch bucket; device path: the scratch lives
        # on the accelerator, the credit is a pure flow-control token
        return None if self.device_encode \
            else np.empty(self.cfg.bucket_bytes, np.uint8)

    def _replenish(self) -> None:
        """Top the shared pool back up (idle only): a flight that died
        mid-drain may have stranded credits with its corpse."""
        while self._free.qsize() < self.scratch_buffers:
            self._free.put(self._new_credit())

    def live_flights(self) -> int:
        n, f = 0, self._last
        while f is not None and f.in_flight():
            n += 1
            f = f.prev
        return n

    def start(self, leaves: List[Any], step: int, extra_meta: dict,
              delta: Optional[FlightDelta] = None) -> PipelineFlight:
        if self.live_flights() >= self.max_flights:
            # the engine refuses before calling; this is the backstop for
            # direct callers — the flight chain (and the SMP's triple
            # buffer) is sized for max_flights
            raise RuntimeError(
                f"max_flights={self.max_flights} snapshots already in "
                f"flight")
        prev = self._last if (self._last is not None
                              and self._last.in_flight()) else None
        if prev is None:
            self._replenish()
        encoder = DeviceEncoder(self.spec, leaves,
                                crc_impl=self.crc_impl) \
            if self.device_encode else None
        flight = PipelineFlight(
            self.smp, self.spec, self.cfg, self.schedule, self.budget,
            leaves, step, extra_meta, free=self._free, prev=prev,
            encoder=encoder, affinity=self.affinity, pipeline=self,
            delta=delta, want_digests=self.delta_enabled)
        self._last = flight
        return flight.launch()
