"""CRC32 helpers for the device-side snapshot encode path.

The device encode kernel (`repro.kernels.stage`) computes one CRC32 per
bucket on the accelerator (slice-by-4 table lookups over uint32 lanes).
Buckets cover the own region exactly once but arrive in schedule order
(optimizer-moments first), so the host recombines the per-bucket digests
into the contiguous own-region CRC with `crc32_combine` — an O(log len)
GF(2) matrix fold per bucket instead of a full zlib pass over the bytes.
The combined value is byte-for-byte what `zlib.crc32` returns over the
same region, so recovery's `verify_crc` needs no changes.
"""
from __future__ import annotations

import functools
from typing import Iterable, Tuple

import numpy as np

_POLY = 0xEDB88320          # reflected CRC-32 (IEEE 802.3), zlib-compatible


def _make_slice4_tables() -> np.ndarray:
    """(4, 256) uint32 lookup tables.  tables[0] is the classic byte-at-a-
    time table; tables[k][i] advances the remainder k extra zero bytes, so
    one uint32 word is consumed with four lookups (slice-by-4)."""
    t0 = np.zeros(256, np.uint64)
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ _POLY if c & 1 else c >> 1
        t0[i] = c
    tabs = [t0]
    for _ in range(3):
        prev = tabs[-1]
        t = np.zeros(256, np.uint64)
        for i in range(256):
            t[i] = (prev[i] >> 8) ^ t0[prev[i] & 0xFF]
        tabs.append(t)
    return np.stack(tabs).astype(np.uint32)


CRC_TABLES = _make_slice4_tables()


# ------------------------------------------------------------- combining
def _gf2_times(mat, vec: int) -> int:
    s = 0
    i = 0
    while vec:
        if vec & 1:
            s ^= mat[i]
        vec >>= 1
        i += 1
    return s


def _gf2_square(mat):
    return [_gf2_times(mat, mat[i]) for i in range(32)]


@functools.lru_cache(maxsize=256)
def _zero_operator(len2: int) -> tuple:
    """The GF(2) matrix advancing a CRC register past `len2` zero bytes,
    as a tuple of 32 columns.  Cached: the stager recombines one digest
    per bucket and nearly all buckets share a single length, so each
    combine after the first is one 32-step matrix-vector product instead
    of ~45 pure-Python matrix squarings."""
    odd = [0] * 32
    odd[0] = _POLY                       # one zero bit
    for i in range(1, 32):
        odd[i] = 1 << (i - 1)
    even = _gf2_square(odd)              # two zero bits
    odd = _gf2_square(even)              # four zero bits
    op = [1 << i for i in range(32)]     # identity
    while True:
        even = _gf2_square(odd)          # even <- 2x the zero-bits of odd
        if len2 & 1:
            op = [_gf2_times(even, c) for c in op]
        len2 >>= 1
        if not len2:
            break
        odd = _gf2_square(even)
        if len2 & 1:
            op = [_gf2_times(odd, c) for c in op]
        len2 >>= 1
        if not len2:
            break
    return tuple(op)


def crc32_combine(crc1: int, crc2: int, len2: int) -> int:
    """CRC32 of A||B from crc(A), crc(B), len(B) — zlib's crc32_combine
    (not exposed by the `zlib` module).  `crc32_combine(0, crc, n) == crc`,
    so a fold over (crc, len) pairs starts from 0 (the empty-string CRC).

    Inputs are masked to 32 bits: callers hand over digests that may
    ride in wider containers (uint64 device lanes, Python ints from
    signed struct unpacks) — an unmasked bit >= 32 used to index past
    the 32-column GF(2) matrix and raise, and a zero-length B with such
    a crc1 slipped through unmasked entirely."""
    crc1 = int(crc1) & 0xFFFFFFFF
    if int(len2) <= 0:                 # empty B: crc(A||B) == crc(A);
        return crc1                    # numpy scalar lens coerce too
    return _gf2_times(_zero_operator(int(len2)), crc1) \
        ^ (int(crc2) & 0xFFFFFFFF)


def crc32_concat(parts: Iterable[Tuple[int, int]]) -> int:
    """Fold (crc, nbytes) digests of consecutive chunks into one CRC32.
    Zero-length chunks (empty tail parts, padding-only segments) fold to
    identity; single-byte tails exercise `_zero_operator(1)`."""
    crc = 0
    for part_crc, nbytes in parts:
        crc = crc32_combine(crc, part_crc, nbytes)
    return crc
