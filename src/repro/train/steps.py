"""Train / prefill / decode step factories.

The train state is the exact pytree REFT snapshots: params + optimizer
moments + step + data-RNG key (the paper's "model parameters, optimizer
states, and RNG states").
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim.adam import AdamConfig, adam_init, adam_update


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: Any
    rng: Any

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state,
                "step": self.step, "rng": self.rng}

    @classmethod
    def from_tree(cls, t):
        return cls(params=t["params"], opt_state=t["opt_state"],
                   step=t["step"], rng=t["rng"])


def init_train_state(cfg: ModelConfig, seed: int = 0) -> TrainState:
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    return TrainState(params=params, opt_state=adam_init(params),
                      step=jnp.zeros((), jnp.int32),
                      rng=jax.random.PRNGKey(seed + 1))


def make_train_step(cfg: ModelConfig, opt: AdamConfig | None = None,
                    unroll: bool = False, microbatches: int = 1):
    """Train-step factory.

    microbatches > 1 splits the global batch on axis 0 and accumulates
    gradients over a lax.scan — the standard memory/throughput knob when
    the per-step activation footprint exceeds HBM (grads are averaged, so
    the update is identical to the full-batch step for equal-size chunks).
    """
    # per-call default: a signature-level AdamConfig() would be one shared
    # instance across every factory call (the PR 1 aliased-config bug)
    opt = opt if opt is not None else AdamConfig()

    def loss_fn(p, batch):
        loss, _ = M.forward(cfg, p, batch, unroll=unroll)
        return loss

    def full_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def accum_grads(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, b_i):
            loss_acc, g_acc = carry
            loss_i, g_i = jax.value_and_grad(loss_fn)(params, b_i)
            g_acc = jax.tree.map(jnp.add, g_acc, g_i)
            return (loss_acc + loss_i, g_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: dict, batch: dict) -> tuple:
        loss, grads = (full_grads if microbatches == 1 else accum_grads)(
            state["params"], batch)
        new_params, new_opt, gnorm = adam_update(
            opt, grads, state["opt_state"], state["params"])
        new_state = {
            "params": new_params,
            "opt_state": new_opt,
            "step": state["step"] + 1,
            "rng": jax.random.fold_in(state["rng"], state["step"]),
        }
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def with_step_boundary(step_fn: Callable,
                       notify: Callable[[], None] = None) -> Callable:
    """Yield hook for the HASC saving pipeline: wrap an (already-jitted)
    step function so every invocation ticks the snapshot pipeline's
    step-boundary gate — in-flight L1 device pumps then schedule their
    bucket bursts at step boundaries instead of racing the step for host
    bandwidth.  Wrap OUTSIDE `jax.jit` (the tick is a Python-side effect;
    under a trace it would fire once at trace time and never again):

        step_fn = with_step_boundary(jax.jit(make_train_step(cfg)))
    """
    if notify is None:
        from repro.core.pipeline import step_boundary as notify

    @functools.wraps(step_fn)
    def stepped(*args, **kw):
        out = step_fn(*args, **kw)
        notify()
        return out
    return stepped


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, _ = M.forward(cfg, params, batch, remat=False)
        return loss
    return eval_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False):
    def prefill_step(params, batch):
        logits, caches = M.logits_fn(cfg, params, batch, unroll=unroll)
        return logits, caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, unroll: bool = False):
    def serve_step(params, cache, tokens):
        logits, new_cache = M.decode_step(cfg, params, cache, tokens,
                                          unroll=unroll)
        return logits, new_cache
    return serve_step
