"""Goodput ledger: attribute every wall-clock second of a supervised run.

The resiliency literature (and the nemo-gke resiliency recipes) measure
fault-tolerance quality as *goodput*: the fraction of wall clock spent on
forward progress.  Everything else is badput with a cause:

  compute           productive train steps that survived to the end
  lost_steps        steps that ran but were rolled back by a restore
  checkpoint_stall  trainer blocked on snapshot/persist machinery
  detect            failure happened -> supervisor noticed
  restore           recovery ladder + heal + verify
  overhead          supervisor bookkeeping / scenario injection

Attribution is *sequential*: `mark(category)` charges all time since the
previous mark to `category`.  Because every second lands in exactly one
bucket, the per-category sums reconstruct wall clock exactly — which is
what makes the BENCH_goodput.json 5%-sum acceptance check meaningful
rather than vacuous.
"""
from __future__ import annotations

import json
import time

CATEGORIES = ("compute", "lost_steps", "checkpoint_stall",
              "detect", "restore", "overhead")


class GoodputLedger:
    """Sequential wall-clock attribution with an injectable clock."""

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.seconds = {c: 0.0 for c in CATEGORIES}
        self.events: list[dict] = []
        self._t0 = clock()
        self._last = self._t0
        self._closed_at = None

    def mark(self, category: str) -> float:
        """Charge the interval since the previous mark to `category`.
        Returns the interval length."""
        if category not in self.seconds:
            raise ValueError(f"unknown goodput category {category!r}; "
                             f"want one of {CATEGORIES}")
        now = self.clock()
        dt = now - self._last
        self.seconds[category] += dt
        self._last = now
        return dt

    def transfer(self, frm: str, to: str, seconds: float) -> None:
        """Re-attribute already-charged seconds (e.g. compute that a
        rollback turned into lost_steps).  Conserves the total, so the
        sum-to-wall-clock invariant is untouched."""
        seconds = min(max(seconds, 0.0), self.seconds[frm])
        self.seconds[frm] -= seconds
        self.seconds[to] += seconds

    def record_event(self, **kw) -> None:
        """Append one structured failure/recovery event to the trajectory."""
        kw.setdefault("t", self.clock() - self._t0)
        self.events.append(kw)

    def close(self, category: str = "overhead") -> None:
        """Flush the tail interval so wall == sum(categories)."""
        self.mark(category)
        self._closed_at = self._last     # the mark's own clock reading:
        # a second clock() call here would open a sliver of unaccounted
        # wall between the final mark and the close stamp

    @property
    def wall(self) -> float:
        end = self._closed_at if self._closed_at is not None else self.clock()
        return end - self._t0

    @property
    def accounted(self) -> float:
        return sum(self.seconds.values())

    @property
    def goodput_frac(self) -> float:
        return self.seconds["compute"] / max(self.wall, 1e-9)

    def check(self, tol: float = 0.05) -> bool:
        """Per-category seconds must sum to wall clock within `tol`."""
        wall = self.wall
        return abs(self.accounted - wall) <= tol * max(wall, 1e-9)

    def summary(self) -> dict:
        wall = self.wall
        return {
            "wall_seconds": wall,
            "goodput_frac": self.goodput_frac,
            "seconds": dict(self.seconds),
            "fractions": {c: s / max(wall, 1e-9)
                          for c, s in self.seconds.items()},
            "accounted_seconds": self.accounted,
            "accounting_error": abs(self.accounted - wall) / max(wall, 1e-9),
            "events": list(self.events),
        }

    def dump(self, path: str, extra: dict = None) -> dict:
        payload = self.summary()
        if extra:
            payload.update(extra)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        return payload
