"""Supervised training run: real model, injected failures, goodput report.

The supervisor-shaped sibling of `repro.launch.train`: same model/data/
step wiring, but the loop belongs to `repro.supervise.Supervisor` — it
fires a seeded scenario schedule (or explicit `--inject` specs), detects
and heals every fault (elastically resharding on `--elastic-to`), checks
each restore byte-exact against the oracle ring, and emits the
`BENCH_goodput.json` trajectory the CI goodput smoke gates on.

  PYTHONPATH=src python -m repro.supervise.run --arch opt-125m --reduced \\
      --steps 24 --sg-size 4 --scenarios 5 --seed 0 --elastic-to 2 \\
      --json BENCH_goodput.json --min-goodput 0.2

Exits non-zero on any unrecovered failure, any non-byte-exact restore,
a goodput fraction under `--min-goodput`, or ledger accounting that does
not sum to wall clock within 5%.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.supervise.inject import (
    KINDS, Scenario, ensure_coverage, parse_scenario, plan_scenarios,
)

#: kinds a default CI smoke must cover (>=4 distinct, incl. a preempt)
SMOKE_KINDS = ("smp", "corrupt-stripe", "node", "preempt", "slow-persist")


def build_scenarios(args, sg: int) -> list:
    if args.inject:
        out = [parse_scenario(item) for item in args.inject]
    else:
        kinds = tuple(args.kinds.split(",")) if args.kinds else SMOKE_KINDS
        for k in kinds:
            if k not in KINDS:
                raise SystemExit(f"unknown kind {k!r}; want one of {KINDS}")
        out = plan_scenarios(args.seed, n=sg, total_steps=args.steps,
                             count=args.scenarios, kinds=kinds)
        out = ensure_coverage(out, kinds=kinds[:min(len(kinds), 4)], n=sg)
    if out and all(s.graceful for s in out):
        # the acceptance bar wants >=1 genuinely mid-flight injection
        out[0] = dataclasses.replace(out[0], graceful=False)
    if args.elastic_to:
        # the last scenario becomes the elastic reshard trigger
        last = out[-1]
        out[-1] = Scenario(kind="preempt", step=last.step, node=last.node,
                           graceful=last.graceful,
                           params={"new_sg": args.elastic_to})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--backend", default="reft",
                    choices=["reft", "objstore"])
    ap.add_argument("--sg-size", type=int, default=4)
    ap.add_argument("--snapshot-every", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/reft-supervised-ckpt")
    ap.add_argument("--auto-tune", action="store_true",
                    help="MTBF-fed Appendix-A cadence retuning")
    ap.add_argument("--scenarios", type=int, default=5,
                    help="number of seeded scenarios to plan")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kinds", default="",
                    help="comma-separated kind pool for the planner")
    ap.add_argument("--inject", action="append", default=[],
                    help="explicit STEP:KIND[:NODE] (overrides the "
                         "planner; repeatable)")
    ap.add_argument("--elastic-to", type=int, default=0,
                    help="reshard to this sg_size at the final scenario "
                         "(turns it into a preempt -> elastic rebuild)")
    ap.add_argument("--json", default="",
                    help="write the goodput trajectory here")
    ap.add_argument("--min-goodput", type=float, default=0.0,
                    help="fail the run under this goodput fraction")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.api import CheckpointSpec
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import SyntheticDataset
    from repro.supervise.supervisor import Supervisor
    from repro.train.steps import init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = InputShape("cli", args.seq, args.batch, "train")
    state = init_train_state(cfg, 0).tree()
    ds = SyntheticDataset(cfg, shape, seed=0)
    step_fn = jax.jit(make_train_step(cfg))

    def advance(st, step):
        st = jax.tree.map(jnp.asarray, st)     # restored trees are numpy
        st, _metrics = step_fn(st, next(ds))
        return st

    scenarios = build_scenarios(args, args.sg_size)
    print(f"[supervise] arch={cfg.name} params={cfg.param_count():,} "
          f"sg={args.sg_size} steps={args.steps} "
          f"scenarios={[(s.step, s.kind) for s in scenarios]}")

    spec = CheckpointSpec(
        backend=args.backend, ckpt_dir=args.ckpt_dir,
        sg_size=args.sg_size,
        snapshot_every_steps=args.snapshot_every,
        checkpoint_every_steps=args.ckpt_every,
        resume=False, auto_tune=args.auto_tune,
    )
    sup = Supervisor(spec, state, advance, scenarios=scenarios,
                     log=lambda s: print(s, flush=True))
    out = sup.run(args.steps)
    out.pop("final_state")

    g = out["goodput"]
    print(f"[supervise] failures={out['failures']} "
          f"kinds={out['kinds']} unrecovered={out['unrecovered']} "
          f"goodput={g['goodput_frac']:.3f} "
          f"acct_err={g['accounting_error']:.4f} "
          f"mtbf={out['mtbf_s']:.2f}s "
          f"lam_post={out['lam_node_posterior']:.2e}")
    for c, s in sorted(g["seconds"].items()):
        print(f"  {c:<17s} {s:8.3f}s  ({g['fractions'][c] * 100:5.1f}%)")

    if args.json:
        payload = dict(out)
        payload["config"] = {
            "arch": cfg.name, "sg_size": args.sg_size,
            "steps": args.steps, "seed": args.seed,
            "backend": args.backend,
            "scenarios": [dataclasses.asdict(s) for s in scenarios],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        print(f"[supervise] wrote {args.json}")

    ok = True
    if out["unrecovered"]:
        print(f"FAIL: {out['unrecovered']} unrecovered failures")
        ok = False
    bad_exact = [b for b in out["bit_exact_checks"] if b is False]
    if bad_exact:
        print(f"FAIL: {len(bad_exact)} restores were not byte-exact")
        ok = False
    if not (abs(g["accounting_error"]) <= 0.05):
        print(f"FAIL: ledger accounting error {g['accounting_error']:.4f} "
              f"> 5%")
        ok = False
    if g["goodput_frac"] < args.min_goodput:
        print(f"FAIL: goodput {g['goodput_frac']:.3f} < "
              f"{args.min_goodput}")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
