"""Goodput-driven elastic supervision: fault injection, auto-heal/reshard,
and wall-clock accounting (docs/API.md "Supervisor & goodput accounting")."""
from repro.supervise.goodput import CATEGORIES, GoodputLedger
from repro.supervise.inject import (
    DEFAULT_PARAMS, FAILURE_KINDS, KINDS, Scenario, corrupt_reft_file,
    corrupt_shm_stripe, ensure_coverage, parse_scenario, plan_scenarios,
)
from repro.supervise.supervisor import Supervisor, trees_equal

__all__ = [
    "CATEGORIES", "GoodputLedger", "DEFAULT_PARAMS", "FAILURE_KINDS",
    "KINDS", "Scenario", "corrupt_reft_file", "corrupt_shm_stripe",
    "ensure_coverage", "parse_scenario", "plan_scenarios", "Supervisor",
    "trees_equal",
]
