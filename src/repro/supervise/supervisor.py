"""Supervisor loop: failures are routine, training is forever.

`Supervisor` owns the training loop a driver would otherwise run inline:
it advances steps through a caller-supplied `advance` function, drives a
`CheckpointSession`'s cadence, fires planned fault `Scenario`s (mid-flight
when non-graceful), *detects* each fault via `health()` / preempt ticks /
a CRC integrity probe, and recovers — heal-in-place through the recovery
ladder with bounded-backoff retries, or an elastic n→m session rebuild
when a preemption shrinks the group.  Every restore is checked byte-exact
against an oracle ring of states remembered at snapshot steps, every
wall-clock second lands in exactly one `GoodputLedger` bucket, and
observed failures/restore costs feed the session's MTBF-driven cadence
tuner through a shared `FailureObserver`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import numpy as np

from repro.api import CheckpointSession, CheckpointSpec
from repro.core.policy import FailureObserver
from repro.core.recovery import (
    RecoveryError, attach_survivors, verify_crc,
)
from repro.supervise.goodput import GoodputLedger
from repro.supervise.inject import FAILURE_KINDS, Scenario

#: kinds detectable by polling health() until the member reads bad
_HEALTH_KINDS = frozenset({"software", "node", "smp"})


def _copy_tree(tree):
    import jax
    return jax.tree.map(lambda x: np.array(x, copy=True), tree)


def trees_equal(a, b) -> bool:
    import jax
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


class Supervisor:
    """Run `advance` for `total_steps` under fault injection + auto-heal.

    advance(state, step) -> state   one training step (deterministic for
                                    byte-exact verification to mean much)

    Scenario dispatch:
      software / node / smp   inject -> poll health -> ladder restore,
                              retried with exponential backoff
      corrupt-stripe          inject -> drain + CRC-probe every CLEAN
                              buffer -> evict the corrupt member ->
                              RAIM5 restore decodes it from parity
      preempt                 inject -> use the grace window to drain +
                              persist a durable family -> wait out the
                              reclaim -> heal-in-place, or (with a
                              `new_sg` param) elastic n→m rebuild: a
                              fresh session restores the family
                              resharded onto m members
      laggard / slow-persist  perf faults: recorded, survived, and (for
                              slow-persist) latency reset after
                              `duration_steps`; nothing to restore

    The `observer` (shared across elastic rebuilds) carries measured
    failure arrivals and restore costs into `CheckpointSession._retune`.
    """

    def __init__(self, spec: CheckpointSpec, template: Any,
                 advance: Callable[[Any, int], Any], *,
                 scenarios: Optional[List[Scenario]] = None,
                 retries: int = 3, backoff_s: float = 0.1,
                 detect_timeout_s: float = 10.0,
                 oracle_keep: int = 16,
                 observer: Optional[FailureObserver] = None,
                 ledger: Optional[GoodputLedger] = None,
                 on_event: Optional[Callable] = None,
                 log: Callable[[str], None] = lambda s: None):
        self.spec = spec
        self.template = template
        self.advance = advance
        self.scenarios = sorted(scenarios or [], key=lambda s: s.step)
        self.retries = max(1, retries)
        self.backoff_s = backoff_s
        self.detect_timeout_s = detect_timeout_s
        self.oracle_keep = oracle_keep
        self.observer = observer or FailureObserver()
        self.ledger = ledger or GoodputLedger()
        self.on_event = on_event
        self.log = log
        self.sess: Optional[CheckpointSession] = None
        self.events: List[dict] = []
        self.unrecovered = 0
        self._oracle: dict = {}           # step -> state copy (bounded ring)
        self._step_cost: dict = {}        # step -> compute seconds
        self._slow_resets: List[tuple] = []   # (due_step, node, old_delay)

    # ------------------------------------------------------------ oracle
    def _remember(self, state, step: int):
        self._oracle[step] = _copy_tree(state)
        for s in sorted(self._oracle)[:-self.oracle_keep]:
            del self._oracle[s]

    def _bit_exact(self, res) -> Optional[bool]:
        ref = self._oracle.get(res.step)
        if ref is None:
            return None                   # restored past the oracle ring
        return trees_equal(res.state, ref)

    # ------------------------------------------------------------ events
    def _record(self, **kw):
        self.events.append(kw)
        self.ledger.record_event(**kw)
        if self.on_event:
            self.on_event(kw)

    # ----------------------------------------------------------- healing
    def _restore_with_backoff(self) -> tuple:
        """(RestoreResult, attempts) — bounded-backoff retry around the
        ladder; raises the last error when the budget is exhausted."""
        last = None
        for attempt in range(self.retries):
            try:
                return self.sess.restore(), attempt + 1
            except (RecoveryError, OSError, RuntimeError) as e:
                last = e
                self.log(f"[supervisor] restore attempt {attempt + 1}/"
                         f"{self.retries} failed: {e}")
                time.sleep(self.backoff_s * (2 ** attempt))
                # a durable round may have landed since the failure but
                # its manifest only commits on a poll — without this the
                # checkpoint tier can stay invisible across every retry
                try:
                    self.sess.checkpointer.poll_persists()
                except Exception:
                    pass
        raise last

    def _probe_corruption(self) -> List[int]:
        """Drain in-flight saves, then CRC-verify EVERY clean buffer of
        every attachable member (corruption may sit on a non-latest
        buffer of the 3-slot rotation).  Returns the corrupt members."""
        self.sess.wait()
        g = self.sess.checkpointer.group
        from repro.core.coordinator import NodeState
        nodes = [i for i in range(g.n)
                 if g.states[i] != NodeState.OFFLINE]
        views = attach_survivors(g.run, nodes, g.n, g.total_bytes)
        bad = []
        try:
            for node, v in views.items():
                for s in v.clean_steps():
                    if not verify_crc(v, s, g.n, g.total_bytes):
                        bad.append(node)
                        break
        finally:
            for v in views.values():
                v.close()
        return bad

    def _wait_unhealthy(self, node: int) -> float:
        """Poll health() until `node` reads bad; returns detection lag."""
        t0 = time.monotonic()
        deadline = t0 + self.detect_timeout_s
        while time.monotonic() < deadline:
            h = self.sess.health()
            if node in h["degraded"] or node in h.get("preempted", []):
                return time.monotonic() - t0
            # health() is a pull API over the sim cluster; detection-
            # lag measurement needs a fine poll  # analyze: ok ANZ007
            time.sleep(0.01)
        raise RuntimeError(f"node {node} never detected unhealthy "
                           f"within {self.detect_timeout_s}s")

    def _rollback(self, res, cur_step: int) -> None:
        """Re-attribute compute seconds of steps the restore rolled back."""
        lost = sum(dt for s, dt in self._step_cost.items()
                   if res.step < s <= cur_step)
        if lost:
            self.ledger.transfer("compute", "lost_steps", lost)
        for s in list(self._step_cost):
            if s > res.step:
                del self._step_cost[s]

    # ------------------------------------------------- per-kind recovery
    def _heal_in_place(self, sc: Scenario, cur_step: int) -> tuple:
        """(new_state, new_step) after a ladder restore + heal, verified
        byte-exact against the oracle ring."""
        detect_s = (self._wait_unhealthy(sc.node)
                    if sc.kind in _HEALTH_KINDS or sc.kind == "preempt"
                    else 0.0)
        evicted = []
        if sc.kind == "corrupt-stripe":
            t0 = time.monotonic()
            evicted = self._probe_corruption()
            detect_s = time.monotonic() - t0
            for node in evicted:
                self.sess.checkpointer.evict(node)
        self.ledger.mark("detect")
        t0 = time.monotonic()
        try:
            res, attempts = self._restore_with_backoff()
        except Exception as e:
            self.ledger.mark("restore")
            self.unrecovered += 1
            self._record(kind=sc.kind, node=sc.node, fired_step=sc.step,
                         graceful=sc.graceful, recovered=False,
                         error=f"{type(e).__name__}: {e}")
            import traceback
            self.log(f"[supervisor] UNRECOVERED {sc.kind}@node{sc.node}: "
                     f"{traceback.format_exc()}")
            return None, cur_step
        restore_s = time.monotonic() - t0
        exact = self._bit_exact(res)
        self._rollback(res, cur_step)
        self.ledger.mark("restore")
        self._record(kind=sc.kind, node=sc.node, fired_step=sc.step,
                     graceful=sc.graceful, recovered=True,
                     detect_s=detect_s, restore_s=restore_s,
                     tier=res.tier, restored_step=res.step,
                     rolled_back=cur_step - res.step, attempts=attempts,
                     bit_exact=exact, evicted=evicted or None)
        self.log(f"[supervisor] healed {sc.kind}@node{sc.node}: "
                 f"tier={res.tier} step={res.step} "
                 f"bit_exact={exact} detect={detect_s:.3f}s "
                 f"restore={restore_s:.3f}s")
        return res.state, res.step

    def _preempt(self, sc: Scenario, state, cur_step: int) -> tuple:
        """Spot reclaim: persist inside the grace window, then heal in
        place or rebuild the session elastically onto `new_sg` members."""
        params = sc.merged_params()
        new_sg = params.get("new_sg")
        # use the grace window: a durable family survives the reclaim
        # even if the in-memory tier does not
        self.sess.drain()
        try:
            self.sess.persist()
        except Exception as e:            # grace persist is best-effort
            self.log(f"[supervisor] grace-window persist failed: {e}")
        self.ledger.mark("checkpoint_stall")
        detect_s = self._wait_unhealthy(sc.node)   # grace expiry tick
        self.ledger.mark("detect")
        if not new_sg or new_sg == self.spec.sg_size:
            # replacement hardware shows up: ladder restore + heal
            t0 = time.monotonic()
            res, attempts = self._restore_with_backoff()
            restore_s = time.monotonic() - t0
            exact = self._bit_exact(res)
            self._rollback(res, cur_step)
            self.ledger.mark("restore")
            self._record(kind="preempt", node=sc.node, fired_step=sc.step,
                         graceful=sc.graceful, recovered=True,
                         detect_s=detect_s, restore_s=restore_s,
                         tier=res.tier, restored_step=res.step,
                         rolled_back=cur_step - res.step,
                         attempts=attempts, bit_exact=exact)
            self.log(f"[supervisor] healed preempt@node{sc.node}: "
                     f"tier={res.tier} step={res.step} bit_exact={exact}")
            return res.state, res.step
        # elastic n->m: tear down, rebuild smaller, restore resharded
        t0 = time.monotonic()
        old_sg = self.spec.sg_size
        self.sess.close(final_persist=False)
        self.spec = dataclasses.replace(self.spec, sg_size=int(new_sg),
                                        resume=True, run_id=None)
        self.sess = CheckpointSession(self.spec, self.template,
                                      observer=self.observer)
        self.sess.__enter__()
        res = self.sess.restored
        if res is None:
            self.ledger.mark("restore")
            self.unrecovered += 1
            self._record(kind="preempt", node=sc.node, fired_step=sc.step,
                         graceful=sc.graceful, recovered=False,
                         error="elastic rebuild found nothing to restore")
            return None, cur_step
        restore_s = time.monotonic() - t0
        exact = self._bit_exact(res)
        self._rollback(res, cur_step)
        self.ledger.mark("restore")
        self._record(kind="preempt", node=sc.node, fired_step=sc.step,
                     graceful=sc.graceful, recovered=True,
                     detect_s=detect_s, restore_s=restore_s,
                     tier=res.tier, restored_step=res.step,
                     rolled_back=cur_step - res.step,
                     elastic=f"{old_sg}->{new_sg}", bit_exact=exact)
        self.log(f"[supervisor] elastic reshard {old_sg}->{new_sg}: "
                 f"tier={res.tier} step={res.step} bit_exact={exact}")
        return res.state, res.step

    def _perf_fault(self, sc: Scenario, cur_step: int):
        """laggard / slow-persist: inject, remember the remediation.

        A laggard additionally runs a VERIFICATION restore through the
        straggler-aware read scheduler while the member is stopped: no
        state is adopted (the trainer never lost anything), but the
        restore must come back bit-exact and its wall clock / tier land
        in the fault event — this is exactly the window where adaptive
        scheduling (work stealing, parity reroute) earns its keep, and
        the restore's LoadStats feed the observer's bandwidth priors.
        Disable with scenario param verify_restore=False.
        """
        params = sc.merged_params()
        verify_restore = bool(params.pop("verify_restore", True))
        if sc.kind == "slow-persist":
            node = sc.node % self.spec.sg_size
            e = self.sess.checkpointer.group.engines[node]
            old = e.persist_delay_s
            due = cur_step + int(params.pop("duration_steps", 3))
            self._slow_resets.append((due, node, old))
        self.sess.inject(sc.kind, node=sc.node % self.spec.sg_size,
                         graceful=sc.graceful, **params)
        extra = {}
        if sc.kind == "laggard" and verify_restore:
            t0 = time.monotonic()
            try:
                res, attempts = self._restore_with_backoff()
            except Exception as e:
                self.log(f"[supervisor] laggard verification restore "
                         f"failed: {e}")
                self.unrecovered += 1
                extra = {"restore_s": time.monotonic() - t0,
                         "recovered": False}
            else:
                ld = res.load
                extra = {"restore_s": time.monotonic() - t0,
                         "tier": res.tier, "attempts": attempts,
                         "bit_exact": self._bit_exact(res),
                         "sched": getattr(ld, "sched", "") if ld else "",
                         "stolen_chunks": getattr(ld, "stolen_chunks", 0)
                         if ld else 0}
            self.ledger.mark("restore")
        self._record(kind=sc.kind, node=sc.node, fired_step=sc.step,
                     graceful=sc.graceful, perf_only=True,
                     **{"recovered": True, **extra},
                     **{k: v for k, v in params.items()
                        if isinstance(v, (int, float))})

    def _tick_slow_resets(self, cur_step: int):
        """Supervisor-side remediation of slow-persist: latency injected
        for a bounded window, then restored to the configured value."""
        for due, node, old in list(self._slow_resets):
            if cur_step >= due:
                try:
                    g = self.sess.checkpointer.group
                    g.engines[node].persist_delay_s = old
                except Exception:
                    pass
                self._slow_resets.remove((due, node, old))

    # -------------------------------------------------------------- run
    def run(self, total_steps: int, state: Optional[Any] = None) -> dict:
        pending = list(self.scenarios)
        state = state if state is not None else _copy_tree(self.template)
        self.sess = CheckpointSession(self.spec, self.template,
                                      observer=self.observer)
        self.sess.__enter__()
        if self.sess.restored is not None:
            state = self.sess.restored.state
        step = 0
        self.ledger.mark("overhead")
        try:
            while step < total_steps:
                state = self.advance(state, step + 1)
                step += 1
                self._step_cost[step] = self.ledger.mark("compute")
                self.sess.after_step(state, step)
                self.ledger.mark("checkpoint_stall")
                self._remember(state, step)
                self._tick_slow_resets(step)
                self.ledger.mark("overhead")

                while pending and pending[0].step <= step:
                    sc = pending.pop(0)
                    node = sc.node % self.spec.sg_size
                    sc = dataclasses.replace(sc, node=node)
                    self.log(f"[supervisor] inject {sc.kind}@node{node} "
                             f"step={step}"
                             + ("" if sc.graceful else " (mid-flight)"))
                    if sc.kind in ("laggard", "slow-persist"):
                        self._perf_fault(sc, step)
                        self.ledger.mark("overhead")
                        continue
                    params = sc.merged_params()
                    params.pop("new_sg", None)
                    self.sess.inject(sc.kind, node=node,
                                     graceful=sc.graceful, **params)
                    self.ledger.mark("overhead")
                    if sc.kind == "preempt":
                        new_state, step = self._preempt(sc, state, step)
                    else:
                        new_state, step = self._heal_in_place(sc, step)
                    if new_state is not None:
                        state = new_state
            self.sess.drain()
            self.ledger.mark("checkpoint_stall")
        finally:
            try:
                self.sess.close()
            finally:
                self.ledger.close()
        failures = [e for e in self.events
                    if e["kind"] in FAILURE_KINDS]
        return {
            "steps": total_steps,
            "final_state": state,
            "events": list(self.events),
            "injected": len(self.events),
            "failures": len(failures),
            "kinds": sorted({e["kind"] for e in self.events}),
            "unrecovered": self.unrecovered,
            "bit_exact_checks": [e.get("bit_exact") for e in failures],
            "mtbf_s": self.observer.mtbf(),
            "lam_node_posterior": self.observer.lam_node(
                prior=self.spec.lam_node, n=self.spec.sg_size),
            "goodput": self.ledger.summary(),
        }
