"""Deterministic fault-scenario engine for supervised training runs.

The paper treats failures as routine; this module makes them *injectable*
on demand, mid-flight, and reproducibly.  A `Scenario` names one fault
from the ROADMAP taxonomy:

  software        trainer-process crash (engine marked UNHEALTHY)
  node            whole-node loss (SMP killed + shm segments unlinked)
  smp             dead Snapshot Management Process only (segments survive)
  laggard         member stalls (SIGSTOP, auto-SIGCONT after lag_s)
  corrupt-stripe  bytes flipped inside a live shm snapshot buffer
  slow-persist    latency injected on the durable-tier write path
  preempt         spot reclaim: SIGTERM-style notice, grace_s to drain,
                  then the node is gone

`plan_scenarios(seed, ...)` derives a schedule from a single RNG seed so
every sweep episode, CI smoke, and bug report replays byte-identically.
Corruption helpers write real damage — XORing bytes in an attached shm
segment or a `.reft` file past its pickled head — so detection has to be
earned by the CRC machinery, not simulated.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field, replace

import numpy as np

KINDS = ("software", "node", "smp", "laggard", "corrupt-stripe",
         "slow-persist", "preempt")

#: kinds that destroy state and force a restore (vs perf-only faults)
FAILURE_KINDS = frozenset({"software", "node", "smp", "preempt",
                           "corrupt-stripe"})

#: sane small-scale defaults for parameterized kinds (seconds / bytes)
DEFAULT_PARAMS = {
    "laggard": {"lag_s": 0.4},
    "slow-persist": {"delay_s": 0.25},
    "preempt": {"grace_s": 0.3},
    "corrupt-stripe": {"nbytes": 16},
}


@dataclass(frozen=True)
class Scenario:
    """One planned fault: fire `kind` on `node` at training step `step`.
    `graceful=False` means inject mid-flight — no draining of in-flight
    saves first."""
    kind: str
    step: int
    node: int = 0
    graceful: bool = False
    params: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown scenario kind {self.kind!r}; "
                             f"want one of {KINDS}")

    def merged_params(self) -> dict:
        out = dict(DEFAULT_PARAMS.get(self.kind, {}))
        out.update(self.params)
        return out


def parse_scenario(text: str, *, default_node: int = 0) -> Scenario:
    """Parse 'STEP:KIND[:NODE]' (the --inject CLI grammar)."""
    parts = text.split(":")
    if len(parts) not in (2, 3):
        raise ValueError(f"--inject wants STEP:KIND[:NODE] "
                         f"(kind: {'|'.join(KINDS)}), got {text!r}")
    try:
        step = int(parts[0])
    except ValueError:
        raise ValueError(f"--inject STEP must be an int, got {parts[0]!r}")
    kind = parts[1]
    if kind not in KINDS:
        raise ValueError(f"--inject kind must be one of "
                         f"{'|'.join(KINDS)}, got {kind!r}")
    node = int(parts[2]) if len(parts) == 3 else default_node
    return Scenario(kind=kind, step=step, node=node)


def plan_scenarios(seed: int, *, n: int, total_steps: int, count: int,
                   kinds=KINDS, first_step: int = 3,
                   min_gap: int = 2) -> list:
    """Derive a deterministic schedule of `count` scenarios from `seed`.

    Steps are spread over [first_step, total_steps) with at least
    `min_gap` steps between consecutive faults so each one can be healed
    before the next lands; kinds cycle through a seed-shuffled order so
    a small `count` still covers distinct kinds; every non-parametric
    fault targets a seed-chosen node.  Same seed -> same plan, always.
    """
    kinds = tuple(kinds)
    if not kinds:
        raise ValueError("kinds must be non-empty")
    rng = np.random.default_rng(seed)
    span = max(total_steps - first_step, count * min_gap)
    # spread: one fault per equal slice of the run, jittered inside it
    slice_w = span / count
    steps, prev = [], first_step - min_gap
    for i in range(count):
        lo = first_step + int(i * slice_w)
        hi = max(first_step + int((i + 1) * slice_w) - 1, lo + 1)
        s = int(rng.integers(lo, hi))
        s = max(s, prev + min_gap)
        steps.append(s)
        prev = s
    order = list(kinds)
    rng.shuffle(order)
    out = []
    for i, step in enumerate(steps):
        kind = order[i % len(order)]
        node = int(rng.integers(0, n))
        graceful = bool(rng.integers(0, 2))
        out.append(Scenario(kind=kind, step=step, node=node,
                            graceful=graceful))
    return out


def ensure_coverage(scenarios, *, kinds, n: int) -> list:
    """Rewrite a plan so it covers every kind in `kinds` at least once,
    keeping steps/nodes/gracefulness fixed (used by CI smokes that must
    hit >=4 distinct kinds regardless of the seed's shuffle)."""
    want = [k for k in kinds if k not in {s.kind for s in scenarios}]
    out = list(scenarios)
    for i in range(len(out) - 1, -1, -1):
        if not want:
            break
        dupes = [s.kind for s in out].count(out[i].kind)
        if dupes > 1:
            out[i] = replace(out[i], kind=want.pop(), params={})
    return out


# ------------------------------------------------------- corruption helpers
def corrupt_shm_stripe(run: str, node: int, n: int, total_bytes: int,
                       *, seed: int = 0, nbytes: int = 16,
                       step: int = None, region: str = "own") -> dict:
    """Flip `nbytes` bytes inside a live CLEAN shm snapshot buffer of
    `node` — real damage in the real segment, detectable only by the CRC
    probe / in-pass restore CRC.  `region="own"` (default) confines the
    flip to the member's data shard, which the snapshot-time `crc_own`
    digest covers; `region="any"` may hit the parity strip too (live
    parity carries no digest — only a durable-tier scrub would see it).
    Returns {step, offset, nbytes}."""
    from repro.core.smp import ReadOnlyNode
    view = ReadOnlyNode(run, node, n, total_bytes)
    try:
        clean = view.clean_steps()
        if not clean:
            raise RuntimeError(f"node {node} has no CLEAN snapshot buffer "
                               "to corrupt")
        tgt = step if step in clean else max(clean)
        idx = clean[tgt]
        shm = view._bufs[idx]
        rng = np.random.default_rng(seed)
        limit = (view.layout.buf_bytes if region == "any"
                 else (total_bytes if n == 1 else view.layout.own_bytes))
        off = int(rng.integers(0, max(limit - nbytes, 1)))
        buf = np.ndarray((limit,), np.uint8, shm.buf)
        buf[off:off + nbytes] ^= 0xFF
        del buf                       # no exported pointers past close()
        return {"step": int(tgt), "offset": off, "nbytes": int(nbytes)}
    finally:
        view.close()


def corrupt_reft_file(path: str, *, seed: int = 0, nbytes: int = 16) -> dict:
    """Flip `nbytes` bytes in a `.reft` member file's data region (past
    the pickled head, so the family still opens but fails its digest /
    CRC check).  Returns {offset, nbytes}."""
    with open(path, "rb") as f:
        pickle.load(f)                # skip the head
        data_off = f.tell()
    import os
    size = os.path.getsize(path)
    if size - data_off < nbytes:
        raise RuntimeError(f"{path}: data region too small to corrupt")
    rng = np.random.default_rng(seed)
    off = data_off + int(rng.integers(0, size - data_off - nbytes + 1))
    with open(path, "r+b") as f:
        f.seek(off)
        chunk = bytearray(f.read(nbytes))
        for i in range(len(chunk)):
            chunk[i] ^= 0xFF
        f.seek(off)
        f.write(bytes(chunk))
    return {"offset": off, "nbytes": int(nbytes)}
