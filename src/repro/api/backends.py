"""Built-in memory-tier backends: `reft` and `null`.

`reft` wraps the paper's full stack behind the uniform `Checkpointer`
protocol: a `ReftGroup` of SnapshotEngines (one real SMP process per SG
member), the three-tier recovery ladder, and `CheckpointManager` retention
(manifest + keep-latest-k GC) for the persisted REFT-Ckpt tier.

`reft_recovery_ladder` is the single implementation of the tier policy —
`ReftGroup.recover`, `LocalCluster.recover`, and the facade all route
through it.
"""
from __future__ import annotations

import time
from typing import Any, List, Optional

from repro.api.registry import register_backend
from repro.api.types import (
    Checkpointer, CheckpointSpec, RestoreResult, RestoreTarget,
)
from repro.core.loader import LoadStats, resolve_need
from repro.core.recovery import (
    RecoveryError, restore_from_checkpoint, restore_state,
)


def _target_need(template: Any, target: Optional[RestoreTarget]):
    """RestoreTarget -> (global byte ranges or None, device_put flag).
    The spec is derived from the template, which is layout-identical to
    what was saved (the FlatSpec contract every tier relies on)."""
    if target is None:
        return None, False
    from repro.core.treebytes import make_flat_spec
    need = resolve_need(make_flat_spec(template), target)
    return need, bool(target.device_put)


def reft_recovery_ladder(run: str, n: int, total_bytes: int, template: Any,
                         alive_nodes: List[int], ckpt_dir: str,
                         step: Optional[int] = None,
                         target: Optional[RestoreTarget] = None,
                         store=None, store_prefix: str = "families",
                         store_retry=None, sched=None) -> RestoreResult:
    """Tiered recovery (paper §3 step 5 + the tier-4 remote rung):
      in-memory  — every member's SMP segments reachable, plain reassembly;
      raim5      — exactly one member missing, decode it from parity;
      checkpoint — >1 member gone, reload the last persisted REFT-Ckpt;
      objstore   — local families gone/corrupt too, ranged reads from the
                   object store's manifest-complete families (only when a
                   `store` is configured).

    Every tier routes through the distributed loader's `LoadPlan`
    executors; `target` restricts the plan to the restoring job's layout
    (reshard-on-restore / partial loads) and the returned
    `RestoreResult.load` carries the per-phase `LoadStats`.
    """
    need, device_put = _target_need(template, target)
    target_n = (target.sg_size if target and target.sg_size else n)
    stats = LoadStats()
    stats.target_n = target_n
    try:
        info: dict = {}
        state, got_step, extra = restore_state(
            run, n, total_bytes, template, alive_nodes, info=info,
            step=step, need=need, device_put=device_put, stats=stats,
            sched=sched)
        # tier reflects what the restore actually did: any member that had
        # to be decoded from parity (gone, corrupt, OR a laggard whose
        # buffers rotated past the chosen step) makes it raim5
        repaired = (info.get("missing", []) or info.get("corrupt", [])
                    or info.get("stale", []))
        stats.tier = "raim5" if repaired else "in-memory"
        stats.saved_n = n
        stats.resharded = stats.target_n != n
        return RestoreResult(state=state, step=got_step, extra_meta=extra,
                             tier=stats.tier, load=stats)
    except RecoveryError:
        pass
    try:
        stats = LoadStats()                    # drop partial tier-1/2 reads
        stats.target_n = target_n
        state, got_step, extra = restore_from_checkpoint(
            ckpt_dir, n, template, step=step, need=need,
            device_put=device_put, stats=stats, sched=sched)
        stats.tier = "checkpoint"
        stats.resharded = stats.saved_n != stats.target_n
        return RestoreResult(state=state, step=got_step, extra_meta=extra,
                             tier="checkpoint", load=stats)
    except RecoveryError:
        if store is None:
            raise
    from repro.core.recovery import restore_from_objstore
    stats = LoadStats()                        # drop partial tier-3 reads
    stats.target_n = target_n
    state, got_step, extra = restore_from_objstore(
        store, store_prefix, n, template, step=step, need=need,
        device_put=device_put, stats=stats, retry=store_retry, sched=sched)
    stats.tier = "objstore"
    stats.resharded = stats.saved_n != stats.target_n
    return RestoreResult(state=state, step=got_step, extra_meta=extra,
                         tier="objstore", load=stats)


class ReftCheckpointer(Checkpointer):
    """REFT behind the facade: async sharded in-memory snapshots (REFT-Sn),
    SMP-side persistence (REFT-Ckpt) with managed retention, ladder
    recovery, real fault injection, and elastic healing."""

    name = "reft"

    def __init__(self, spec: CheckpointSpec, state_template: Any):
        super().__init__(spec)
        from repro.ckpt.manager import CheckpointManager
        from repro.core.coordinator import ReftGroup
        from repro.core.snapshot import ReftConfig, _trace_default

        run_id = spec.run_id or CheckpointSpec.alloc_run_id()
        opt = spec.options
        rcfg = ReftConfig(
            bucket_bytes=spec.bucket_bytes,
            ckpt_dir=spec.ckpt_dir,
            snapshot_every_steps=spec.snapshot_every_steps,
            # the session owns persist cadence; disable the group's own
            checkpoint_every_snapshots=10 ** 9,
            run_id=run_id,
            stage_slots=opt.get("stage_slots", 8),
            # HASC saving-pipeline knobs (docs/API.md "Saving pipeline");
            # pipeline=False keeps the serial pre-refactor thread as the
            # measurable interference baseline
            pipeline=opt.get("pipeline", True),
            prefetch_window=opt.get("prefetch_window", 4),
            scratch_buffers=opt.get("scratch_buffers", 2),
            opt_first=opt.get("opt_first", True),
            yield_every_buckets=opt.get("yield_every_buckets", 4),
            boundary_timeout_s=opt.get("boundary_timeout_s", 0.005),
            # device-side encode + multi-flight (docs/API.md
            # "Device-side encode"): fused Pallas gather+XOR+CRC before
            # d2h, overlapped flights, saving-path CPU pinning
            device_encode=opt.get("device_encode", "auto"),
            crc_impl=opt.get("crc_impl", "pallas"),
            max_flights=opt.get("max_flights", 1),
            pin_cpus=opt.get("pin_cpus", "auto"),
            # async-persistence knobs (docs/API.md "Async persistence"):
            # simulated durable-tier latency for tests and the
            # persist-overlap interference benchmark; persist_bw_limit
            # rate-limits the SMP's background writes (+ uploads) so the
            # durable tier cannot starve a co-located trainer of IO
            persist_delay_s=opt.get("persist_delay_s", 0.0),
            persist_bw_limit=opt.get("persist_bw_limit", 0.0),
            # dirty-delta snapshotting (docs/API.md "Delta snapshots &
            # keyframes"): flights re-send only changed buckets, persists
            # write `.reftd` chains against the last persisted step
            delta=opt.get("delta", False),
            delta_keyframe=opt.get("delta_keyframe", 8),
            delta_dirty_threshold=opt.get("delta_dirty_threshold", 0.6),
            delta_digest=opt.get("delta_digest", True),
            # straggler-aware loading (docs/API.md "Straggler-aware
            # loading"): restore-side read scheduler mode and token-bucket
            # rate cap mirroring persist_bw_limit on the write side
            restore_sched=opt.get("restore_sched", "adaptive"),
            restore_bw_limit=opt.get("restore_bw_limit", 0.0),
            # runtime SMP-protocol validation (docs/API.md "Analysis &
            # invariants"); default follows REPRO_TRACE_PROTOCOL so CI
            # turns it on fleet-wide without touching call sites
            trace_protocol=bool(opt.get("trace_protocol",
                                        _trace_default())),
        )
        self.group = ReftGroup(spec.sg_size, state_template, rcfg)
        self.manager = CheckpointManager(spec.ckpt_dir, spec.sg_size,
                                         keep=spec.keep)
        self._degraded_emitted: set = set()
        self._preempts: dict = {}       # node -> monotonic eviction deadline
        self._preempted: list = []      # nodes whose grace window expired
        # optional FailureObserver attached by the session; its learned
        # per-source bandwidths seed the read scheduler's EWMA priors
        self.observer = None

    # ------------------------------------------------------------- save
    def snapshot(self, state, step, extra_meta=None, wait=False):
        self.poll_persists()           # fold finished async persists first
        t0 = time.perf_counter()
        lv0 = self.group.level_seconds() if wait else None
        started = self.group.snapshot(state, step, extra_meta, wait=wait)
        if started:
            levels = None
            if wait:
                lv1 = self.group.level_seconds()
                levels = {k: lv1[k] - lv0[k] for k in lv1}
            self.emit("snapshot", step, seconds=time.perf_counter() - t0,
                      nbytes=self.group.total_bytes, levels=levels,
                      detail="" if wait else "async-launch")
        self._check_degraded(step)
        return started

    def set_dirty_provider(self, fn) -> None:
        """Install the delta saving path's dirtiness signal on every
        member engine (e.g. `repro.core.delta.expert_dirty_ranges` over
        the MoE router's touched-expert mask); no-op when `delta` is
        off."""
        for e in self.group.engines:
            e.set_dirty_provider(fn)

    def poll_persists(self):
        """Collect finished REFT-Ckpt rounds: resolve the manager's
        in-flight registration, commit the manifest (+GC), and emit a
        `persist` (or `persist-error`) event per round."""
        self._tick_preempts()
        return self._emit_rounds(self.group.poll_persists())

    def _emit_rounds(self, out):
        for r in out:
            self.manager.resolve_inflight(r["step"])
            if r["ok"]:
                manifest = self.manager.commit()
                detail = f"manifest={manifest['complete_steps']}"
                if r.get("kind") == "delta":
                    detail += f" delta-from-{r['base_step']}"
                self.emit("persist", r["step"], seconds=r["seconds"],
                          detail=detail)
            else:
                # the torn family is left to GC (no longer in-flight);
                # the engine is NOT degraded — a failed durable write
                # must not pause in-memory protection
                self.manager.commit()
                self.emit("persist-error", r["step"], seconds=r["seconds"],
                          detail="; ".join(r["errors"]))
        return out

    def _persist_remote(self) -> Optional[dict]:
        """Tier-4 hook: the `remote` spec ({store, prefix, retry}) each
        persist round mirrors shards under, or None for local-only (this
        base backend).  `ObjStoreCheckpointer` overrides it."""
        return None

    def _delta_base(self) -> Optional[int]:
        """Base step for a delta persist round: the newest fully-landed
        step on EVERY durable tier in play (a local-only base would tear
        the remote chain), or None for a full round.  The coordinator
        still falls back to full shards when any member lacks the flight
        extents, and the engines' snapshot keyframes bound chain length
        (a keyframe in the span voids the chain)."""
        if not self.spec.options.get("delta", False):
            return None
        steps = set(self.manager.complete_steps())
        if self.manager.store is not None:
            steps &= set(self.manager.remote_complete_steps())
        steps -= set(self.manager.inflight_steps())
        return max(steps) if steps else None

    def persist(self, step=None, wait=True):
        """Fire an SG-consistent REFT-Ckpt round.  `wait=False` returns
        the fired step immediately (the SMPs stream their pinned shards
        on background threads); `wait=True` additionally drains the
        freshest snapshot first (so the round captures it) and blocks
        until the family is durable, raising on persist failure."""
        self.poll_persists()
        if wait:
            self.group.wait()          # capture the newest snapshot
        s = self.group.checkpoint_async(remote=self._persist_remote(),
                                        delta_base=self._delta_base())
        if s is None:
            return None
        self.manager.register_inflight(s)
        if wait:
            rounds = self._emit_rounds(self.group.drain_persists())
            mine = next((r for r in rounds if r["step"] == s), None)
            if mine is not None and not mine["ok"]:
                raise RuntimeError(f"REFT-Ckpt persist failed: "
                                   f"{'; '.join(mine['errors'])}")
        return s

    # ---------------------------------------------------------- restore
    def _ladder_extra(self) -> dict:
        """Tier-4 hook: extra `reft_recovery_ladder` kwargs (the object
        store the checkpoint tier falls through to).  Empty here;
        `ObjStoreCheckpointer` overrides it."""
        return {}

    def _restore_sched(self):
        """Build the read-scheduler config for this restore.

        Mode and the token-bucket cap come from the spec options (via
        `ReftConfig`); EWMA bandwidth priors come from the attached
        `FailureObserver`'s per-source history when a session wired one
        in, so a source that dragged the last restore starts this one
        already marked slow.  Returns None for mode "fcfs" so the legacy
        executor runs untouched.
        """
        from repro.core.readsched import SchedConfig
        rcfg = self.group.cfg
        if rcfg.restore_sched == "fcfs" and rcfg.restore_bw_limit <= 0:
            return None
        priors = {}
        obs = getattr(self, "observer", None)
        if obs is not None:
            priors = dict(getattr(obs, "source_bw", {}) or {})
        return SchedConfig(mode=rcfg.restore_sched,
                           restore_bw_limit=rcfg.restore_bw_limit,
                           priors=priors)

    def restore(self, step=None, target=None):
        from repro.core.coordinator import NodeState
        if target is None:
            target = RestoreTarget(sg_size=self.spec.sg_size)
        t0 = time.perf_counter()
        # drain each member best-effort: one dying member's flight error
        # (e.g. its SMP was killed mid-send) must never abort recovery —
        # mark it degraded so the ladder excludes it and RAIM5 repairs it
        for e in self.group.engines:
            if self.group.states[e.node] != NodeState.HEALTHY:
                continue
            try:
                e.wait()
            except Exception:
                e.degraded = True
        # a degraded member's SMP is gone: its segments (if any survive)
        # hold STALE steps that would drag the common step backwards —
        # treat it like a failed node and let RAIM5 repair it instead
        alive = [i for i in range(self.group.n)
                 if self.group.states[i] != NodeState.OFFLINE
                 and not self.group.engines[i].degraded]
        res = reft_recovery_ladder(
            self.group.run, self.group.n, self.group.total_bytes,
            self.group.template, alive, self.spec.ckpt_dir,
            step=step, target=target, sched=self._restore_sched(),
            **self._ladder_extra())
        ld = res.load
        self.emit("restore", res.step, seconds=time.perf_counter() - t0,
                  tier=res.tier, nbytes=ld.bytes_read if ld else 0,
                  detail=(f"read={ld.bytes_read} decoded={ld.decoded_bytes}"
                          f"{' resharded' if ld.resharded else ''}"
                          if ld else ""))
        return res

    # ----------------------------------------------------------- health
    def _check_degraded(self, step):
        for e in self.group.engines:
            if e.degraded and e.node not in self._degraded_emitted:
                self._degraded_emitted.add(e.node)
                self.emit("degraded", step, detail=f"node{e.node}:smp-lost")

    def _tick_preempts(self):
        """Fire pending spot reclaims whose grace window has expired: the
        node is gone exactly as if it had hard-failed (SMP killed, shm
        unlinked, OFFLINE)."""
        if not self._preempts:
            return
        now = time.monotonic()
        for node, deadline in list(self._preempts.items()):
            if now >= deadline:
                del self._preempts[node]
                self._preempted.append(node)
                self.group.inject_node_failure(node)
                self.emit("preempted", -1, detail=f"node{node}")

    def health(self):
        from repro.core.coordinator import NodeState
        self._tick_preempts()
        now = time.monotonic()
        members = {}
        degraded = []
        for e in self.group.engines:
            st = self.group.states[e.node]
            smp_alive = e.smp.alive()
            # a dead SMP is degradation even before a send notices it
            # (killed between snapshots: `e.degraded` has not flipped yet)
            bad = e.degraded or st != NodeState.HEALTHY or not smp_alive
            members[e.node] = {
                "state": st.value,
                "degraded": e.degraded,
                "smp_alive": smp_alive,
                "last_clean_step": e.last_clean_step,
            }
            if bad:
                degraded.append(e.node)
        return {"healthy": not degraded, "degraded": degraded,
                "members": members,
                "preempting": {n: max(d - now, 0.0)
                               for n, d in self._preempts.items()},
                "preempted": list(self._preempted)}

    def stats(self):
        out = super().stats()
        eng = [e.stats for e in self.group.engines]
        out["engine_snapshots"] = sum(s["snapshots"] for s in eng)
        out["engine_bytes_sent"] = sum(s["bytes_sent"] for s in eng)
        out["engine_seconds"] = sum(s["seconds"] for s in eng)
        out["persist_inflight"] = self.group.persist_inflight()
        out["persist_overlap_seconds"] = sum(
            s.get("persist_overlap_seconds", 0.0) for s in eng)
        out["persist_errors"] = sum(s.get("persist_errors", 0) for s in eng)
        out["persist_throttle_seconds"] = sum(
            s.get("persist_throttle_seconds", 0.0) for s in eng)
        out["persist_bw_limit"] = float(
            self.spec.options.get("persist_bw_limit", 0.0))
        out["restore_bw_limit"] = float(
            self.spec.options.get("restore_bw_limit", 0.0))
        out["restore_sched"] = self.spec.options.get(
            "restore_sched", "adaptive")
        out["skipped_buckets"] = sum(s.get("skipped_buckets", 0)
                                     for s in eng)
        out["delta_flights"] = sum(s.get("delta_flights", 0) for s in eng)
        out["keyframe_flights"] = sum(s.get("keyframe_flights", 0)
                                      for s in eng)
        out["delta_base_misses"] = sum(s.get("delta_base_misses", 0)
                                       for s in eng)
        up_bytes = sum(s.get("persist_upload_bytes", 0) for s in eng)
        if up_bytes:
            out["persist_upload_bytes"] = up_bytes
            out["persist_upload_seconds"] = sum(
                s.get("persist_upload_seconds", 0.0) for s in eng)
            out["persist_upload_retries"] = sum(
                s.get("persist_upload_retries", 0) for s in eng)
        for k, v in self.group.level_seconds().items():
            out[f"engine_{k}_seconds"] = v
        return out

    # ----------------------------------------------------------- faults
    def inject_failure(self, node=0, kind="software", **params):
        """Knock out a real member.  Beyond the classic `software`/`node`
        kinds, the supervisor's scenario taxonomy is supported:

          smp             kill only the fault-tolerance sidecar process
                          (segments survive; the engine degrades on its
                          next send, or `health()` notices sooner)
          laggard         SIGSTOP the member's SMP for `lag_s` seconds
                          (delayed acks / credit stalls), auto-SIGCONT
          corrupt-stripe  flip `nbytes` bytes inside the member's newest
                          CLEAN shm snapshot buffer (`seed` deterministic)
          slow-persist    raise the member's durable-tier write latency
                          to `delay_s` per shard, effective immediately
          preempt         spot reclaim notice: after `grace_s` seconds the
                          node hard-fails (health()/poll ticks fire it)
        """
        e = self.group.engines[node]
        if kind == "software":
            self.group.inject_software_failure(node)
        elif kind == "node":
            self.group.inject_node_failure(node)
        elif kind == "smp":
            e.smp.kill()
        elif kind == "laggard":
            import os
            import signal
            import threading
            lag = float(params.get("lag_s", 0.4))
            pid = e.smp.proc.pid
            try:
                os.kill(pid, signal.SIGSTOP)
            except (ProcessLookupError, PermissionError):
                pass                      # already gone: nothing to stall
            else:
                def _cont():
                    try:
                        os.kill(pid, signal.SIGCONT)
                    except (ProcessLookupError, PermissionError):
                        pass
                # a real timer thread: the trainer may be *blocked* on this
                # SMP's ring credits, so a poll-based resume would deadlock
                t = threading.Timer(lag, _cont)
                t.daemon = True
                t.start()
        elif kind == "corrupt-stripe":
            from repro.supervise.inject import corrupt_shm_stripe
            kw = dict(seed=int(params.get("seed", 0)),
                      nbytes=int(params.get("nbytes", 16)),
                      step=params.get("step"),
                      region=params.get("region", "own"))
            try:
                info = corrupt_shm_stripe(
                    self.group.run, node, self.group.n,
                    self.group.total_bytes, **kw)
            except RuntimeError:
                # no CLEAN buffer yet (all flights in the air): land one,
                # then corrupt it
                e.wait()
                info = corrupt_shm_stripe(
                    self.group.run, node, self.group.n,
                    self.group.total_bytes, **kw)
            self.emit("corrupt", info["step"],
                      detail=f"node{node}:off{info['offset']}"
                             f"+{info['nbytes']}")
        elif kind == "slow-persist":
            e.persist_delay_s = float(params.get("delay_s", 0.25))
        elif kind == "preempt":
            grace = float(params.get("grace_s", 0.3))
            self._preempts[node] = time.monotonic() + grace
        else:
            raise ValueError(f"unknown failure kind {kind!r}")
        self.emit("inject", -1, detail=f"{kind}:node{node}")

    def evict(self, node):
        """Remediate a member whose live stripe is known-corrupt: take it
        OFFLINE so the next restore RAIM5-decodes it from the survivors'
        parity instead of trusting its segments."""
        self.group.inject_node_failure(node)
        self.emit("evict", -1, detail=f"node{node}")

    def heal(self):
        for i in range(self.group.n):
            self.group.heal(i)
        self._degraded_emitted.clear()        # healed members report anew
        self._preempted.clear()
        self.emit("heal", -1)

    def wait(self):
        self.group.wait()
        self._emit_rounds(self.group.drain_persists())

    def close(self):
        try:                              # join outstanding persists so a
            self._emit_rounds(            # durable family is never torn
                self.group.drain_persists(30))   # by a clean shutdown
        except Exception:
            pass
        self.group.close()


@register_backend("reft")
def _make_reft(spec: CheckpointSpec, template: Any) -> Checkpointer:
    return ReftCheckpointer(spec, template)


class NullCheckpointer(Checkpointer):
    """No fault tolerance at all — the paper's 'no checkpointing' baseline
    and the overhead floor every other backend is measured against."""

    name = "null"

    def __init__(self, spec: CheckpointSpec, state_template: Any):
        super().__init__(spec)

    def snapshot(self, state, step, extra_meta=None, wait=False):
        return True

    def persist(self, step=None, wait=True):
        return None

    def restore(self, step=None, target=None):
        raise RecoveryError("null backend keeps nothing to restore")

    def health(self):
        return {"healthy": True, "degraded": [], "members": {}}

    def close(self):
        pass


@register_backend("null")
def _make_null(spec: CheckpointSpec, template: Any) -> Checkpointer:
    return NullCheckpointer(spec, template)
