"""Unified checkpointing facade: protocol types.

One `Checkpointer` interface in front of every save/restore engine in the
repo — REFT's in-memory three-tier ladder and the disk baselines — so the
paper's headline comparison (near-zero in-memory overhead vs disk
checkpointing) is a one-flag swap in every driver, benchmark, and example.

A backend implements:
  snapshot(state, step)  cheap/frequent tier (in-memory for REFT, the disk
                         write itself for disk backends)
  persist(step)          durable tier (REFT-Ckpt shard persist; fsync/drain
                         for disk backends)
  restore(step)          best state the backend can reconstruct, with the
                         recovery tier that produced it
  health()               structured liveness/degradation report
  close()                release processes / shared memory / threads

and emits `CkptEvent` records for every operation, so drivers get uniform
stats without reaching into backend internals.
"""
from __future__ import annotations

import abc
import time
import uuid
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional


@dataclass(frozen=True)
class CkptEvent:
    """One structured record per checkpointing operation."""
    kind: str                     # snapshot | persist | persist-error |
                                  # restore | degraded | inject | heal | gc
    step: int
    backend: str
    seconds: float = 0.0
    nbytes: int = 0
    tier: Optional[str] = None    # restore only: in-memory | raim5 | ...
    detail: str = ""
    # saving-pipeline decomposition for this operation (seconds spent per
    # HASC level: l1 device reads / l1_stall credit waits / l2 ring writes
    # / l3 SMP signaling+ack); None for backends without a pipeline
    levels: Optional[Dict[str, float]] = None
    wall: float = field(default_factory=time.time)


@dataclass(frozen=True)
class RestoreTarget:
    """Where a restore is going — the reshard-on-restore contract.

    The snapshot may have been taken by an n-member SG on one mesh; the
    restoring job declares its OWN layout here and the distributed loader
    (`repro.core.loader`) computes the minimal old-layout byte ranges to
    read.  All filters compose by intersection; everything defaults to a
    full-state restore.
    """
    sg_size: Optional[int] = None     # restoring group's SG size (n -> m)
    member: Optional[int] = None      # only this NEW member's byte shard
    leaves: Optional[Tuple[str, ...]] = None   # leaf-path substrings
    shardings: Any = None             # PartitionSpec pytree (repro.dist)
    mesh: Any = None                  # target mesh the shardings refer to
    coord: Optional[Dict[str, int]] = None     # this rank's mesh coords
    device_put: bool = False          # overlapped h2d during assembly


@dataclass(frozen=True)
class RestoreResult:
    """What `Checkpointer.restore()` hands back to the training loop."""
    state: Any
    step: int
    extra_meta: dict
    tier: str                     # which rung of the ladder produced it
    # per-phase load accounting from the distributed loader (None for
    # backends that bypass it): tier/source, bytes_read, decoded_bytes,
    # read/decode/h2d seconds, resharded flag (repro.core.loader.LoadStats)
    load: Optional[Any] = None


@dataclass(frozen=True)
class CheckpointSpec:
    """Declarative backend selection + tuning, shared by every driver.

    `backend` is a registry name ("reft", "sync_disk", "async_disk",
    "null", ...); everything else is cadence/layout the `CheckpointSession`
    and the backend share.  Backend-specific extras go in `options`.
    """
    backend: str = "reft"
    ckpt_dir: str = "/tmp/repro-ckpt"
    sg_size: int = 4                    # SG members (reft) / ranks (disk)
    snapshot_every_steps: int = 1
    checkpoint_every_steps: int = 50
    bucket_bytes: int = 4 << 20
    keep: int = 3                       # retention (complete ckpt families)
    run_id: Optional[str] = None        # None -> session allocates one
    resume: bool = True                 # restore-on-entry when possible
    auto_tune: bool = False             # Appendix-A cadence retuning
    lam_node: float = 1e-4
    fsync: bool = False
    options: Dict[str, Any] = field(default_factory=dict)

    def with_run_id(self, run_id: str) -> "CheckpointSpec":
        return replace(self, run_id=run_id)

    @staticmethod
    def alloc_run_id() -> str:
        return uuid.uuid4().hex[:8]

    def build(self, state_template: Any) -> "Checkpointer":
        from repro.api.registry import create_checkpointer
        return create_checkpointer(self, state_template)


class Checkpointer(abc.ABC):
    """Pluggable checkpointing backend (see module docstring)."""

    name: str = "abstract"

    # events kept for inspection are bounded; stats aggregate ALL events
    # incrementally so stats() stays O(1) (auto-tune calls it every step)
    EVENT_BUFFER = 4096

    def __init__(self, spec: CheckpointSpec):
        from collections import deque
        self.spec = spec
        self.events = deque(maxlen=self.EVENT_BUFFER)
        self.on_event: Optional[Callable[[CkptEvent], None]] = None
        self._agg: Dict[str, Any] = {}

    # ------------------------------------------------------------- emit
    def emit(self, kind: str, step: int, **kw) -> CkptEvent:
        ev = CkptEvent(kind=kind, step=int(step), backend=self.name, **kw)
        self.events.append(ev)
        agg = self._agg
        agg[kind] = agg.get(kind, 0) + 1
        agg[f"{kind}_seconds"] = agg.get(f"{kind}_seconds", 0.0) + ev.seconds
        agg[f"{kind}_bytes"] = agg.get(f"{kind}_bytes", 0) + ev.nbytes
        if self.on_event is not None:
            self.on_event(ev)
        return ev

    def stats(self) -> dict:
        """Aggregate event counters (uniform across backends)."""
        return {"backend": self.name, **self._agg}

    # --------------------------------------------------------- protocol
    @abc.abstractmethod
    def snapshot(self, state: Any, step: int, extra_meta: dict = None,
                 wait: bool = False) -> bool:
        """Capture `state` at `step`; False if skipped (in-flight save,
        degraded backend).  `wait=True` blocks until the capture is clean."""

    @abc.abstractmethod
    def persist(self, step: Optional[int] = None,
                wait: bool = True) -> Optional[int]:
        """Make the newest clean capture durable; returns its step (None
        when there is nothing to persist).  `wait=False` fires the
        durable write WITHOUT blocking on disk I/O and returns the step
        as a ticket — completion is collected by `poll_persists()` /
        `wait()` and surfaced as `persist` events; backends whose persist
        is inherently synchronous may ignore the flag."""

    @abc.abstractmethod
    def restore(self, step: Optional[int] = None,
                target: Optional[RestoreTarget] = None) -> RestoreResult:
        """Reconstruct state (newest available, or exactly `step`).
        `target` declares the restoring job's layout (reshard-on-restore,
        partial loads); backends without a distributed loader may ignore
        it.  Raises `repro.core.recovery.RecoveryError` when nothing is
        left."""

    @abc.abstractmethod
    def health(self) -> dict:
        """{"healthy": bool, "degraded": [...], "members": {...}} — shape
        shared across backends, members payload backend-specific."""

    @abc.abstractmethod
    def close(self) -> None:
        """Release resources.  Idempotent."""

    # ------------------------------------------------- optional surface
    def wait(self) -> None:
        """Drain in-flight async work — snapshots AND fired persists
        (no-op where saves are synchronous)."""

    def poll_persists(self) -> list:
        """Non-blocking: collect async persists that completed since the
        last poll (emitting their events); returns completion records.
        Backends without overlapped persistence return []."""
        return []

    def inject_failure(self, node: int = 0, kind: str = "software",
                       **params) -> None:
        """Simulate a failure for drills.  Disk backends interpret any kind
        as 'the training process lost its in-memory state' (a no-op on the
        backend itself); memory-tier backends knock out real members.
        `params` carry kind-specific knobs (grace_s, lag_s, delay_s,
        nbytes, seed — see `repro.supervise.inject.DEFAULT_PARAMS`)."""
        self.emit("inject", -1, detail=f"{kind}:node{node}")

    def heal(self) -> None:
        """Bring failed members back after a recovery (no-op by default)."""

    # ------------------------------------------------------- context mgr
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
