"""`objstore` backend: the REFT stack + tier-4 object-store durability.

Extends `ReftCheckpointer` so every REFT-Ckpt round ALSO streams each
member's shard to an object store — stripe-granular multipart uploads
running on the SMPs' persist workers (seq-tagged tickets, refcounted
buffer pins: snapshots keep flowing through uploads) — and publishes a
per-family MANIFEST as the completeness marker once all shards landed.
Restore gains a fourth rung: when local `.reft` families are gone or
corrupt, the recovery ladder falls through to ranged remote reads
(`ObjectSource`), including elastic n->m reshard against remote
families.  A background `Scrubber` walks both durable tiers on a
cadence, verifies stripe digests, and repairs corrupt blocks from RAIM5
parity; its findings surface as `scrub` events and `scrub_*` stats.

spec.options (on top of the reft backend's):
  store          ObjectStore instance or config dict (default: a
                 LocalObjectStore under `<ckpt_dir>/objstore`)
  store_prefix   key prefix remote families live under ("families")
  store_retry    retry/backoff policy dict ({attempts, base_s, max_s,
                 mult}) for uploads, restores, and scrubs
  scrub_every_s  scrubber cadence; 0 disables the daemon (manual
                 `scrub()` still works)                      [300.0]
  scrub_repair   let the scrubber rewrite repaired blocks     [True]

The reft backend's `restore_sched` / `restore_bw_limit` options are
inherited and apply to every rung here too — remote ranged reads go
through the same straggler-aware chunk scheduler and token bucket as
shm and tier-3 file reads (docs/API.md "Straggler-aware loading").
"""
from __future__ import annotations

import os
from typing import Any, Optional

from repro.api.backends import ReftCheckpointer
from repro.api.registry import register_backend
from repro.api.types import Checkpointer, CheckpointSpec
from repro.store import (
    ScrubReport, Scrubber, build_manifest, put_manifest, store_from_config,
)


class ObjStoreCheckpointer(ReftCheckpointer):
    name = "objstore"

    def __init__(self, spec: CheckpointSpec, state_template: Any):
        super().__init__(spec, state_template)
        from repro.ckpt.manager import CheckpointManager
        opt = spec.options
        store = opt.get("store") or {
            "kind": "local", "root": os.path.join(spec.ckpt_dir, "objstore")}
        self.store = store_from_config(store)
        self._store_cfg = self.store.config
        # a CONSTANT default prefix (not run-scoped): a restarted run
        # must find the previous run's remote families
        self.store_prefix = opt.get("store_prefix", "families")
        self.store_retry = opt.get("store_retry")
        # swap in a store-aware manager: remote families join latest()
        # and GC on equal footing with local ones
        self.manager = CheckpointManager(
            spec.ckpt_dir, spec.sg_size, keep=spec.keep, store=self.store,
            remote_prefix=self.store_prefix)
        self.scrubber = Scrubber(
            ckpt_dir=spec.ckpt_dir, store=self.store,
            prefix=self.store_prefix,
            interval_s=float(opt.get("scrub_every_s", 300.0)),
            repair=bool(opt.get("scrub_repair", True)),
            skip_steps=self.manager.inflight_steps,
            on_report=self._on_scrub, retry=self.store_retry)
        if self.scrubber.interval_s > 0:
            self.scrubber.start()

    # ---------------------------------------------------- tier-4 hooks
    def _persist_remote(self) -> Optional[dict]:
        return {"store": self._store_cfg, "prefix": self.store_prefix,
                "retry": self.store_retry}

    def _ladder_extra(self) -> dict:
        return {"store": self.store, "store_prefix": self.store_prefix,
                "store_retry": self.store_retry}

    def _emit_rounds(self, out):
        # publish the family manifest BEFORE the base class commits and
        # emits: the manifest is the remote completeness marker, so an
        # upload round only counts once it exists — a round that fails
        # here is downgraded to persist-error and its orphans left to GC
        for r in out:
            ups = r.get("uploads")
            if not r["ok"] or not ups:
                continue
            try:
                man = build_manifest(
                    run=self.group.run, step=r["step"], n=self.group.n,
                    total_bytes=self.group.total_bytes, nodes=ups)
                put_manifest(self.store, self.store_prefix, man,
                             retry=self.store_retry)
            except Exception as e:
                r["ok"] = False
                r["errors"].append(f"manifest: {e!r}")
        return super()._emit_rounds(out)

    # --------------------------------------------------------- scrubbing
    def scrub(self):
        """One synchronous scrub pass over both durable tiers (the
        daemon keeps its own cadence)."""
        return self.scrubber.scan_once()

    def _on_scrub(self, rep: ScrubReport) -> None:
        if rep.clean and not rep.repaired:
            return                       # quiet pass: stats only
        kind = "scrub-repair" if rep.repaired else "scrub"
        self.emit(kind, rep.step,
                  detail=(f"{rep.kind}: corrupt={rep.corrupt} "
                          f"repaired={rep.repaired} "
                          f"unrepairable={rep.unrepairable} "
                          f"errors={rep.errors}"))

    def stats(self):
        out = super().stats()
        out.update(self.scrubber.stats())
        return out

    def close(self):
        self.scrubber.stop()
        super().close()


@register_backend("objstore")
def _make_objstore(spec: CheckpointSpec, template: Any) -> Checkpointer:
    return ObjStoreCheckpointer(spec, template)
